# Convenience wrappers — all targets set PYTHONPATH=src so `make test`
# works from a clean checkout with no install step.
PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-all test-chaos bench-smoke bench-plan bench-cache \
        bench-pipeline bench-features bench-resilience bench-obs \
        bench-serve bench-membership trace-demo train-smoke serve-demo

# Fast lane (tier-1): everything except @pytest.mark.slow (pyproject default)
test:
	$(PYTHON) -m pytest -x -q

# Full suite including the slow multi-device integration tests
test-all:
	$(PYTHON) -m pytest -q -m ""

# Tier-1 fast lane under transient-only background chaos (deterministic
# low-rate comm delays, guarded drops, planner stalls, flapping peers —
# repro.resilience). Every tier-1 assertion must hold unchanged, and every
# chaos kind must fire at least once over the suite (the coverage gate in
# tests/conftest.py). Seed 17 is chosen so each kind has firing
# coordinates inside the (epoch, it) range the suite actually visits.
test-chaos:
	REPRO_CHAOS_SEED=17 $(PYTHON) -m pytest -x -q

# Quick pass over every benchmark suite (ratios, 1-CPU-core scales)
bench-smoke:
	$(PYTHON) -m benchmarks.run

# Host-planner microbenchmark: legacy vs vectorized plan construction
# (writes BENCH_planning.json at the repo root)
bench-plan:
	$(PYTHON) -m benchmarks.planning

# Remote-feature cache sweep: hit rate + bytes/iter vs budget (0 → covering)
# (writes BENCH_cache.json at the repo root)
bench-cache:
	$(PYTHON) -m benchmarks.cache

# Async-pipeline A/B smoke: measured steady wall + host-overhead gap,
# legacy loop vs fused/non-blocking/ping-pong-uploaded pipeline, plus the
# emulated 8-shard ≤½-wall gate case (writes BENCH_pipeline.json; the full
# end_to_end suite in `bench-smoke` emits the same cases into
# BENCH_end_to_end.json alongside the comm-model decomposition)
bench-pipeline:
	$(PYTHON) -m benchmarks.end_to_end --measured-only

# Tiered FeatureStore sweep: steady iter time + per-tier bytes vs
# host-budget fraction on a spilled graph 4x the host budget
# (writes BENCH_features.json at the repo root)
bench-features:
	$(PYTHON) -m benchmarks.features

# Resilience A/B: always-on policy plumbing overhead vs policy-off, and
# recovery under the headline recoverable FaultPlan on the streamed stack
# (bit-parity + ≤1.15x steady overhead; writes BENCH_resilience.json)
bench-resilience:
	$(PYTHON) -m benchmarks.resilience

# Observability A/B: tracing-on vs tracing-off on the pipelined + cached
# + streamed stack (bit-parity, ≤1.05x steady overhead, span/track
# coverage of the exported timeline; writes BENCH_obs.json + the Perfetto
# trace at benchmarks/results/obs_trace.json)
bench-obs:
	$(PYTHON) -m benchmarks.obs

# Online-inference suite: dynamic micro-batcher vs batch-size-1 at
# saturation (≥2x gate), offered-QPS latency sweep (p50/p99), served ==
# offline bit-parity, zero retraces after warmup
# (writes BENCH_serve.json at the repo root)
bench-serve:
	$(PYTHON) -m benchmarks.serve

# Elastic membership A/B: peer death mid-epoch → rejoin bit-parity with
# detection/rebuild/resume phase walls, plus elastic shrink to P-1 vs a
# fresh P-1 baseline (loss tolerance, zero steady-state retraces after
# recovery; writes BENCH_membership.json)
bench-membership:
	$(PYTHON) -m benchmarks.membership

# Checkpoint → precomputed embeddings → zipf request stream through the
# tiered GNNServer; prints p50/p99 latency and the tier breakdown
serve-demo:
	$(PYTHON) examples/serve_gnn.py

# 2-epoch pipelined + cached quickstart with span tracing on; writes a
# Perfetto/chrome://tracing-loadable timeline under benchmarks/results/
# (kept out of the checkout root — the results dir is gitignored)
trace-demo:
	@mkdir -p benchmarks/results
	$(PYTHON) examples/quickstart.py --trace benchmarks/results/trace_demo.json

# 3-epoch compile-once smoke train (prints first vs steady epoch times)
train-smoke:
	$(PYTHON) examples/train_hopgnn.py --preset smoke
