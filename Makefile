# Convenience wrappers — all targets set PYTHONPATH=src so `make test`
# works from a clean checkout with no install step.
PYTHON ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-all bench-smoke bench-plan bench-cache train-smoke

# Fast lane (tier-1): everything except @pytest.mark.slow (pyproject default)
test:
	$(PYTHON) -m pytest -x -q

# Full suite including the slow multi-device integration tests
test-all:
	$(PYTHON) -m pytest -q -m ""

# Quick pass over every benchmark suite (ratios, 1-CPU-core scales)
bench-smoke:
	$(PYTHON) -m benchmarks.run

# Host-planner microbenchmark: legacy vs vectorized plan construction
# (writes BENCH_planning.json at the repo root)
bench-plan:
	$(PYTHON) -m benchmarks.planning

# Remote-feature cache sweep: hit rate + bytes/iter vs budget (0 → covering)
# (writes BENCH_cache.json at the repo root)
bench-cache:
	$(PYTHON) -m benchmarks.cache

# 3-epoch compile-once smoke train (prints first vs steady epoch times)
train-smoke:
	$(PYTHON) examples/train_hopgnn.py --preset smoke
