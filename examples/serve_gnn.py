"""Online GNN serving quickstart: checkpoint → tiers → latency.

Trains the quickstart graph for a couple of epochs with iteration-boundary
checkpoints, precomputes the embedding table from the checkpointed params
(the cold-vertex tier), then serves a zipf-skewed synthetic request stream
through :class:`repro.serve.GNNServer` in ``auto`` mode — hot vertices get
fresh computes against the request-frequency feature cache, cold vertices
are answered from the precomputed table — and prints p50/p99 latency plus
the tier breakdown. Served logits are bit-identical to the offline eval
forward, and nothing recompiles after warmup (printed as proof).

    PYTHONPATH=src python examples/serve_gnn.py
    PYTHONPATH=src python examples/serve_gnn.py --requests 500 --qps 200
"""
import argparse
import tempfile
import time

import numpy as np

from repro.features import FeatureStore
from repro.graph import make_dataset
from repro.graph.partition import community_partition, shard_features
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.serve import GNNServer, precompute_embeddings
from repro.train import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=300)
ap.add_argument("--qps", type=float, default=150.0,
                help="offered request rate (open loop)")
ap.add_argument("--zipf", type=float, default=1.1,
                help="request skew exponent (higher = hotter head)")
args = ap.parse_args()

N_SHARDS = 4

# 1. train briefly, checkpointing — the server only ever sees the artifact
ds = make_dataset("products", scale=0.02, seed=0)
part = community_partition(ds.communities, N_SHARDS)
table, owner, local_idx = shard_features(ds.features, part, N_SHARDS)
store = FeatureStore.from_array(table, owner=owner, local_idx=local_idx)
cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                feature_dim=ds.feature_dim, num_classes=ds.num_classes,
                fanout=10)
ckpt_dir = tempfile.mkdtemp(prefix="serve_gnn_")
tr = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
             local_idx=local_idx, table=store, cfg=cfg,
             optimizer=adam(5e-3), merging=False,
             train_vertices=ds.train_vertices(), ckpt_dir=ckpt_dir)
tr.fit(epochs=2, iters_per_epoch=8, batch_per_model=16)
acc = tr.evaluate(n_eval=256)
print(f"trained to step {tr.global_step}, eval acc {acc:.3f}, "
      f"checkpoints in {ckpt_dir}")

# 2. precompute the cold-vertex tier from the checkpointed params
precompute_embeddings(ds.graph, store, tr.params, cfg, ckpt_dir=ckpt_dir,
                      params_step=tr.global_step)
print(f"precomputed {ds.num_vertices} embedding rows "
      f"(stamped params_step={tr.global_step})")

# 3. serve a zipf-skewed stream in auto mode (hot → fresh, cold → table)
srv = GNNServer(graph=ds.graph, params=tr.params, cfg=cfg, store=store,
                ckpt_dir=ckpt_dir, params_step=tr.global_step, mode="auto",
                cache_budget_bytes=1 << 20, max_batch=32)
w = srv.warmup()
print(f"warmup compiled {w['traces']} programs for rungs {w['rungs']}")

rng = np.random.default_rng(0)
ranks = np.arange(1, ds.num_vertices + 1, dtype=np.float64)
p = ranks ** -args.zipf
vertices = rng.permutation(ds.num_vertices)[
    rng.choice(ds.num_vertices, args.requests, p=p / p.sum())]

srv.start()
gap = 1.0 / args.qps
tickets, t_next = [], time.perf_counter()
for v in vertices:
    now = time.perf_counter()
    if now < t_next:
        time.sleep(t_next - now)
    tickets.append(srv.submit(int(v)))
    t_next += gap
for t in tickets:
    t.wait(120.0)
srv.stop()

lat = np.array([1e3 * t.latency_s() for t in tickets])
span = tickets[-1].t_done - tickets[0].t_submit
st = srv.stats()
print(f"\nserved {len(tickets)} requests at "
      f"{len(tickets) / span:.0f} qps (offered {args.qps:.0f})")
print(f"latency p50 {np.percentile(lat, 50):.2f} ms, "
      f"p99 {np.percentile(lat, 99):.2f} ms")
print(f"tiers: {st['fresh_requests']} fresh "
      f"({st['fresh_batches']} micro-batches, "
      f"{st['cache_hit_rows']} cached feature rows hit), "
      f"{st['precomputed_hits']} precomputed")
print(f"retraces since warmup: {st['retraces_since_warmup']} (must be 0)")
