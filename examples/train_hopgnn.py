"""End-to-end HopGNN training driver (deliverable b).

Full loop: synthetic dataset → METIS-style partition → per-epoch planning
(redistribution + pre-gathering + adaptive merging) → device iteration →
AdamW → eval + iteration-level checkpointing.

Presets:
  --preset smoke   ~1 min on 1 CPU core (default)
  --preset 100m    ~100M-parameter GraphSAGE (dim 600, hidden 4096) for a
                   few hundred steps — the production-scale invocation
                   (expect hours on CPU; sized for a real accelerator).

    PYTHONPATH=src python examples/train_hopgnn.py --preset smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.core import MergingController, plan_iteration, run_iteration
from repro.core.micrograph import hopgnn_assignment
from repro.graph import make_dataset
from repro.graph.partition import community_partition, shard_features
from repro.graph.sampler import sample_tree_block
from repro.models.gnn import (GNNConfig, gnn_forward, init_gnn,
                              model_param_bytes)
from repro.optim import adamw, cosine_schedule

PRESETS = {
    "smoke": dict(scale=0.03, hidden=64, fanout=4, layers=2, batch=16,
                  epochs=3, iters=8, dim=None),
    "100m": dict(scale=0.3, hidden=4096, fanout=10, layers=3, batch=256,
                 epochs=10, iters=30, dim=600),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=PRESETS)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--strategy", default="hopgnn",
                    choices=["hopgnn", "model_centric", "lo"])
    ap.add_argument("--ckpt-dir", default="/tmp/hopgnn_ckpt")
    args = ap.parse_args()
    P = PRESETS[args.preset]

    ds = make_dataset("products", scale=P["scale"], seed=0,
                      feat_dim=P["dim"])
    part = community_partition(ds.communities, args.shards)
    table, owner, local_idx = shard_features(ds.features, part, args.shards)
    cfg = GNNConfig(model="sage", num_layers=P["layers"],
                    hidden_dim=P["hidden"], feature_dim=ds.feature_dim,
                    num_classes=ds.num_classes, fanout=P["fanout"])
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    print(f"dataset: {ds.num_vertices} vertices; model: "
          f"{model_param_bytes(params) / 1e6:.1f} MB params "
          f"({model_param_bytes(params) / 4 / 1e6:.1f}M)")

    opt = adamw(cosine_schedule(3e-3, warmup=10,
                                total=P["epochs"] * P["iters"]),
                weight_decay=1e-4, grad_clip=1.0)
    state = opt.init(params)
    rng = np.random.default_rng(0)
    tv = ds.train_vertices()
    ctl = None

    step = 0
    for epoch in range(P["epochs"]):
        t0 = time.perf_counter()
        ep_loss, remote = 0.0, 0
        for it in range(P["iters"]):
            roots = [rng.choice(tv, P["batch"] // args.shards,
                                replace=False)
                     for _ in range(args.shards)]
            assignment = None
            if args.strategy == "hopgnn":
                base = hopgnn_assignment(
                    [np.asarray(r, np.int64) for r in roots], part)
                if ctl is None:
                    ctl = MergingController(base=base)
                # merging pattern follows the controller's step count
                a = ctl.assignment_for_epoch()
                assignment = base if a.num_steps == base.num_steps else None
            plan = plan_iteration(
                ds.graph, ds.labels, part, owner, local_idx,
                table.shape[1], roots, num_layers=cfg.num_layers,
                fanout=cfg.fanout, strategy=args.strategy,
                assignment=assignment, sample_seed=epoch * 10_000 + it)
            grads, loss = run_iteration(params, table, plan, cfg)
            params, state = opt.update(grads, state, params)
            ep_loss += float(loss)
            remote += plan.remote_rows_exact
            step += 1
        dt = time.perf_counter() - t0
        if ctl is not None:
            ctl.record_epoch_time(dt)
        acc = evaluate(ds, cfg, params)
        print(f"epoch {epoch}: loss {ep_loss / P['iters']:.4f} "
              f"acc {100 * acc:.1f}% remote_rows {remote} "
              f"({dt:.1f}s)")
        save_checkpoint(args.ckpt_dir, step, params,
                        extra={"epoch": epoch, "acc": acc})
    print(f"done; checkpoints in {args.ckpt_dir}")


def evaluate(ds, cfg, params, n_eval=512, seed=123) -> float:
    rng = np.random.default_rng(seed)
    nodes = rng.choice(ds.num_vertices, min(n_eval, ds.num_vertices),
                       replace=False)
    blk = sample_tree_block(ds.graph, nodes, cfg.num_layers, cfg.fanout,
                            seed=999)
    feats = [jnp.asarray(ds.features[ids]) for ids in blk.hops]
    logits = gnn_forward(params, cfg, feats)
    return float((jnp.argmax(logits, -1) ==
                  jnp.asarray(ds.labels[nodes])).mean())


if __name__ == "__main__":
    main()
