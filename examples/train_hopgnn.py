"""End-to-end LeapGNN training driver (deliverable b).

Full loop via the repro.train Trainer: synthetic dataset → METIS-style
partition → compile-once planning (shape budget + plan prefetch +
redistribution + pre-gathering + adaptive merging) → device iteration →
AdamW → eval + iteration-level checkpointing.

The Trainer plans every iteration under one quantized shape budget, so the
jitted iteration traces once per merge pattern instead of once per step:
epoch 0 pays compilation, epochs ≥1 run at steady-state device speed (both
times are printed).

Presets:
  --preset smoke   ~1 min on 1 CPU core (default)
  --preset 100m    ~100M-parameter GraphSAGE (dim 600, hidden 4096) for a
                   few hundred steps — the production-scale invocation
                   (expect hours on CPU; sized for a real accelerator).

    PYTHONPATH=src python examples/train_hopgnn.py --preset smoke
"""
import argparse

import jax

from repro.core import distributed as engine
from repro.graph import make_dataset
from repro.graph.partition import community_partition, shard_features
from repro.models.gnn import GNNConfig, init_gnn, model_param_bytes
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer

PRESETS = {
    "smoke": dict(scale=0.03, hidden=64, fanout=4, layers=2, batch=16,
                  epochs=3, iters=8, dim=None),
    "100m": dict(scale=0.3, hidden=4096, fanout=10, layers=3, batch=256,
                 epochs=10, iters=30, dim=600),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=PRESETS)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--strategy", default="hopgnn",
                    choices=["hopgnn", "model_centric", "lo"])
    ap.add_argument("--ckpt-dir", default="/tmp/hopgnn_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="per-iteration blocking loop (pre-PR5 behavior) "
                         "instead of the async fused pipeline")
    ap.add_argument("--stack", type=int, default=1,
                    help="K-stacked scan dispatch (amortizes dispatch "
                         "overhead when device iterations are tiny)")
    args = ap.parse_args()
    P = PRESETS[args.preset]

    ds = make_dataset("products", scale=P["scale"], seed=0,
                      feat_dim=P["dim"])
    part = community_partition(ds.communities, args.shards)
    table, owner, local_idx = shard_features(ds.features, part, args.shards)
    cfg = GNNConfig(model="sage", num_layers=P["layers"],
                    hidden_dim=P["hidden"], feature_dim=ds.feature_dim,
                    num_classes=ds.num_classes, fanout=P["fanout"])
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    print(f"dataset: {ds.num_vertices} vertices; model: "
          f"{model_param_bytes(params) / 1e6:.1f} MB params "
          f"({model_param_bytes(params) / 4 / 1e6:.1f}M)")

    total = P["epochs"] * P["iters"]
    opt = adamw(cosine_schedule(3e-3, warmup=10, total=total),
                weight_decay=1e-4, grad_clip=1.0,
                key=("cos", 3e-3, 10, total))   # value identity for the
    #             engine's fused-step compile cache (schedule isn't hashable)
    trainer = Trainer(
        graph=ds.graph, labels=ds.labels, part=part, owner=owner,
        local_idx=local_idx, table=table, cfg=cfg, optimizer=opt,
        params=params, strategy=args.strategy,
        train_vertices=ds.train_vertices(), ckpt_dir=args.ckpt_dir,
        pipeline=not args.no_pipeline, pipeline_stack=args.stack)

    tc0 = engine.trace_count()
    stats = trainer.fit(epochs=P["epochs"], iters_per_epoch=P["iters"],
                        batch_per_model=P["batch"] // args.shards,
                        eval_every=1, resume=args.resume, log=print)
    if not stats:
        print("nothing to do: checkpoint already covers every epoch "
              f"(step {trainer.global_step})")
        return
    first, rest = stats[0], stats[1:]
    if rest:
        print(f"compile-once: epoch 0 {first.time_s:.2f}s "
              f"(incl. compile) vs epochs>=1 mean "
              f"{sum(s.time_s for s in rest) / len(rest):.2f}s; "
              f"{engine.trace_count() - tc0} traces total, "
              f"budget {trainer.budget.signature()} "
              f"({trainer.budget.rebuckets} rebuckets)")
        if first.pipelined:
            print(f"pipeline: steady "
                  f"{1000 * rest[-1].steady_time_s / P['iters']:.1f} ms/iter "
                  f"(synced window), dispatch "
                  f"{1000 * rest[-1].dispatch_s / P['iters']:.1f} ms/iter, "
                  f"{trainer._uploader.uploads} committed uploads, "
                  f"{trainer._uploader.shape_changes} shape changes")
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
