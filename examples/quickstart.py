"""Quickstart: LeapGNN (the paper's system; titled "HopGNN") in ~70 lines.

Builds a synthetic community graph, partitions it METIS-style, plans one
feature-centric (micrograph) training iteration under a compile-once shape
budget, and shows the paper's three headline quantities next to the
model-centric baseline:

  * remote feature rows (the communication bottleneck, Fig. 4)
  * miss rate (Fig. 14)
  * gradient parity (Table 3 — same batch => same gradient)

then runs two epochs through the repro.train Trainer (the compile-once
loop used by the full driver), and finally the same training with the
repro.cache remote-feature cache on: the deterministic epoch prefetcher
precomputes next-epoch hot sets, so steady epochs serve their remote rows
from the device-resident cache (identical losses — cached rows are exact).

    PYTHONPATH=src python examples/quickstart.py

Pass ``--host-budget-bytes`` to finish with an out-of-core demo: the
features are spilled to mmap ``.npy`` shard files and trained through a
tiered ``repro.features.FeatureStore`` (host hot tier capped at the given
budget, disk below it) — losses stay bit-identical to the in-RAM run:

    PYTHONPATH=src python examples/quickstart.py --host-budget-bytes 200000

Pass ``--trace out.json`` to record the Trainer sections with repro.obs
span tracing and export a Perfetto-loadable timeline (open the file at
https://ui.perfetto.dev or chrome://tracing) — one lane per thread: the
main dispatch loop, the plan-prefetch thread, the uploader commits, and
the cache/readahead worker. Tracing is bit-neutral: the printed losses
are identical with and without it.
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.core import run_iteration
from repro.features import FeatureStore
from repro.graph import make_dataset
from repro.graph.partition import community_partition, shard_features
from repro.models.gnn import GNNConfig, init_gnn
from repro.obs import trace as obs_trace
from repro.optim import adam
from repro.train import ShapeBudget, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--host-budget-bytes", type=int, default=0,
                help="if > 0, run the out-of-core demo: spill features to "
                     "disk and cap the host hot tier at this many bytes")
ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                help="record repro.obs spans across the Trainer runs and "
                     "export a Chrome-trace/Perfetto timeline here")
args = ap.parse_args()

if args.trace:
    obs_trace.enable()

N_SHARDS = 4

# 1. data: synthetic Products analogue + METIS-style partition
ds = make_dataset("products", scale=0.05, seed=0)
part = community_partition(ds.communities, N_SHARDS)
table, owner, local_idx = shard_features(ds.features, part, N_SHARDS)
print(f"graph: {ds.num_vertices} vertices, {ds.graph.num_edges} edges, "
      f"features {ds.features.shape}")

# 2. one mini-batch per model replica
rng = np.random.default_rng(0)
tv = ds.train_vertices()
roots = [rng.choice(tv, 32, replace=False) for _ in range(N_SHARDS)]

# 3. plan the same iteration under both paradigms through one shared shape
#    budget (stateless sampling makes the comparison exact; the budget
#    quantizes device shapes so repeated plans reuse one compiled program)
budget = ShapeBudget()
kw = dict(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
          local_idx=local_idx, local_rows=table.shape[1],
          roots_per_model=roots, num_layers=2, fanout=10, sample_seed=42)
plan_mc = budget.plan(strategy="model_centric", **kw)
plan_hop = budget.plan(strategy="hopgnn", pregather=True, **kw)

print(f"\nmodel-centric: {plan_mc.remote_rows_exact:6d} remote rows, "
      f"miss {100 * plan_mc.miss_rate():.1f}%")
print(f"hopgnn:        {plan_hop.remote_rows_exact:6d} remote rows, "
      f"miss {100 * plan_hop.miss_rate():.1f}%, "
      f"{plan_hop.num_steps} time steps "
      f"(budget batch_pad={budget.batch_pad}, r_max={budget.r_max})")

# 4. run both; gradients must match (accuracy fidelity)
cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=64,
                feature_dim=ds.feature_dim, num_classes=ds.num_classes,
                fanout=10)
params = init_gnn(jax.random.PRNGKey(0), cfg)
g_mc, loss_mc = run_iteration(params, table, plan_mc, cfg)
g_hop, loss_hop = run_iteration(params, table, plan_hop, cfg)
dmax = max(float(abs(a - b).max())
           for a, b in zip(jax.tree.leaves(g_mc), jax.tree.leaves(g_hop)))
print(f"\nloss: model-centric {float(loss_mc):.4f} vs "
      f"hopgnn {float(loss_hop):.4f}")
print(f"max gradient difference: {dmax:.2e}  (accuracy fidelity, Table 3)")

# 5. the compile-once loop: two epochs through the Trainer
trainer = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
                  local_idx=local_idx, table=table, cfg=cfg,
                  optimizer=adam(5e-3), params=params,
                  train_vertices=tv, merging=False)
stats = trainer.fit(epochs=2, iters_per_epoch=4, batch_per_model=8)
print(f"\ntrainer: epoch0 {stats[0].time_s:.2f}s "
      f"({stats[0].traces} jit traces) -> "
      f"epoch1 {stats[1].time_s:.2f}s ({stats[1].traces} traces), "
      f"loss {stats[0].loss:.3f} -> {stats[1].loss:.3f}")

# 6. the same run with the remote-feature cache (repro.cache): an LFU fed
#    by the deterministic epoch prefetcher — steady-epoch remote rows come
#    from the device-resident cache, losses stay bit-identical
cached = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
                 local_idx=local_idx, table=table, cfg=cfg,
                 optimizer=adam(5e-3), params=params,
                 train_vertices=tv, merging=False,
                 cache_policy="lfu",
                 cache_budget_bytes=4096 * ds.feature_dim * 4)
cstats = cached.fit(epochs=2, iters_per_epoch=4, batch_per_model=8)
saved = sum(s.cache_bytes_saved for s in cstats)
print(f"cache:   epoch1 hit rate {100 * cstats[1].cache_hit_rate:.1f}% "
      f"({cstats[1].cache_hit_rows} rows from cache, "
      f"{cstats[1].remote_rows} shipped), {saved / 1e6:.2f} MB fabric "
      f"traffic saved, refresh {cstats[1].cache_refresh_s * 1e3:.1f} ms")
print(f"         losses identical to cache-off: "
      f"{[s.loss for s in cstats] == [s.loss for s in stats]}")

# 7. (--host-budget-bytes) out-of-core: spill the feature table to mmap
#    .npy shard files, cap the host hot tier, and train through the tiered
#    FeatureStore — the epoch prefetcher's exact next-epoch forecast
#    promotes disk rows into the hot tier at epoch boundaries, and losses
#    stay bit-identical to the in-RAM run above
if args.host_budget_bytes > 0:
    with tempfile.TemporaryDirectory() as td:
        store = FeatureStore.build(ds.features, part, N_SHARDS,
                                   directory=td,
                                   host_budget_bytes=args.host_budget_bytes)
        ooc = Trainer(graph=ds.graph, labels=ds.labels, part=part,
                      owner=owner, local_idx=local_idx, table=store,
                      cfg=cfg, optimizer=adam(5e-3), params=params,
                      train_vertices=tv, merging=False)
        ostats = ooc.fit(epochs=2, iters_per_epoch=4, batch_per_model=8)
        print(f"\nout-of-core: backing {store.backing_nbytes() / 1e6:.2f} MB "
              f"on disk, hot tier {store.hot_nbytes() / 1e6:.2f} MB "
              f"({store.hot_rows} rows/shard)")
        print(f"             epoch1: {ostats[1].tier1_rows} hot-tier rows, "
              f"{ostats[1].tier2_rows} disk rows, "
              f"readahead {ostats[1].readahead_s * 1e3:.1f} ms")
        print(f"             losses identical to in-RAM: "
              f"{[s.loss for s in ostats] == [s.loss for s in stats]}")

# 8. (--trace) export the recorded span timeline: one Perfetto lane per
#    thread (main dispatch / prefetch / uploader / cache+readahead), plus
#    the run manifest (git sha, jax version, platform) as trace metadata
if args.trace:
    from repro.obs.export import export_chrome_trace, run_manifest

    obs_trace.disable()
    n_spans = len(obs_trace.records())
    out = export_chrome_trace(args.trace, manifest=run_manifest(seed=0))
    print(f"\ntrace: {n_spans} spans -> {out} "
          f"(open in https://ui.perfetto.dev or chrome://tracing)")
