"""Communication-strategy analysis (the paper's Figs 5/7 as a tool).

Given a dataset/partition/model, print a per-strategy communication
breakdown and the α ratio, over both the paper's 10 GbE fabric and TPU ICI.

    PYTHONPATH=src python examples/comm_analysis.py --dataset uk --model gat
"""
import argparse

import numpy as np

from repro.cache import CacheStore, DegreePolicy
from repro.core import plan_iteration
from repro.core.comm_model import (FABRICS, ModelSpec, alpha_ratio,
                                   alpha_ratio_cached, hopgnn_bytes,
                                   hopgnn_bytes_cached, lo_bytes,
                                   model_centric_bytes, naive_fc_bytes,
                                   p3_bytes)
from repro.graph import make_dataset
from repro.graph.partition import community_partition, shard_features
from repro.graph.sampler import micrograph_split, sample_tree_block
from repro.models.gnn import GNNConfig, init_gnn, model_param_bytes
import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="products")
    ap.add_argument("--model", default="sage")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--fanout", type=int, default=10)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--cache-rows", type=int, default=1024,
                    help="per-shard remote-feature cache budget "
                         "(repro.cache, degree policy; 0 disables)")
    args = ap.parse_args()

    ds = make_dataset(args.dataset, scale=args.scale, seed=0)
    part = community_partition(ds.communities, args.shards)
    table, owner, local_idx = shard_features(ds.features, part, args.shards)
    cfg = GNNConfig(model=args.model, num_layers=args.layers,
                    hidden_dim=128, feature_dim=ds.feature_dim,
                    num_classes=ds.num_classes, fanout=args.fanout)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    spec = ModelSpec(feature_dim=cfg.feature_dim, hidden_dim=cfg.hidden_dim,
                     num_layers=cfg.num_layers,
                     param_bytes=model_param_bytes(params))

    rng = np.random.default_rng(0)
    tv = ds.train_vertices()
    roots = [rng.choice(tv, args.batch // args.shards, replace=False)
             for _ in range(args.shards)]
    micros, shard_of = [], []
    for s, r in enumerate(roots):
        blk = sample_tree_block(ds.graph, r, args.layers, args.fanout,
                                seed=7)
        micros.extend(micrograph_split(blk))
        shard_of.extend([s] * len(r))

    plan = plan_iteration(ds.graph, ds.labels, part, owner, local_idx,
                          table.shape[1], roots, num_layers=args.layers,
                          fanout=args.fanout, strategy="hopgnn",
                          pregather=True, sample_seed=7)

    rows = {
        "model-centric (DGL)": model_centric_bytes(
            micros, owner, shard_of, spec, args.shards),
        "naive feature-centric": naive_fc_bytes(
            micros, owner, spec, args.shards),
        "P3": p3_bytes(micros, owner, shard_of, spec, args.shards),
        "LO (biased)": lo_bytes(spec, args.shards),
        "HopGNN (paper)": hopgnn_bytes(
            plan.remote_rows_exact, plan.num_steps, spec, args.shards,
            replicated_params=False),
        "HopGNN (SPMD)": hopgnn_bytes(
            plan.remote_rows_exact, plan.num_steps, spec, args.shards,
            replicated_params=True),
    }
    if args.cache_rows > 0:
        # degree-policy resident cache: re-plan the same iteration against
        # it and report the cache-adjusted bytes (misses + amortized refill)
        pol = DegreePolicy(ds.graph, owner)
        store = CacheStore(args.shards, ds.feature_dim,
                           c_max=args.cache_rows)
        ids = [pol.select(s, args.cache_rows) for s in range(args.shards)]
        store.install(ids, [table[owner[i], local_idx[i]] for i in ids])
        plan_c = plan_iteration(ds.graph, ds.labels, part, owner, local_idx,
                                table.shape[1], roots,
                                num_layers=args.layers, fanout=args.fanout,
                                strategy="hopgnn", pregather=True,
                                sample_seed=7, cache_index=store.index)
        rows["HopGNN (SPMD+cache)"] = hopgnn_bytes_cached(
            plan_c.remote_rows_exact, plan_c.cache_hit_rows, plan_c.num_steps,
            spec, args.shards, replicated_params=True,
            refresh_rows=store.rows_installed(), iters_per_refresh=8)
    a = alpha_ratio(rows["model-centric (DGL)"]["remote_rows"],
                    spec.feature_dim, spec.param_bytes)
    print(f"{args.dataset} × {args.model}: α = {a:.1f} "
          f"(model {spec.param_bytes / 1e6:.2f} MB)")
    if args.cache_rows > 0:
        a_c = alpha_ratio_cached(plan_c.remote_rows_exact, spec.feature_dim,
                                 spec.param_bytes,
                                 refresh_rows=store.rows_installed(),
                                 iters_per_refresh=8)
        print(f"cache ({args.cache_rows} rows/shard, degree policy): "
              f"hit rate {100 * plan_c.cache_hit_rate():.1f}%, "
              f"cache-adjusted α = {a_c:.1f}")
    print(f"{'strategy':24s} {'total MB':>10s} {'feat':>8s} {'model':>8s} "
          f"{'interm':>8s} {'10GbE ms':>9s} {'ICI ms':>8s}")
    for name, d in rows.items():
        t1 = FABRICS["ethernet_10g"].seconds(d["total"] / args.shards)
        t2 = FABRICS["tpu_ici"].seconds(d["total"] / args.shards)
        print(f"{name:24s} {d['total'] / 1e6:10.2f} "
              f"{d['feature_bytes'] / 1e6:8.2f} "
              f"{d['model_bytes'] / 1e6:8.2f} "
              f"{d['intermediate_bytes'] / 1e6:8.2f} "
              f"{1e3 * t1:9.2f} {1e3 * t2:8.3f}")


if __name__ == "__main__":
    main()
