"""Batched LLM serving example over the assigned-architecture stack.

Prefill a batch of prompts through any ``--arch`` (reduced smoke variant on
CPU), then decode autoregressively with the per-family cache (KV ring
buffer / RWKV state / RG-LRU state).

    PYTHONPATH=src python examples/serve_llm.py --arch rwkv6-7b --gen 12
    PYTHONPATH=src python examples/serve_llm.py --arch h2o-danube-3-4b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.data import make_batch
from repro.launch.serve import generate
from repro.models.transformer import init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch))
    print(f"arch {cfg.name} ({cfg.family}): {cfg.num_layers}L "
          f"d={cfg.d_model} vocab={cfg.vocab_size}")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, args.batch, args.prompt_len, seed=0)

    t0 = time.perf_counter()
    out = generate(params, cfg, batch, args.gen,
                   max_seq=args.prompt_len + args.gen + 8,
                   greedy=not args.sample)
    dt = time.perf_counter() - t0
    toks = np.asarray(out)
    print(f"generated {toks.shape[0]}×{toks.shape[1]} tokens in {dt:.2f}s "
          f"({toks.size / dt:.1f} tok/s on CPU)")
    for i, row in enumerate(toks):
        print(f"  request {i}: {row[:16].tolist()}")


if __name__ == "__main__":
    main()
