"""Vectorized planner ≡ reference planner (the tentpole parity invariant).

The vectorized ``build_gather_plan`` (bitmap/sort dedup + SlotMap) must
produce byte-identical plans to the kept per-vertex reference
implementation: same req layout, same counts, same slot assignments, same
overflow behavior — on random graphs, through both dedup paths, and for
the hop translation. Property-tested via hypothesis when available, plus a
seeded sweep that always runs.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import repro.core.pregather as pg
from repro.core.pregather import (SlotMap, _reference_build_gather_plan,
                                  _reference_workspace_indices,
                                  build_gather_plan, workspace_indices)


def _random_partition(rng, n_shards, n_vertices):
    owner = rng.integers(0, n_shards, n_vertices).astype(np.int32)
    local_idx = np.zeros(n_vertices, np.int32)
    for s in range(n_shards):
        ids = np.nonzero(owner == s)[0]
        local_idx[ids] = np.arange(ids.size)
    local_rows = max(1, int(np.bincount(owner, minlength=n_shards).max()))
    return owner, local_idx, local_rows


def _assert_plans_equal(a, b):
    np.testing.assert_array_equal(a.req, b.req)
    np.testing.assert_array_equal(a.req_count, b.req_count)
    assert a.r_max == b.r_max
    np.testing.assert_array_equal(a.slot_map.starts, b.slot_map.starts)
    np.testing.assert_array_equal(a.slot_map.ids, b.slot_map.ids)
    np.testing.assert_array_equal(a.slot_map.slots, b.slot_map.slots)


def _check_case(n_shards, n_vertices, n_ids, seed, r_max=None):
    rng = np.random.default_rng(seed)
    owner, local_idx, local_rows = _random_partition(rng, n_shards,
                                                     n_vertices)
    needed = [rng.integers(0, n_vertices, n_ids) for _ in range(n_shards)]
    try:
        a = build_gather_plan(needed, owner, local_idx, n_shards,
                              local_rows, r_max)
        overflow_a = None
    except pg.PlanOverflow as e:
        a, overflow_a = None, e
    try:
        b = _reference_build_gather_plan(needed, owner, local_idx, n_shards,
                                         local_rows, r_max)
        overflow_b = None
    except pg.PlanOverflow as e:
        b, overflow_b = None, e
    if overflow_a or overflow_b:
        # both must overflow, identically
        assert overflow_a is not None and overflow_b is not None
        assert (overflow_a.field, overflow_a.needed, overflow_a.limit) == \
            (overflow_b.field, overflow_b.needed, overflow_b.limit)
        return
    _assert_plans_equal(a, b)
    # hop translation parity (exercises translation_row + lookup oracle)
    for s in range(n_shards):
        if needed[s].size == 0:
            continue
        hops = [needed[s][rng.integers(0, needed[s].size, 64)],
                needed[s][rng.integers(0, needed[s].size, 2048)]]
        wa = workspace_indices(hops, s, owner, local_idx, a)
        wb = _reference_workspace_indices(hops, s, owner, local_idx, b)
        for x, y in zip(wa, wb):
            np.testing.assert_array_equal(x, y)


@given(st.integers(2, 8), st.integers(8, 400), st.integers(0, 120),
       st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_planner_parity_property(n_shards, n_vertices, n_ids, seed):
    """Vectorized planner ≡ reference planner on random graphs."""
    _check_case(n_shards, n_vertices, n_ids, seed)


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("dense", [True, False])
def test_planner_parity_seeded(seed, dense, monkeypatch):
    """Always-on parity sweep through BOTH dedup paths (the bitmap path
    and the sort fallback the memory guard selects at scale)."""
    monkeypatch.setattr(pg, "_DENSE_DEDUP_MAX_CELLS",
                        (1 << 28) if dense else 0)
    rng = np.random.default_rng(1000 + seed)
    _check_case(int(rng.integers(2, 8)), int(rng.integers(8, 400)),
                int(rng.integers(0, 120)), seed)


@pytest.mark.parametrize("dense", [True, False])
def test_planner_parity_with_budgeted_r_max(dense, monkeypatch):
    monkeypatch.setattr(pg, "_DENSE_DEDUP_MAX_CELLS",
                        (1 << 28) if dense else 0)
    _check_case(4, 200, 80, seed=3, r_max=64)       # roomy bucket
    _check_case(4, 200, 80, seed=3, r_max=1)        # must overflow both


def test_slotmap_lookup_rejects_unknown_ids():
    rng = np.random.default_rng(0)
    owner, local_idx, local_rows = _random_partition(rng, 3, 50)
    needed = [rng.integers(0, 50, 20) for _ in range(3)]
    plan = build_gather_plan(needed, owner, local_idx, 3, local_rows)
    local_ids = np.nonzero(owner == 0)[0][:1]
    with pytest.raises(KeyError):
        plan.slot_map.lookup(0, local_ids)          # local id: never remote
    # a shard with an EMPTY remote set must also raise KeyError (not
    # IndexError from probing a zero-length segment)
    empty = build_gather_plan([np.zeros(0, np.int64)] * 3, owner,
                              local_idx, 3, local_rows)
    with pytest.raises(KeyError):
        empty.slot_map.lookup(0, local_ids)


def test_slotmap_translation_row_covers_local_and_remote():
    rng = np.random.default_rng(4)
    owner, local_idx, local_rows = _random_partition(rng, 4, 120)
    needed = [rng.integers(0, 120, 60) for _ in range(4)]
    plan = build_gather_plan(needed, owner, local_idx, 4, local_rows)
    for s in range(4):
        row = plan.slot_map.translation_row(s, owner, local_idx)
        assert row is not None and row.dtype == np.int32
        local = np.nonzero(owner == s)[0]
        np.testing.assert_array_equal(row[local], local_idx[local])
        remote = plan.slot_map.shard_ids(s)
        np.testing.assert_array_equal(row[remote],
                                      plan.slot_map.shard_slots(s))
        untouched = np.setdiff1d(np.arange(120),
                                 np.concatenate([local, remote]))
        assert np.all(row[untouched] == -1)


def test_slotmap_shard_segments_sorted():
    rng = np.random.default_rng(5)
    owner, local_idx, local_rows = _random_partition(rng, 5, 300)
    needed = [rng.integers(0, 300, 100) for _ in range(5)]
    plan = build_gather_plan(needed, owner, local_idx, 5, local_rows)
    sm: SlotMap = plan.slot_map
    for s in range(5):
        ids = sm.shard_ids(s)
        assert np.all(np.diff(ids) > 0)             # strictly sorted, unique
        # slot layout invariant: slot = local_rows + p*r_max + j
        slots = sm.shard_slots(s)
        assert np.all(slots >= local_rows)
        assert np.all(slots < local_rows + 5 * plan.r_max)
