"""End-to-end behaviour: multi-epoch HopGNN training on a synthetic graph
learns (loss falls, accuracy rises), merging controller engages, and the
accuracy-parity claim (Table 3) holds across strategies."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import MergingController, plan_iteration, run_iteration
from repro.graph import ldg_partition, make_dataset
from repro.graph.partition import shard_features
from repro.models.gnn import GNNConfig, gnn_forward, init_gnn
from repro.optim import adam


def _train(strategy, epochs=3, iters=6, seed=0):
    ds = make_dataset("arxiv", scale=0.02, seed=0)
    n = 4
    part = ldg_partition(ds.graph, n, passes=1)
    table, owner, local_idx = shard_features(ds.features, part, n)
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                    feature_dim=ds.feature_dim, num_classes=ds.num_classes,
                    fanout=4)
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    opt = adam(5e-3)
    state = opt.init(params)
    rng = np.random.default_rng(seed)
    tv = ds.train_vertices()
    losses = []
    for ep in range(epochs):
        ep_loss = 0.0
        for it in range(iters):
            roots = [rng.choice(tv, 16, replace=False) for _ in range(n)]
            plan = plan_iteration(ds.graph, ds.labels, part, owner,
                                  local_idx, table.shape[1], roots,
                                  num_layers=2, fanout=4, strategy=strategy,
                                  sample_seed=ep * 1000 + it)
            grads, loss = run_iteration(params, table, plan, cfg)
            params, state = opt.update(grads, state, params)
            ep_loss += float(loss)
        losses.append(ep_loss / iters)
    return ds, part, cfg, params, losses


def _eval_acc(ds, cfg, params, n_eval=256, seed=99):
    from repro.graph.sampler import sample_tree_block
    rng = np.random.default_rng(seed)
    nodes = rng.choice(ds.num_vertices, n_eval, replace=False)
    blk = sample_tree_block(ds.graph, nodes, cfg.num_layers, cfg.fanout,
                            seed=1234)
    feats = [jnp.asarray(ds.features[ids]) for ids in blk.hops]
    logits = gnn_forward(params, cfg, feats)
    return float((jnp.argmax(logits, -1) ==
                  jnp.asarray(ds.labels[nodes])).mean())


@pytest.mark.slow
def test_hopgnn_training_learns():
    ds, part, cfg, params, losses = _train("hopgnn", epochs=3)
    assert losses[-1] < losses[0] * 0.9, losses
    acc = _eval_acc(ds, cfg, params)
    assert acc > 0.3, acc       # community labels are very learnable


@pytest.mark.slow
def test_accuracy_parity_across_strategies():
    """Table 3: hopgnn ends at the same place as model-centric (identical
    batches, identical samples => near-identical final accuracy)."""
    ds, _, cfg, p_mc, _ = _train("model_centric", epochs=2, seed=0)
    _, _, _, p_hop, _ = _train("hopgnn", epochs=2, seed=0)
    acc_mc = _eval_acc(ds, cfg, p_mc)
    acc_hop = _eval_acc(ds, cfg, p_hop)
    assert abs(acc_mc - acc_hop) < 0.02, (acc_mc, acc_hop)


def test_merging_reduces_steps_over_epochs():
    """Fig. 17 behaviour: the controller walks steps down from N and
    freezes at the best count (simulated epoch times)."""
    ds = make_dataset("arxiv", scale=0.01, seed=0)
    n = 4
    part = ldg_partition(ds.graph, n, passes=1)
    rng = np.random.default_rng(0)
    tv = ds.train_vertices()
    roots = [rng.choice(tv, 8, replace=False) for _ in range(n)]
    from repro.core.micrograph import hopgnn_assignment
    base = hopgnn_assignment([np.asarray(r, np.int64) for r in roots], part)
    ctl = MergingController(base=base)
    simulated = {4: 10.0, 3: 8.0, 2: 7.0, 1: 9.0}
    for _ in range(6):
        amat = ctl.assignment_for_epoch()
        ctl.record_epoch_time(simulated[amat.num_steps])
        if ctl.frozen:
            break
    assert ctl.frozen
    assert ctl.assignment_for_epoch().num_steps == 2
    assert ctl.history[0] == 4
