"""repro.membership: peer-death detection, re-ownership, bit-parity resume.

The headline contracts:

* a persistent ``peer_death`` fault mid-epoch is detected through the comm
  deadline (CommTimeout carries the peer), confirmed by the bounded probe,
  and recovered without intervention — same-world-size **rejoin** resumes
  bit-identical to the fault-free run (losses AND parameters);
* **elastic shrink** (redistribute/adopt) re-owns the lost shard's
  vertices deterministically, rebuilds every world-shaped structure, and
  continues at P-1 within loss tolerance of a fresh P-1 baseline, with
  zero steady-state retraces after the recovery epoch;
* plans stamped under an old membership generation are refused at dispatch
  and upload boundaries (StaleGeneration), the same stale-refusal
  discipline the cache uses.

Satellites covered here: jittered backoff schedule (deterministic, never
longer than unjittered), checkpoint keep-last-K GC with crash-safe
deletion ordering (incl. SIGKILL mid-GC), and the serving loop's bounded
drain deadline (ServeShutdown instead of forever-pending tickets).
"""
import json
import subprocess
import sys
import textwrap
import threading
import time
import types
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import distributed as engine
from repro.membership import (MembershipView, PeerProbe, StaleGeneration,
                              peer_of, rebuild_world)
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.resilience import (CommTimeout, FaultPlan, FaultSpec,
                              PeerDeadError, ResiliencePolicy, RetryPolicy,
                              backoff_schedule, resilient_call)
from repro.train import Trainer

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(autouse=True)
def _clean_registry():
    """The dead-peer registry is process-global; a test that kills a peer
    and fails before recovery must not poison its neighbours."""
    yield
    for p in list(engine.dead_peers()):
        engine.revive_peer(p)


def _cfg(d):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=16,
                     feature_dim=d["ds"].feature_dim,
                     num_classes=d["ds"].num_classes, fanout=4)


def _trainer(d, cfg, **kw):
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"], table=d["table"], cfg=cfg, **kw)


def _losses(stats):
    return [s.loss for s in stats]


def _assert_params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _policy(mode="rejoin", retries=2):
    return ResiliencePolicy(
        retry=RetryPolicy(max_retries=retries, backoff_s=0.001,
                          deadline_s=5.0),
        membership_mode=mode, probe_backoff_s=0.0)


# ---------------------------------------------------------------------------
# MembershipView + generation discipline
# ---------------------------------------------------------------------------

def test_view_state_machine():
    v = MembershipView(4)
    assert v.world_size() == 4 and v.generation == 0
    v.mark_suspect(2, epoch=1)
    assert v.is_suspect(2) and v.is_alive(2)
    assert v.generation == 0          # suspicion never bumps the world
    v.clear_suspect(2)
    assert not v.is_suspect(2)
    g = v.confirm_dead(2, epoch=1)
    assert g == 1 and not v.is_alive(2) and v.world_size() == 3
    assert v.alive_shards() == [0, 1, 3]
    assert v.confirm_dead(2, epoch=1) == 1    # idempotent
    assert v.rejoin(2, epoch=1) == 2
    assert v.is_alive(2) and v.world_size() == 4
    v.confirm_dead(3, epoch=2)
    assert v.shrink(3, epoch=2) == 4
    assert v.num_shards == 3 and v.world_size() == 3
    kinds = [e[0] for e in v.events]
    assert kinds == ["suspect", "dead", "rejoin", "dead", "shrink"]


def test_stale_generation_refused():
    v = MembershipView(4)
    v.check_generation(-1, epoch=0, it=0)     # unstamped passes
    v.check_generation(0, epoch=0, it=0)      # current passes
    v.confirm_dead(1, epoch=0)
    with pytest.raises(StaleGeneration) as ei:
        v.check_generation(0, epoch=0, it=3)
    assert ei.value.have == 0 and ei.value.want == 1
    assert ei.value.site == "membership"


def test_shrink_one_shard_world_rejected():
    v = MembershipView(1)
    with pytest.raises(ValueError):
        v.shrink(0)


# ---------------------------------------------------------------------------
# Deterministic re-ownership (graph.partition.reassign_partition)
# ---------------------------------------------------------------------------

def test_reassign_redistribute_is_deterministic_and_balanced():
    from repro.graph.partition import reassign_partition
    rng = np.random.default_rng(0)
    part = rng.integers(0, 4, size=1000).astype(np.int32)
    a = reassign_partition(part, 1, mode="redistribute")
    b = reassign_partition(part, 1, mode="redistribute")
    np.testing.assert_array_equal(a, b)       # pure function of inputs
    assert a.min() >= 0 and a.max() <= 2      # compacted to 3 shards
    # survivors keep every vertex they already owned (modulo compaction)
    old = part.copy()
    old[old > 1] -= 1
    keep = part != 1
    np.testing.assert_array_equal(a[keep], old[keep])
    # the lost vertices spread across survivors within one of each other
    lost_counts = np.bincount(a[part == 1], minlength=3)
    assert lost_counts.max() - lost_counts.min() <= 1


def test_reassign_adopt_and_errors():
    from repro.graph.partition import reassign_partition
    rng = np.random.default_rng(1)
    part = rng.integers(0, 4, size=500).astype(np.int32)
    sizes = np.bincount(part, minlength=4)
    smallest = int(np.argmin(np.where(np.arange(4) == 2, np.iinfo(int).max,
                                      sizes)))
    a = reassign_partition(part, 2, mode="adopt")
    old = part.copy()
    old[old > 2] -= 1
    tgt = smallest if smallest < 2 else smallest - 1
    assert set(a[part == 2]) == {tgt}         # one adopter takes the shard
    with pytest.raises(ValueError):
        reassign_partition(part, 2, mode="nope")
    with pytest.raises(ValueError):
        reassign_partition(part, 2, mode="adopt", adopter=2)
    with pytest.raises(ValueError):
        reassign_partition(part, 7)


def test_rebuild_world_maps_are_consistent():
    rng = np.random.default_rng(2)
    part = rng.integers(0, 4, size=800).astype(np.int32)
    wr = rebuild_world(part, 3, 4, mode="redistribute")
    assert wr.num_shards == 3 and wr.dead == 3
    np.testing.assert_array_equal(wr.owner, wr.part.astype(wr.owner.dtype))
    # local_idx is a dense 0..size-1 numbering within each shard
    for s in range(3):
        rows = np.sort(wr.local_idx[wr.part == s])
        np.testing.assert_array_equal(rows, np.arange(rows.size))
    assert wr.moved_rows >= int((part == 3).sum())
    with pytest.raises(ValueError):
        rebuild_world(part, 3, 4, mode="rejoin")


# ---------------------------------------------------------------------------
# Detection: attribution, probe, timeout plumbing
# ---------------------------------------------------------------------------

def test_peer_of_walks_cause_chain():
    try:
        try:
            raise PeerDeadError("inner", peer=3)
        except PeerDeadError as inner:
            raise CommTimeout("outer") from inner
    except CommTimeout as e:
        assert peer_of(e) == 3
    assert peer_of(RuntimeError("no peer")) == -1
    assert peer_of(CommTimeout("stamped", peer=1)) == 1


def test_probe_confirms_death_and_clears_flap():
    engine.kill_peer(2)
    try:
        pr = PeerProbe(attempts=3, backoff_s=0.0).confirm(2)
        assert not pr.alive and pr.attempts == 3
    finally:
        engine.revive_peer(2)
    pr = PeerProbe(attempts=3, backoff_s=0.0).confirm(2)
    assert pr.alive and pr.attempts == 1      # first answer clears it


def test_dead_peer_timeout_carries_attribution():
    engine.kill_peer(1)
    plan = types.SimpleNamespace(epoch_it=(0, 0))
    try:
        with pytest.raises(CommTimeout) as ei:
            resilient_call(lambda: engine.comm_fault_point(plan),
                           policy=RetryPolicy(max_retries=1,
                                              backoff_s=0.0001),
                           epoch=0, it=0)
        assert ei.value.peer == 1
    finally:
        engine.revive_peer(1)
    # registry empty again: the same call now succeeds
    assert engine.comm_fault_point(plan) is None


# ---------------------------------------------------------------------------
# Backoff jitter (satellite: decorrelation)
# ---------------------------------------------------------------------------

def test_backoff_schedule_is_deterministic_and_bounded():
    pol = RetryPolicy(max_retries=4, backoff_s=0.01, backoff_mult=2.0,
                      jitter=0.5, seed=3)
    a = backoff_schedule(pol, epoch=1, it=2)
    b = backoff_schedule(pol, epoch=1, it=2)
    assert a == b and len(a) == 4             # pure function of coords
    plain = [0.01 * 2.0 ** k for k in range(4)]
    for got, base in zip(a, plain):
        assert base * 0.5 <= got <= base      # never longer than unjittered
    assert a != backoff_schedule(pol, epoch=1, it=3)   # decorrelated
    other = backoff_schedule(RetryPolicy(max_retries=4, backoff_s=0.01,
                                         backoff_mult=2.0, jitter=0.5,
                                         seed=4), epoch=1, it=2)
    assert a != other                          # per-shard seeds decorrelate
    nojit = RetryPolicy(max_retries=4, backoff_s=0.01, backoff_mult=2.0,
                        jitter=0.0)
    assert backoff_schedule(nojit, epoch=1, it=2) == plain


# ---------------------------------------------------------------------------
# FeatureStore re-ownership
# ---------------------------------------------------------------------------

def test_feature_store_reshard_serves_identical_rows(partitioned):
    from repro.features import FeatureStore
    d = partitioned
    store = FeatureStore.from_array(d["table"], owner=d["owner"],
                                    local_idx=d["local_idx"])
    wr = rebuild_world(d["part"], 1, d["parts"], mode="redistribute")
    st2 = store.reshard(wr.part, wr.num_shards)
    assert st2.num_shards == d["parts"] - 1
    ids = np.arange(d["part"].shape[0])
    np.testing.assert_array_equal(store.take_global(ids),
                                  st2.take_global(ids))


# ---------------------------------------------------------------------------
# End-to-end recovery (the tentpole gates)
# ---------------------------------------------------------------------------

def test_peer_death_rejoin_is_bit_identical(partitioned, tmp_path):
    """Persistent peer death mid-epoch: detected via the comm deadline,
    confirmed by the probe, recovered by rejoin + resume from the shared
    checkpoint — losses and parameters bit-identical to fault-free."""
    d = partitioned
    clean_tr = _trainer(d, _cfg(d), resilience=_policy())
    clean_stats = clean_tr.fit(epochs=3, iters_per_epoch=4,
                               batch_per_model=8)
    fp = FaultPlan([FaultSpec("peer_death", epoch=2, it=1, shard=1)])
    tr = _trainer(d, _cfg(d), resilience=_policy(),
                  ckpt_dir=str(tmp_path / "ck"))
    with fp.active():
        stats = tr.fit(epochs=3, iters_per_epoch=4, batch_per_model=8)
    assert fp.fired_count() == 1
    assert _losses(stats) == _losses(clean_stats)
    _assert_params_equal(tr, clean_tr)
    assert tr.membership_recoveries == 1
    assert tr.degradations_taken == ["membership_rejoin"]
    # death + rejoin = two world transitions
    assert tr.membership.generation == 2
    assert stats[2].epoch_attempts == 2
    assert stats[2].membership_recoveries == 1
    assert stats[2].membership_generation == 2
    assert engine.dead_peers() == frozenset()
    # epoch 1's checkpoint existed, so the resume came from shared storage
    assert stats[2].comm_timeouts >= 1        # detection went via deadline


def test_peer_death_rejoin_without_checkpoint(partitioned):
    """No ckpt_dir: the epoch-start snapshot is the restore point and the
    recovery is still bit-identical (snapshot == last checkpoint state)."""
    d = partitioned
    clean_tr = _trainer(d, _cfg(d), resilience=_policy())
    clean_stats = clean_tr.fit(epochs=2, iters_per_epoch=4,
                               batch_per_model=8)
    fp = FaultPlan([FaultSpec("peer_death", epoch=1, it=2, shard=2)])
    tr = _trainer(d, _cfg(d), resilience=_policy())
    with fp.active():
        stats = tr.fit(epochs=2, iters_per_epoch=4, batch_per_model=8)
    assert _losses(stats) == _losses(clean_stats)
    _assert_params_equal(tr, clean_tr)
    assert tr.degradations_taken == ["membership_rejoin"]


@pytest.mark.parametrize("mode", ["redistribute", "adopt"])
def test_peer_death_elastic_shrink(partitioned, small_dataset, mode):
    """Elastic shrink: the world compacts to P-1 mid-run, training
    continues within loss tolerance of a fresh P-1 baseline, and the
    steady state after the recovery epoch has zero retraces."""
    d = partitioned
    fp = FaultPlan([FaultSpec("peer_death", epoch=1, it=2, shard=1)])
    tr = _trainer(d, _cfg(d), resilience=_policy(mode=mode))
    with fp.active():
        stats = tr.fit(epochs=4, iters_per_epoch=4, batch_per_model=8)
    assert tr.num_shards == d["parts"] - 1
    assert tr.degradations_taken == [f"membership_{mode}"]
    assert tr.membership.generation == 2       # death + shrink
    assert engine.dead_peers() == frozenset()
    assert all(np.isfinite(s.loss) for s in stats)
    # training still converges at the new world size
    assert stats[-1].loss < stats[0].loss
    # zero steady-state retraces once the new world's shapes are traced
    assert stats[-1].traces == 0
    # loss tolerance vs a fresh same-world-size baseline
    from repro.graph import ldg_partition
    from repro.graph.partition import shard_features
    ds = small_dataset
    p3 = d["parts"] - 1
    part3 = ldg_partition(ds.graph, p3, passes=1)
    t3, o3, l3 = shard_features(ds.features, part3, p3)
    base = Trainer(graph=ds.graph, labels=ds.labels, part=part3, owner=o3,
                   local_idx=l3, table=t3, cfg=_cfg(d),
                   optimizer=adam(5e-3), merging=False,
                   train_vertices=ds.train_vertices(),
                   resilience=_policy())
    bstats = base.fit(epochs=4, iters_per_epoch=4, batch_per_model=8)
    assert abs(stats[-1].loss - bstats[-1].loss) <= \
        0.35 * max(abs(bstats[-1].loss), 1e-6)


def test_transient_flap_absorbed_with_zero_trace(partitioned):
    """A flapping peer (transient peer_death) is absorbed by the retry
    guard: bit-parity holds, no membership change, generation stays 0."""
    d = partitioned
    clean_tr = _trainer(d, _cfg(d), resilience=_policy())
    clean_stats = clean_tr.fit(epochs=2, iters_per_epoch=4,
                               batch_per_model=8)
    fp = FaultPlan([FaultSpec("peer_death", epoch=0, it=1, shard=3,
                              transient=True, drops=1, once=False)])
    tr = _trainer(d, _cfg(d), resilience=_policy())
    with fp.active():
        stats = tr.fit(epochs=2, iters_per_epoch=4, batch_per_model=8)
    assert _losses(stats) == _losses(clean_stats)
    _assert_params_equal(tr, clean_tr)
    assert stats[0].comm_retries >= 1
    assert tr.membership.generation == 0
    assert tr.membership_recoveries == 0


def test_probe_false_positive_clears_suspicion(partitioned):
    """A peer-attributed timeout whose peer answers the probe is a flap:
    suspicion cleared, no generation bump, ordinary comm accounting."""
    d = partitioned
    tr = _trainer(d, _cfg(d), resilience=_policy())
    rung = tr._recover(CommTimeout("ghost", peer=2, epoch=0, it=0), 0)
    assert rung is None
    assert not tr.membership.is_suspect(2)
    assert tr.membership.generation == 0
    assert tr.membership_recoveries == 0
    assert tr._site_failures.get("comm") == 1  # fell through to comm path


def test_stale_plan_refused_at_dispatch_and_upload(partitioned):
    d = partitioned
    tr = _trainer(d, _cfg(d), resilience=_policy())
    plan = tr.build_plan(0, 0, 8)
    assert plan.generation == 0
    tr.membership.confirm_dead(3, epoch=0)     # world moved on
    with pytest.raises(StaleGeneration):
        tr._dispatch([plan], 0, 0)
    from repro.train.pipeline import PlanUploader
    up = PlanUploader(view=tr.membership)
    with pytest.raises(StaleGeneration):
        up.commit(plan)
    assert up.uploads == 0                     # refused before staging


# ---------------------------------------------------------------------------
# Checkpoint GC (satellite: keep-last-K, crash-safe ordering)
# ---------------------------------------------------------------------------

def _tree(v=0.0):
    return {"w": np.full((4, 4), v, np.float32)}


def test_gc_keeps_last_k_and_pins_latest(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.checkpoint.store import gc_checkpoints, latest_step, \
        valid_steps
    for s in range(1, 6):
        save_checkpoint(tmp_path, s, _tree(s), keep=0)   # keep=0: no GC
    assert valid_steps(tmp_path) == [1, 2, 3, 4, 5]
    deleted = gc_checkpoints(tmp_path, keep=2)
    assert deleted == [1, 2, 3]
    assert valid_steps(tmp_path) == [4, 5]
    # latest pinned even when the keep window would drop it
    (tmp_path / "latest").write_text("4")
    save_checkpoint(tmp_path, 6, _tree(6), keep=0)
    (tmp_path / "latest").write_text("4")
    deleted = gc_checkpoints(tmp_path, keep=1)
    assert 4 not in deleted
    assert latest_step(tmp_path) == 4
    assert set(valid_steps(tmp_path)) == {4, 6}


def test_gc_sweeps_npz_orphans(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.checkpoint.store import gc_checkpoints, valid_steps
    for s in (1, 2, 3):
        save_checkpoint(tmp_path, s, _tree(s), keep=0)
    # simulate a crash that deleted the manifest but not the npz
    (tmp_path / "step-00000001.json").unlink()
    assert valid_steps(tmp_path) == [2, 3]     # orphan is invisible
    deleted = gc_checkpoints(tmp_path, keep=2)
    assert deleted == [1]                      # ...and swept next pass
    assert not (tmp_path / "step-00000001.npz").exists()


def test_save_checkpoint_prunes_via_gc(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.checkpoint.store import valid_steps
    for s in range(1, 7):
        save_checkpoint(tmp_path, s, _tree(s), keep=3)
    assert valid_steps(tmp_path) == [4, 5, 6]
    assert not list(tmp_path.glob("*.tmp"))


def _run_py(code: str, expect_signal=None) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=600)
    if expect_signal is not None:
        assert out.returncode == -expect_signal, out.stderr[-2000:]
        return {}
    assert out.returncode == 0, out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in:\n{out.stdout}\n{out.stderr}")


def test_sigkill_during_gc_leaves_recoverable_state(tmp_path):
    """SIGKILL between a checkpoint's manifest and npz deletions: the
    newest-durable checkpoint still loads, and the next sweep removes the
    stranded npz orphan."""
    ck = str(tmp_path / "ck")
    _run_py(f"""
    import os, signal
    import numpy as np
    from repro.checkpoint import save_checkpoint
    from repro.checkpoint.store import gc_checkpoints
    from repro.checkpoint import store as ckstore
    d = {ck!r}
    for s in range(1, 5):
        save_checkpoint(d, s, {{"w": np.full((4, 4), float(s),
                                            np.float32)}}, keep=0)
    ckstore._GC_FAULT_HOOK = \\
        lambda step: os.kill(os.getpid(), signal.SIGKILL)
    gc_checkpoints(d, keep=2)      # killed mid-delete of step 1
    """, expect_signal=9)
    res = _run_py(f"""
    import json
    import numpy as np
    from repro.checkpoint import load_checkpoint
    from repro.checkpoint.store import gc_checkpoints, valid_steps
    d = {ck!r}
    tree, step, _ = load_checkpoint(d, {{"w": np.zeros((4, 4),
                                                       np.float32)}})
    deleted = gc_checkpoints(d, keep=2)
    print("RESULT:" + json.dumps({{
        "step": step, "w": float(tree["w"][0, 0]),
        "valid": valid_steps(d), "deleted": deleted}}))
    """)
    assert res["step"] == 4 and res["w"] == 4.0
    assert res["valid"] == [3, 4]
    assert 1 in res["deleted"] or 2 in res["deleted"]   # orphan swept


# ---------------------------------------------------------------------------
# Serving drain deadline (satellite: ServeShutdown)
# ---------------------------------------------------------------------------

def test_serve_stop_fails_undrained_tickets():
    from repro.serve import BatchingLoop, ServeShutdown
    release = threading.Event()

    def wedge(tickets):
        release.wait(5.0)
        return [t.payload for t in tickets]

    loop = BatchingLoop(wedge, max_batch=1, name="drain-test",
                        drain_deadline_s=0.05)
    assert loop.drain_deadline_s == 0.05
    loop.start()
    tickets = [loop.submit(i) for i in range(4)]
    time.sleep(0.05)               # let the loop wedge on the first batch
    t0 = time.perf_counter()
    loop.stop()                    # deadline bounded, not 30s
    assert time.perf_counter() - t0 < 5.0
    release.set()
    failed = 0
    for t in tickets:
        try:
            t.wait(timeout=5.0)
        except ServeShutdown as e:
            failed += 1
            assert "undrained" in str(e)
    assert failed >= 1             # queued tickets answered, not pending
    assert loop.errors >= failed
    assert all(t.done() for t in tickets[:1] + tickets[-1:]) or failed == 4


def test_serve_stop_drains_when_queue_clears():
    from repro.serve import BatchingLoop
    loop = BatchingLoop(lambda ts: [t.payload * 2 for t in ts],
                        max_batch=8, drain_deadline_s=2.0,
                        name="drain-ok")
    loop.start()
    tickets = [loop.submit(i) for i in range(6)]
    loop.stop()
    assert [t.wait(timeout=1.0) for t in tickets] == [0, 2, 4, 6, 8, 10]
    assert loop.errors == 0
