"""repro.train: compile-once invariant (trace counting), shape-budget
gradient parity, merging-pattern application, and checkpoint/resume."""
import numpy as np
import jax
import pytest

from repro.core import distributed as engine
from repro.core import plan_iteration, run_iteration
from repro.models.gnn import GNNConfig, init_gnn
from repro.optim import adam
from repro.train import ShapeBudget, Trainer, next_bucket


def _cfg(d, model="sage"):
    return GNNConfig(model=model, num_layers=2, hidden_dim=16,
                     feature_dim=d["ds"].feature_dim,
                     num_classes=d["ds"].num_classes, fanout=4)


def _trainer(d, cfg, **kw):
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"], table=d["table"], cfg=cfg, **kw)


def _plan_kwargs(d, roots, **kw):
    out = dict(graph=d["ds"].graph, labels=d["ds"].labels, part=d["part"],
               owner=d["owner"], local_idx=d["local_idx"],
               local_rows=d["table"].shape[1], roots_per_model=roots,
               num_layers=2, fanout=4, strategy="hopgnn", sample_seed=7)
    out.update(kw)
    return out


# ---------------------------------------------------------------------------
# Shape budget
# ---------------------------------------------------------------------------

def test_next_bucket_quantization():
    assert [next_bucket(n) for n in (1, 2, 3, 4, 5, 9, 64, 65)] == \
        [1, 2, 4, 4, 8, 16, 64, 128]
    assert next_bucket(3, minimum=8) == 8


def test_budget_grow_is_explicit_and_counted():
    b = ShapeBudget(batch_pad=8, r_max=8)
    b.grow("batch_pad", 9)
    b.grow("r_max", 100)
    assert (b.batch_pad, b.r_max) == (16, 128)
    assert b.rebuckets == 2
    with pytest.raises(ValueError):
        b.grow("nope", 1)


def test_budget_learns_pow2_buckets(partitioned):
    d = partitioned
    rng = np.random.default_rng(0)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 12, replace=False) for _ in range(d["parts"])]
    budget = ShapeBudget()
    plan = budget.plan(**_plan_kwargs(d, roots))
    assert plan.batch_pad == budget.batch_pad
    assert plan.r_max == budget.r_max
    assert budget.batch_pad & (budget.batch_pad - 1) == 0   # power of two
    assert budget.r_max & (budget.r_max - 1) == 0


def test_budget_buckets_are_per_merge_pattern(partitioned):
    """Switching merge patterns must not force a global re-bucket: each
    num_steps keys its own bucket, and returning to a previously-seen
    pattern reuses its bucket with no new probe and identical shapes."""
    from repro.core.merging import merge_min_step
    from repro.core.micrograph import hopgnn_assignment
    d = partitioned
    rng = np.random.default_rng(0)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 12, replace=False) for _ in range(d["parts"])]
    base = hopgnn_assignment(roots, d["part"])
    merged = merge_min_step(base)
    budget = ShapeBudget()

    p_full = budget.plan(**_plan_kwargs(d, roots, assignment=base))
    assert budget.probes == 1 and set(budget.buckets) == {base.num_steps}
    p_merged = budget.plan(**_plan_kwargs(d, roots, assignment=merged))
    assert budget.probes == 2
    assert set(budget.buckets) == {base.num_steps, merged.num_steps}
    full_bucket = tuple(budget.buckets[base.num_steps])

    # back to the full rotation: prior bucket reused, no probe, no rebucket
    p_again = budget.plan(**_plan_kwargs(d, roots, assignment=base))
    assert budget.probes == 2 and budget.rebuckets == 0
    assert tuple(budget.buckets[base.num_steps]) == full_bucket
    assert (p_again.batch_pad, p_again.r_max) == \
        (p_full.batch_pad, p_full.r_max)
    # merging packs the same roots into fewer steps -> larger batch bucket
    assert p_merged.num_steps == p_full.num_steps - 1


def test_trainer_records_plan_time_stats(partitioned):
    d = partitioned
    tr = _trainer(d, _cfg(d))
    stats = tr.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    for st in stats:
        assert st.plans_built == 3
        assert st.plan_time_s > 0.0


# ---------------------------------------------------------------------------
# Compile-once invariant (the tentpole regression test)
# ---------------------------------------------------------------------------

def test_trace_once_per_shape_bucket(partitioned):
    """≥3 iterations with varying true batch (and hence remote-fetch)
    counts must trace the iteration function exactly once: one shape
    bucket ⇒ one jit trace."""
    engine.clear_compile_cache()
    d = partitioned
    cfg = _cfg(d)
    tv = d["ds"].train_vertices()
    sizes = [12, 7, 10, 9]        # first iteration carries the largest batch

    def root_fn(epoch, it):
        rng = np.random.default_rng(100 * epoch + it)
        return [rng.choice(tv, sizes[it], replace=False)
                for _ in range(d["parts"])]

    tr = _trainer(d, cfg, root_fn=root_fn, prefetch=False)
    t0 = engine.trace_count()
    tr.fit(epochs=1, iters_per_epoch=4)
    assert tr.budget.rebuckets == 0
    assert engine.trace_count() - t0 == 1, engine.trace_log()[-4:]


def test_no_new_traces_after_first_epoch(partitioned):
    """Acceptance: a multi-epoch run with an unchanged merge pattern does
    all its tracing in epoch 0; epochs ≥1 are compile-free and therefore
    much faster in the same process."""
    engine.clear_compile_cache()
    d = partitioned
    tr = _trainer(d, _cfg(d))
    stats = tr.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)
    assert stats[0].traces >= 1
    assert stats[1].traces == 0 and stats[2].traces == 0
    assert stats[1].time_s < stats[0].time_s
    assert stats[2].time_s < stats[0].time_s


def test_merging_pattern_reaches_device(partitioned):
    """The controller's merge pattern must change the *executed* plan (the
    seed loop silently re-planned the unmerged rotation), and each pattern
    change costs at most the traces of one new shape bucket."""
    engine.clear_compile_cache()
    d = partitioned
    tr = _trainer(d, _cfg(d), merging=True)
    stats = tr.fit(epochs=3, iters_per_epoch=4, batch_per_model=8)
    assert stats[0].num_steps == d["parts"]
    # epoch 0 must yield a compile-free sample for the controller to act on
    assert stats[0].compile_free
    # first record_epoch_time always proposes one merge (§5.3 examination)
    assert stats[1].num_steps == stats[0].num_steps - 1
    assert tr.controller is not None and len(tr.controller.history) >= 2


# ---------------------------------------------------------------------------
# Budgeted-plan gradient parity
# ---------------------------------------------------------------------------

def test_budgeted_plan_gradient_parity(partitioned):
    """Padding to the bucket (weight-0 roots, never-read request slots)
    must not change numerics: identical loss, gradients equal to float
    accumulation-order noise."""
    d = partitioned
    cfg = _cfg(d)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 11, replace=False) for _ in range(d["parts"])]

    exact = plan_iteration(**_plan_kwargs(d, roots))
    budgeted = ShapeBudget().plan(**_plan_kwargs(d, roots))
    assert budgeted.batch_pad > exact.batch_pad       # really padded
    assert budgeted.global_batch == exact.global_batch

    ge, le = run_iteration(params, d["table"], exact, cfg)
    gb, lb = run_iteration(params, d["table"], budgeted, cfg)
    assert float(le) == float(lb)                     # bit-identical loss
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=2e-8)


# ---------------------------------------------------------------------------
# Checkpoint / resume
# ---------------------------------------------------------------------------

def test_trainer_checkpoint_resume_matches_straight_run(partitioned,
                                                        tmp_path):
    d = partitioned
    cfg = _cfg(d)
    ck = str(tmp_path / "ck")

    tr1 = _trainer(d, cfg, ckpt_dir=ck, root_seed=5)
    tr1.fit(epochs=2, iters_per_epoch=2, batch_per_model=8)

    tr2 = _trainer(d, cfg, ckpt_dir=ck, root_seed=5)
    stats = tr2.fit(epochs=3, iters_per_epoch=2, batch_per_model=8,
                    resume=True)
    assert [s.epoch for s in stats] == [2]            # epochs 0-1 skipped
    assert tr2.global_step == 6

    tr3 = _trainer(d, cfg, root_seed=5)               # no checkpointing
    tr3.fit(epochs=3, iters_per_epoch=2, batch_per_model=8)
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(tr3.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_controller_restore_keeps_examination_baseline():
    """A resumed controller must compare against the pre-resume epoch time
    (no unconditional merge) and must still be able to revert the last
    merge on regression."""
    from repro.core import MergingController
    from repro.core.micrograph import hopgnn_assignment
    roots = [np.arange(8) * 4 + i for i in range(4)]
    part = (np.arange(64) % 4).astype(np.int32)
    base = hopgnn_assignment(roots, part)

    ctl = MergingController(base=base)
    ctl.restore(num_steps=3, frozen=False, last_time=8.0)
    assert ctl.pattern_steps == 3 and not ctl.frozen
    ctl.record_epoch_time(9.0)        # regression vs restored baseline
    assert ctl.frozen
    assert ctl.pattern_steps == 4     # reverted the pre-resume merge


def test_trainer_eval_uses_sharded_table(partitioned):
    d = partitioned
    tr = _trainer(d, _cfg(d))
    acc = tr.evaluate(n_eval=64)
    assert 0.0 <= acc <= 1.0


def test_budget_state_roundtrip_restores_buckets(partitioned, rng):
    """state_dict()/load_state(): every learned bucket (incl. per-pattern
    l_buckets, global c_max, and the headroom knobs) survives a JSON
    round-trip with integer pattern keys intact."""
    import json
    from repro.features import FeatureStore
    d = partitioned
    store = FeatureStore.from_array(
        d["table"], host_budget_bytes=d["table"].nbytes // 3)
    b = ShapeBudget(r_max_headroom=1.75)
    roots = [rng.choice(d["ds"].train_vertices(), 8, replace=False)
             for _ in range(d["parts"])]
    b.plan(**_plan_kwargs(d, roots, pregather=True), feature_store=store)
    b.grow("c_max", 5)
    state = json.loads(json.dumps(b.state_dict()))
    b2 = ShapeBudget()
    b2.load_state(state)
    assert b2.buckets == b.buckets
    assert b2.l_buckets == b.l_buckets
    assert list(b2.buckets) == [len(roots)]          # int key survived JSON
    assert (b2.c_max, b2.batch_pad, b2.r_max, b2.l_max) == \
        (b.c_max, b.batch_pad, b.r_max, b.l_max)
    assert b2.r_max_headroom == 1.75
    # a restored budget plans straight into the old bucket: no probe, and
    # no NEW re-buckets (the counter itself is restored — it's cumulative)
    rb0 = b2.rebuckets
    plan = b2.plan(**_plan_kwargs(d, roots, pregather=True),
                   feature_store=store)
    assert b2.probes == 0 and b2.rebuckets == rb0
    assert (plan.batch_pad, plan.r_max) == (b.batch_pad, b.r_max)


def test_resume_restores_budget_no_first_epoch_retrace(partitioned,
                                                       tmp_path):
    """Regression: a resumed run used to re-probe and re-trace its first
    epoch because bucket state died with the process. With budget_state in
    the checkpoint extra, the resumed Trainer plans into the original
    buckets and (compile cache permitting) runs zero traces."""
    d = partitioned
    cfg = _cfg(d)
    ck = str(tmp_path / "ck")
    tr1 = _trainer(d, cfg, ckpt_dir=ck, root_seed=5)
    tr1.fit(epochs=2, iters_per_epoch=2, batch_per_model=8)

    tr2 = _trainer(d, cfg, ckpt_dir=ck, root_seed=5)
    tc0 = engine.trace_count()
    stats = tr2.fit(epochs=3, iters_per_epoch=2, batch_per_model=8,
                    resume=True)
    assert tr2.budget.buckets == tr1.budget.buckets
    assert tr2.budget.c_max == tr1.budget.c_max
    assert tr2.budget.probes == 0                 # bucket known, no probe
    # same shapes + process-global compile cache ⇒ nothing retraces
    assert engine.trace_count() == tc0
    assert stats[0].traces == 0
