"""repro.serve: queue/micro-batcher units, tiered GNN server parity
(served == offline eval forward, bitwise), compile-once steady state,
precomputed-embedding tier, obs wiring, LLM loop unification."""
import threading

import numpy as np
import pytest

from repro.core import plan_inference
from repro.core.distributed import infer_trace_count
from repro.features import FeatureStore
from repro.serve import (BatchingLoop, GNNServer, RequestQueue,
                         load_embeddings, precompute_embeddings)
from repro.train.budget import ShapeBudget


# ----------------------------------------------------------------------
# Shared serving fixture: model + bound store over the session partition
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def served(partitioned):
    import jax
    from repro.models.gnn.models import GNNConfig, init_gnn
    ds = partitioned["ds"]
    store = FeatureStore.from_array(partitioned["table"],
                                    owner=partitioned["owner"],
                                    local_idx=partitioned["local_idx"])
    cfg = GNNConfig(model="sage", feature_dim=ds.features.shape[1],
                    hidden_dim=32, num_classes=int(ds.labels.max()) + 1,
                    num_layers=2, fanout=10)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    return dict(ds=ds, store=store, cfg=cfg, params=params)


def offline_logits(served, nodes):
    """The parity reference: Trainer.evaluate's exact forward path."""
    import jax.numpy as jnp
    from repro.graph.sampler import sample_tree_block
    from repro.models.gnn.models import gnn_forward
    cfg = served["cfg"]
    blk = sample_tree_block(served["ds"].graph,
                            np.asarray(nodes, np.int64),
                            cfg.num_layers, cfg.fanout, seed=999)
    feats = [jnp.asarray(served["store"].take_global(ids))
             for ids in blk.hops]
    return np.asarray(gnn_forward(served["params"], cfg, feats))


def make_server(served, **kw):
    return GNNServer(graph=served["ds"].graph, params=served["params"],
                     cfg=served["cfg"], store=served["store"], **kw)


# ----------------------------------------------------------------------
# Queue / micro-batcher units
# ----------------------------------------------------------------------

def test_queue_fifo_and_batching():
    q = RequestQueue()
    tickets = [q.put(i) for i in range(7)]
    assert q.depth() == 7
    first = q.drain(4)
    assert [t.payload for t in first] == [0, 1, 2, 3]
    assert [t.payload for t in q.drain(100)] == [4, 5, 6]
    assert q.drain(4, wait_s=0.0) == []
    assert all(t.t_drain >= t.t_submit for t in tickets)


def test_loop_dispatch_results_and_errors():
    calls = []

    def dispatch(ts):
        calls.append(len(ts))
        if any(t.payload == "boom" for t in ts):
            raise RuntimeError("boom")
        return [t.payload * 2 for t in ts]

    loop = BatchingLoop(dispatch, max_batch=3, name="tloop")
    ts = [loop.submit(i) for i in range(5)]
    assert loop.pump(wait_s=0.0) == 3
    assert loop.pump(wait_s=0.0) == 2
    assert [t.wait(1.0) for t in ts] == [0, 2, 4, 6, 8]
    assert calls == [3, 2]

    bad = loop.submit("boom")
    with pytest.raises(RuntimeError):
        loop.pump(wait_s=0.0)
    with pytest.raises(RuntimeError):
        bad.wait(1.0)
    assert loop.errors == 1
    # the loop keeps serving after a failed batch
    ok = loop.submit(10)
    loop.pump(wait_s=0.0)
    assert ok.wait(1.0) == 20


def test_loop_background_thread():
    loop = BatchingLoop(lambda ts: [t.payload + 1 for t in ts],
                        max_batch=8, name="bg")
    loop.start()
    try:
        results = []

        def client():
            results.extend(loop.submit(i).wait(10.0) for i in range(20))

        th = threading.Thread(target=client)
        th.start()
        th.join(30.0)
        assert results == list(range(1, 21))
    finally:
        loop.stop()
    assert loop.served == 20


# ----------------------------------------------------------------------
# Inference planner
# ----------------------------------------------------------------------

def test_plan_inference_shapes(partitioned):
    g = partitioned["ds"].graph
    nodes = np.array([5, 9, 21], np.int64)
    plan = plan_inference(g, nodes, 2, 10, sample_seed=999, batch_pad=8)
    assert plan.batch_pad == 8 and plan.num_layers == 2
    assert plan.hop_idx[0].size == 8
    assert plan.hop_idx[1].size == 80
    assert plan.hop_idx[2].size == 800
    # no cache: workspace is exactly the fetched uniques
    fetched = np.sort(plan.fetch_ids)
    assert np.array_equal(plan.fetch_ids, fetched)
    for h in plan.hop_idx:
        assert h.min() >= 0 and h.max() < plan.fetch_ids.size
    # determinism: same roots, same seed → identical plan
    plan2 = plan_inference(g, nodes, 2, 10, sample_seed=999, batch_pad=8)
    assert np.array_equal(plan.fetch_ids, plan2.fetch_ids)
    assert all(np.array_equal(a, b)
               for a, b in zip(plan.hop_idx, plan2.hop_idx))


def test_plan_inference_overflow(partitioned):
    from repro.core import PlanOverflow
    g = partitioned["ds"].graph
    with pytest.raises(PlanOverflow):
        plan_inference(g, np.arange(9), 2, 10, sample_seed=999, batch_pad=8)


# ----------------------------------------------------------------------
# Serving parity: served == offline eval forward, bitwise
# ----------------------------------------------------------------------

def test_parity_cache_off(served):
    srv = make_server(served)
    srv.warmup()
    nodes = [3, 14, 15, 92, 65, 35]
    out = srv.predict(nodes)
    assert np.array_equal(out, offline_logits(served, nodes))


def test_parity_cache_on_across_installs(served):
    srv = make_server(served, cache_budget_bytes=256 * 1024,
                      cache_refresh_every=2)
    srv.warmup()
    rng = np.random.default_rng(1)
    n = served["ds"].graph.num_vertices
    for i in range(8):
        nodes = np.unique(rng.integers(0, n, 12))
        out = srv.predict(nodes.tolist())
        assert np.array_equal(out, offline_logits(served, nodes)), \
            f"parity broke at batch {i} (installs={srv.stats()['cache_installs']})"
    st = srv.stats()
    assert st["cache_installs"] > 0, "cache never admitted anything"
    assert st["cache_hit_rows"] > 0, "admitted rows never hit"
    assert srv.retraces_since_warmup == 0


def test_parity_streamed_store(served, tmp_path):
    """Same contract when features resolve through the tiered (host hot
    tier → mmap disk) store rather than a resident table."""
    import jax
    from repro.graph.partition import shard_features  # noqa: F401
    from repro.models.gnn.models import init_gnn
    ds = served["ds"]
    # rebuild a spilled store over the same partition
    from repro.graph import ldg_partition
    part = ldg_partition(ds.graph, 4, passes=1)
    streamed = FeatureStore.build(ds.features, part, 4,
                                  directory=tmp_path / "feats",
                                  host_budget_bytes=64 * 1024)
    assert not streamed.resident
    srv = GNNServer(graph=ds.graph, params=served["params"],
                    cfg=served["cfg"], store=streamed,
                    cache_budget_bytes=128 * 1024)
    srv.warmup()
    nodes = [7, 11, 200, 41]
    out = srv.predict(nodes)
    import jax.numpy as jnp
    from repro.graph.sampler import sample_tree_block
    from repro.models.gnn.models import gnn_forward
    cfg = served["cfg"]
    blk = sample_tree_block(ds.graph, np.asarray(nodes, np.int64),
                            cfg.num_layers, cfg.fanout, seed=999)
    feats = [jnp.asarray(streamed.take_global(ids)) for ids in blk.hops]
    ref = np.asarray(gnn_forward(served["params"], cfg, feats))
    assert np.array_equal(out, ref)


def test_dense_array_store(served):
    """A raw (N, d) table is accepted and serves identically."""
    srv = make_server(served)
    srv.warmup()
    dense = GNNServer(graph=served["ds"].graph, params=served["params"],
                      cfg=served["cfg"], store=served["ds"].features)
    dense.warmup()
    nodes = [3, 14, 15]
    assert np.array_equal(dense.predict(nodes), srv.predict(nodes))


# ----------------------------------------------------------------------
# Compile-once steady state
# ----------------------------------------------------------------------

def test_zero_retraces_after_warmup(served):
    srv = make_server(served, cache_budget_bytes=256 * 1024,
                      cache_refresh_every=3, max_batch=16)
    srv.warmup()
    before = infer_trace_count()
    rng = np.random.default_rng(2)
    n = served["ds"].graph.num_vertices
    for _ in range(25):
        k = int(rng.integers(1, 17))
        srv.predict(rng.integers(0, n, k).tolist())
    assert infer_trace_count() == before, \
        "steady-state serving retraced after warmup"
    assert srv.retraces_since_warmup == 0
    assert srv.stats()["cache_installs"] > 0  # installs didn't retrace


def test_budget_serve_buckets_roundtrip():
    b = ShapeBudget()
    bp = b.serve_batch_pad(13)
    assert bp == 16
    u = b.serve_fetch_pad(bp, 700)
    assert u >= 700 and (u & (u - 1)) == 0
    # growth re-buckets; shrink keeps the learned rung
    assert b.serve_fetch_pad(bp, u + 1) > u
    assert b.serve_fetch_pad(bp, 8) == b.serve_fetch_pad(bp, 8)
    b2 = ShapeBudget()
    b2.load_state(b.state_dict())
    assert b2.serve_rungs() == b.serve_rungs()


# ----------------------------------------------------------------------
# Precomputed-embedding tier
# ----------------------------------------------------------------------

def test_precomputed_tier_parity_and_staleness(served, tmp_path):
    ds, cfg = served["ds"], served["cfg"]
    precompute_embeddings(ds.graph, served["store"], served["params"], cfg,
                          ckpt_dir=tmp_path, params_step=7, chunk=128)
    tab = load_embeddings(tmp_path, params_step=7, sample_seed=999)
    assert tab.num_vertices == ds.graph.num_vertices
    nodes = [3, 14, 15, 92]
    assert np.array_equal(tab.lookup(nodes), offline_logits(served, nodes))

    # serving from the table alone: bit-identical, zero fresh computes
    srv = make_server(served, ckpt_dir=tmp_path, params_step=7,
                      mode="precomputed")
    out = srv.predict(nodes)
    assert np.array_equal(out, offline_logits(served, nodes))
    assert srv.fresh_batches == 0 and srv.precomputed_hits == len(nodes)

    # stale stamps are refused...
    with pytest.raises(ValueError, match="stale"):
        load_embeddings(tmp_path, params_step=8)
    with pytest.raises(ValueError, match="seed"):
        load_embeddings(tmp_path, params_step=7, sample_seed=123)
    with pytest.raises(FileNotFoundError):
        load_embeddings(tmp_path / "nowhere")
    # ...unless explicitly allowed
    assert load_embeddings(tmp_path, params_step=8,
                           allow_stale=True).num_vertices


def test_auto_mode_promotes_hot_vertices(served, tmp_path):
    ds = served["ds"]
    precompute_embeddings(ds.graph, served["store"], served["params"],
                          served["cfg"], ckpt_dir=tmp_path, params_step=0)
    srv = make_server(served, ckpt_dir=tmp_path, params_step=0, mode="auto",
                      cache_budget_bytes=256 * 1024, cache_refresh_every=1)
    srv.warmup()
    fresh_after_warmup = srv.fresh_batches
    # cold vertex → precomputed tier
    t = srv.submit(42)
    srv.loop.pump(wait_s=0.0)
    t.wait(1.0)
    assert t.via == "precomputed"
    assert srv.fresh_batches == fresh_after_warmup
    # hammer the same vertex: LFU admits its feature row, later requests
    # flip to fresh compute (current-params answers at cached-feature cost)
    for _ in range(6):
        srv.predict([42])
    t2 = srv.submit(42)
    srv.loop.pump(wait_s=0.0)
    t2.wait(1.0)
    assert t2.via == "fresh"
    assert np.array_equal(t2.result, offline_logits(served, [42])[0])


def test_edge_prediction(served):
    srv = make_server(served)
    srv.warmup()
    t = srv.submit((3, 14))
    srv.loop.pump(wait_s=0.0)
    score = t.wait(1.0)
    ref = offline_logits(served, [3, 14])
    assert score == pytest.approx(float(np.dot(ref[0], ref[1])))
    assert t.via == "edge"


# ----------------------------------------------------------------------
# Observability wiring
# ----------------------------------------------------------------------

def test_serve_spans_and_metrics(served):
    from repro.obs import metrics, trace
    srv = make_server(served, cache_budget_bytes=128 * 1024)
    srv.warmup()
    trace.enable()
    try:
        srv.predict([3, 14, 15])
        names = {r.name for r in trace.records()}
    finally:
        trace.disable()
    for want in ("serve.queue.wait", "serve.batch", "serve.batch.build",
                 "serve.dispatch", "serve.sync"):
        assert want in names, f"missing span {want} (got {sorted(names)})"
    snap = metrics.registry().snapshot()
    flat = {k for section in snap.values() if isinstance(section, dict)
            for k in section}
    for want in ("serve.requests", "serve.batches", "serve.latency_ms",
                 "serve.queue_wait_ms", "serve.queue_depth", "serve.qps"):
        assert want in flat, f"missing metric {want}"


# ----------------------------------------------------------------------
# LLM unification: same loop, transformer dispatch
# ----------------------------------------------------------------------

def test_llm_server_smoke():
    import jax
    from repro.configs import get_config, smoke_variant
    from repro.launch.serve import LLMServer
    from repro.models.transformer import init_params
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    srv = LLMServer(params, cfg, gen_tokens=4, max_batch=4, name="llm")
    rng = np.random.default_rng(0)
    ts = [srv.submit(rng.integers(1, cfg.vocab_size, 8)) for _ in range(5)]
    while not all(t.done() for t in ts):
        srv.pump(wait_s=0.0)
    for t in ts:
        out = t.wait(1.0)
        assert out.shape == (4,) and out.dtype == np.int32
    st = srv.stats()
    assert st["served"] == 5 and st["batches"] >= 2 and st["errors"] == 0
