"""Per-assigned-architecture smoke tests (deliverable f).

For each of the 10 architectures: instantiate the REDUCED same-family
variant (≤2 layers, d_model ≤ 512, ≤4 experts), run one forward pass and
one train step on CPU, assert output shapes and no NaNs; then one decode
step against a fresh cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.data import make_batch
from repro.launch.train import make_train_step, pick_optimizer
from repro.models.transformer import (decode_step, forward,
                                      init_decode_state, init_params,
                                      prefill)

SEQ, BATCH = 32, 2


def _setup(arch_id):
    cfg = smoke_variant(get_config(arch_id))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, BATCH, SEQ, seed=0)
    return cfg, params, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id):
    cfg, params, batch = _setup(arch_id)
    logits, aux = forward(params, cfg, batch)
    S = (batch["tokens"].shape[1] + batch["patches"].shape[1]
         if cfg.family == "vlm" else batch["tokens"].shape[1])
    assert logits.shape == (BATCH, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_reduces_nothing_nan(arch_id):
    cfg, params, batch = _setup(arch_id)
    opt = pick_optimizer(cfg, lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    params2, opt_state, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    # parameters actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved
    # second step still finite
    _, _, m2 = step(params2, opt_state, batch)
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg, params, batch = _setup(arch_id)
    if cfg.family == "audio":
        _, state = prefill(params, cfg, batch, max_seq=SEQ + 8)
    else:
        state = init_decode_state(cfg, BATCH, SEQ + 8)
    tok = jnp.zeros((BATCH,), jnp.int32)
    logits, state = decode_step(params, cfg, tok, state)
    assert logits.shape == (BATCH, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, _ = decode_step(params, cfg, tok + 1, state)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation must reproduce the full-batch gradient."""
    import dataclasses
    cfg = dataclasses.replace(smoke_variant(get_config("qwen2-1.5b")),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 4, SEQ, seed=0)
    opt = pick_optimizer(cfg, lr=1e-3)
    s1 = jax.jit(make_train_step(cfg, opt, accum=1))
    s4 = jax.jit(make_train_step(cfg, opt, accum=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)


@pytest.mark.parametrize("arch_id", ["rwkv6-7b", "recurrentgemma-9b",
                                     "qwen2-1.5b", "h2o-danube-3-4b",
                                     "qwen2-moe-a2.7b", "pixtral-12b",
                                     "whisper-base"])
def test_prefill_then_decode_matches_full_forward(arch_id):
    """Serving correctness: decode of token T given a prefilled prompt of
    T-1 tokens must equal the full-sequence forward logits (stateful
    prefill for ssm/hybrid; KV-cache prefill for dense/moe/vlm; encoder +
    cross-attn cache for audio)."""
    import dataclasses
    cfg = dataclasses.replace(smoke_variant(get_config(arch_id)),
                              dtype="float32")
    if cfg.moe_num_experts:
        # capacity drops are a *training* artifact: a full-sequence forward
        # may drop the last token from a full expert while decode (S=1)
        # never drops - raise cf so the comparison is drop-free
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    batch = make_batch(cfg, B, S, seed=0)
    toks = batch["tokens"]
    last, state = prefill(params, cfg, batch, max_seq=S + 8)
    full, _ = forward(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    nxt = jnp.asarray([3, 7], jnp.int32)
    dl, state = decode_step(params, cfg, nxt, state)
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([toks, nxt[:, None]], 1)
    full2, _ = forward(params, cfg, ext)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(full2[:, -1]),
                               rtol=5e-3, atol=5e-3)


def test_moe_dispatch_modes_numerically_equivalent():
    """HopMoE's tokens/weights modes are *shardings* of the same math —
    outputs must match exactly on one device."""
    import dataclasses
    from repro.models.transformer.moe import init_moe, moe_forward
    base = dataclasses.replace(smoke_variant(get_config("deepseek-moe-16b")),
                               dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), base, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 16, 256)),
                    jnp.float32)
    outs = {}
    for mode in ("tokens", "weights"):
        cfg = dataclasses.replace(base, moe_dispatch=mode)
        y, stats = moe_forward(p, cfg, x)
        outs[mode] = np.asarray(y)
        assert stats.mode == mode
    np.testing.assert_allclose(outs["tokens"], outs["weights"],
                               rtol=1e-5, atol=1e-5)
