"""Graph substrate: CSR invariants, partitioners, sampler (incl. property
tests with hypothesis)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.graph import (CSRGraph, hash_partition, ldg_partition,
                         make_dataset, range_partition, sample_tree_block)
from repro.graph.partition import (edge_cut, local_index_map, partition_sizes,
                                   shard_features)
from repro.graph.sampler import group_roots_by_home, micrograph_split


# ---------------------------------------------------------------------------
# CSR
# ---------------------------------------------------------------------------

@given(st.integers(4, 64), st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_csr_from_edges_invariants(n, m, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    g = CSRGraph.from_edges(n, src, dst, symmetrize=True)
    assert g.indptr.shape == (n + 1,)
    assert g.indptr[0] == 0 and g.indptr[-1] == g.num_edges
    assert np.all(np.diff(g.indptr) >= 0)
    assert np.all(g.indices >= 0) and np.all(g.indices < n)
    # symmetry: (u,v) present => (v,u) present
    for u in range(n):
        for v in g.neighbors(u):
            assert u in g.neighbors(int(v)), (u, v)
    # no self loops
    for u in range(n):
        assert u not in g.neighbors(u)


def test_dataset_volumes():
    ds = make_dataset("arxiv", scale=0.02, seed=1)
    assert ds.vol_f_bytes() > ds.vol_g_bytes()      # features dominate (Tab 2)
    assert ds.features.shape == (ds.num_vertices, 128)
    assert ds.train_vertices().size > 0


# ---------------------------------------------------------------------------
# Partitioners
# ---------------------------------------------------------------------------

@given(st.integers(16, 300), st.integers(2, 8), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_partitioners_cover_every_vertex(n, parts, seed):
    for part in (hash_partition(n, parts, seed), range_partition(n, parts)):
        assert part.shape == (n,)
        assert part.min() >= 0 and part.max() < parts


def test_ldg_balanced_and_local(small_dataset):
    g = small_dataset.graph
    parts = 4
    part = ldg_partition(g, parts, passes=1)
    sizes = partition_sizes(part, parts)
    assert sizes.sum() == g.num_vertices
    assert sizes.max() <= 1.10 * g.num_vertices / parts   # capacity slack
    # locality: community graph must cut far fewer edges than random
    assert edge_cut(g, part) < 0.8 * edge_cut(
        g, hash_partition(g.num_vertices, parts, 0))


def test_shard_features_roundtrip(partitioned):
    ds, part = partitioned["ds"], partitioned["part"]
    table, owner, local_idx = (partitioned["table"], partitioned["owner"],
                               partitioned["local_idx"])
    v = np.arange(0, ds.num_vertices, 97)
    np.testing.assert_array_equal(table[owner[v], local_idx[v]],
                                  ds.features[v])
    o2, l2, mx = local_index_map(part, partitioned["parts"])
    assert table.shape[1] == mx


# ---------------------------------------------------------------------------
# Sampler
# ---------------------------------------------------------------------------

@given(st.integers(1, 16), st.integers(1, 3), st.integers(1, 5),
       st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_tree_block_shapes(batch, layers, fanout, seed):
    ds = make_dataset("arxiv", scale=0.01, seed=0)
    rng = np.random.default_rng(seed)
    roots = rng.integers(0, ds.num_vertices, batch)
    blk = sample_tree_block(ds.graph, roots, layers, fanout, rng=rng)
    assert blk.num_layers == layers
    for h, ids in enumerate(blk.hops):
        assert ids.shape == (batch * fanout ** h,)
        assert ids.min() >= 0 and ids.max() < ds.num_vertices


def test_deterministic_sampling_is_per_root(small_dataset):
    """Stateless mode: a root's subtree is identical whether sampled alone
    or inside a batch — the gradient-parity prerequisite."""
    g = small_dataset.graph
    roots = np.array([5, 17, 42, 3])
    blk = sample_tree_block(g, roots, 2, 3, seed=11)
    for i, r in enumerate(roots):
        solo = sample_tree_block(g, np.array([r]), 2, 3, seed=11)
        sub = blk.select(np.array([i]))
        for h_solo, h_sub in zip(solo.hops, sub.hops):
            np.testing.assert_array_equal(h_solo, h_sub)


def test_sampler_modes_exclusive(small_dataset):
    g = small_dataset.graph
    with pytest.raises(ValueError):
        sample_tree_block(g, np.array([0]), 1, 2)
    with pytest.raises(ValueError):
        sample_tree_block(g, np.array([0]), 1, 2,
                          rng=np.random.default_rng(0), seed=1)


def test_micrograph_split_and_grouping(partitioned):
    ds, part = partitioned["ds"], partitioned["part"]
    rng = np.random.default_rng(0)
    roots = rng.integers(0, ds.num_vertices, 8)
    blk = sample_tree_block(ds.graph, roots, 2, 3, rng=rng)
    micros = micrograph_split(blk)
    assert len(micros) == 8
    assert all(m.batch_size == 1 for m in micros)
    groups = group_roots_by_home(roots, part, partitioned["parts"])
    assert sum(g.size for g in groups) == roots.size
    for s, grp in enumerate(groups):
        assert np.all(part[grp] == s)


def test_micrograph_locality_beats_subgraph(partitioned):
    """Table 1's central claim: R_micro > R_sub on a locality-partitioned
    graph."""
    ds, part = partitioned["ds"], partitioned["part"]
    rng = np.random.default_rng(3)
    roots = rng.choice(ds.num_vertices, 64, replace=False)
    blk = sample_tree_block(ds.graph, roots, 2, 5, rng=rng)
    r_micro = np.mean([m.locality(part) for m in micrograph_split(blk)])
    r_sub = blk.locality(part)       # vs the first root's home
    assert r_micro > r_sub
