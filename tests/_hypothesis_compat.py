"""Optional-import shim for ``hypothesis``.

The container image does not ship hypothesis; a hard import made three test
modules error at *collection*, taking every example-based test in them down
too. Import ``given``/``settings``/``st`` from here instead: with
hypothesis installed the real objects pass through untouched; without it,
``@given`` rewrites the property test into a zero-argument test that skips
cleanly, and ``st``/``settings`` become inert stand-ins so module-level
strategy expressions still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy constructor / combinator call."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # A fresh zero-arg function (no __wrapped__): pytest must not
            # mistake the property-test's strategy parameters for fixtures.
            def _skipped():
                pytest.skip("hypothesis not installed (property test)")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
