"""Async device pipeline (repro.train.pipeline + fused engine step):

* fused iteration+update vs the grads round-trip + eager optimizer path,
* pipelined (non-blocking, committed uploads) vs synchronous fused loop —
  bit-identical params and losses across pregather / per-step /
  per-step+folded / cache-on configurations,
* K-stacked scan dispatch parity (incl. the remainder path),
* zero retraces after epoch 0 under ping-pong plan buffers,
* device-resident argument fast paths (committed plans, table passthrough,
  shared empty-cache table), and the donation contract.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import distributed as engine
from repro.core import run_iteration
from repro.models.gnn import GNNConfig, init_gnn
from repro.optim import adam
from repro.train import PlanUploader, ShapeBudget, Trainer


def _cfg(d, model="sage"):
    return GNNConfig(model=model, num_layers=2, hidden_dim=16,
                     feature_dim=d["ds"].feature_dim,
                     num_classes=d["ds"].num_classes, fanout=4)


def _trainer(d, cfg, **kw):
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"], table=d["table"], cfg=cfg, **kw)


def _plan(d, roots, **kw):
    budget = ShapeBudget()
    return budget.plan(
        graph=d["ds"].graph, labels=d["ds"].labels, part=d["part"],
        owner=d["owner"], local_idx=d["local_idx"],
        local_rows=d["table"].shape[1], roots_per_model=roots,
        num_layers=2, fanout=4, strategy="hopgnn", sample_seed=7, **kw)


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# Fused engine step
# ---------------------------------------------------------------------------

def test_fused_step_matches_manual_update(partitioned):
    """run_train_step (one fused dispatch) must reproduce run_iteration +
    optimizer.update: bit-identical loss, params equal to XLA
    fusion-boundary rounding (≤1 ulp — the update chain compiles as one
    program instead of per-op)."""
    d = partitioned
    cfg = _cfg(d)
    opt = adam(5e-3)
    rng = np.random.default_rng(1)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 9, replace=False) for _ in range(d["parts"])]
    plan = _plan(d, roots)

    params = init_gnn(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    grads, loss_ref = run_iteration(params, d["table"], plan, cfg)
    p_ref, s_ref = opt.update(grads, state, params)

    params2 = init_gnn(jax.random.PRNGKey(0), cfg)
    state2 = opt.init(params2)
    p_f, s_f, loss_f = engine.run_train_step(params2, state2, d["table"],
                                             plan, cfg, opt)
    assert float(loss_ref) == float(loss_f)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=2e-8)
    for a, b in zip(jax.tree.leaves(s_ref), jax.tree.leaves(s_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=2e-8)


def test_fused_step_donates_buffers(partitioned):
    """The donation contract: the input params/opt_state buffers are
    consumed by the fused call (callers must thread the outputs)."""
    d = partitioned
    cfg = _cfg(d)
    opt = adam(5e-3)
    rng = np.random.default_rng(2)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 9, replace=False) for _ in range(d["parts"])]
    plan = _plan(d, roots)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    state = opt.init(params)
    p2, s2, _ = engine.run_train_step(params, state, d["table"], plan,
                                      cfg, opt)
    assert jax.tree.leaves(params)[0].is_deleted()
    assert not jax.tree.leaves(p2)[0].is_deleted()


def test_optimizer_value_key_shares_compiled_step(partitioned):
    """Two optimizer instances with equal hyperparameters must resolve to
    the same compiled fused program (value cache key, no per-instance
    recompilation)."""
    d = partitioned
    cfg = _cfg(d)
    a = engine.get_compiled_train_step(cfg, True, adam(5e-3))
    b = engine.get_compiled_train_step(cfg, True, adam(5e-3))
    assert a is b
    c = engine.get_compiled_train_step(cfg, True, adam(7e-3))
    assert c is not a


# ---------------------------------------------------------------------------
# Pipelined loop parity (the tentpole acceptance tests)
# ---------------------------------------------------------------------------

_PARITY_CONFIGS = [
    ("pregather", dict()),
    ("per-step", dict(pregather=False, fold_returns=False)),
    ("per-step-folded", dict(pregather=False, fold_returns=True)),
    ("cache-on", dict(cache_policy="degree", cache_budget_bytes=1 << 16)),
]


@pytest.mark.parametrize("name,kw", _PARITY_CONFIGS,
                         ids=[n for n, _ in _PARITY_CONFIGS])
def test_pipelined_matches_sync_loop_bitwise(partitioned, name, kw):
    """The async pipeline changes WHEN work happens, never WHAT is
    computed: params and per-epoch losses must be bit-identical to the
    synchronous (per-iteration blocking) fused loop."""
    d = partitioned
    cfg = _cfg(d)
    tr_p = _trainer(d, cfg, pipeline=True, **kw)
    st_p = tr_p.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    tr_s = _trainer(d, cfg, pipeline=False, fused=True, **kw)
    st_s = tr_s.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    assert _tree_equal(tr_p.params, tr_s.params)
    assert _tree_equal(tr_p.opt_state, tr_s.opt_state)
    assert [s.loss for s in st_p] == [s.loss for s in st_s]
    assert all(s.pipelined for s in st_p)
    assert not any(s.pipelined for s in st_s)


def test_pipelined_matches_legacy_loop_close(partitioned):
    """Against the pre-pipeline loop (grads round-trip + eager per-op
    update) the fused program may differ by XLA fusion-boundary rounding
    only: losses and params agree to float tolerance after two epochs."""
    d = partitioned
    cfg = _cfg(d)
    tr_p = _trainer(d, cfg, pipeline=True)
    st_p = tr_p.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    tr_l = _trainer(d, cfg, pipeline=False, fused=False)   # legacy path
    st_l = tr_l.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    for a, b in zip(jax.tree.leaves(tr_p.params), jax.tree.leaves(tr_l.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose([s.loss for s in st_p],
                               [s.loss for s in st_l], rtol=1e-5)


def test_zero_retraces_after_epoch0_with_pingpong_uploads(partitioned):
    """Acceptance: the pipelined loop double-buffers plan uploads into
    ping-pong slots without ever changing device shapes — epochs ≥1 run
    with zero jit traces and every upload signature is stable."""
    engine.clear_compile_cache()
    d = partitioned
    tr = _trainer(d, _cfg(d), pipeline=True)
    stats = tr.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)
    assert stats[0].traces >= 1
    assert stats[1].traces == 0 and stats[2].traces == 0
    assert all(s.compile_free for s in stats)
    assert tr._uploader.uploads == 9              # one commit per plan
    assert tr._uploader.shape_changes == 0
    # every executed plan took the committed-upload fast path
    assert tr.budget.rebuckets == 0


def test_stacked_dispatch_parity_and_remainder(partitioned):
    """pipeline_stack=K scans the fused step over K stacked plans: results
    must be bit-identical to unstacked, including the remainder dispatch
    when iters % K != 0 (5 iters, K=2 → dispatches of 2, 2, 1)."""
    d = partitioned
    cfg = _cfg(d)
    tr1 = _trainer(d, cfg, pipeline=True)
    st1 = tr1.fit(epochs=2, iters_per_epoch=5, batch_per_model=8)
    trk = _trainer(d, cfg, pipeline=True, pipeline_stack=2)
    stk = trk.fit(epochs=2, iters_per_epoch=5, batch_per_model=8)
    assert _tree_equal(tr1.params, trk.params)
    assert [s.loss for s in st1] == [s.loss for s in stk]
    assert trk.global_step == tr1.global_step == 10


def test_loss_sync_every_k_iters(partitioned):
    """The optional queue-depth throttle (sync losses every K dispatches)
    must not change results."""
    d = partitioned
    cfg = _cfg(d)
    tr_a = _trainer(d, cfg, pipeline=True)
    st_a = tr_a.fit(epochs=1, iters_per_epoch=4, batch_per_model=8)
    tr_b = _trainer(d, cfg, pipeline=True, loss_sync_iters=2)
    st_b = tr_b.fit(epochs=1, iters_per_epoch=4, batch_per_model=8)
    assert _tree_equal(tr_a.params, tr_b.params)
    assert st_a[0].loss == st_b[0].loss


# ---------------------------------------------------------------------------
# Device-resident argument fast paths
# ---------------------------------------------------------------------------

def test_prepare_args_fast_paths(partitioned):
    """Device-resident tables pass through untouched, committed plans skip
    the conversion walk, and cache-off iterations share one zero-width
    cache table."""
    d = partitioned
    rng = np.random.default_rng(3)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 9, replace=False) for _ in range(d["parts"])]
    plan = _plan(d, roots)
    table = jnp.asarray(d["table"])

    t1, c1, dev1, _ = engine.prepare_iteration_args(table, plan)
    assert t1 is table                            # no re-wrap
    t2, c2, _, _ = engine.prepare_iteration_args(table, plan)
    assert c2 is c1                               # shared empty cache

    up = PlanUploader()
    up.commit(plan)
    assert plan.committed is not None
    _, _, dev3, denom3 = engine.prepare_iteration_args(table, plan)
    assert dev3 is plan.committed["dev"]          # committed fast path
    assert denom3 is plan.committed["denom"]
    # committed args execute identically
    params = init_gnn(jax.random.PRNGKey(0), _cfg(d))
    _, loss_a = run_iteration(params, table, plan, _cfg(d))
    plan.committed = None
    _, loss_b = run_iteration(params, table, plan, _cfg(d))
    assert float(loss_a) == float(loss_b)


def test_uploader_pingpong_and_budget_guard(partitioned):
    """Slots alternate, signatures stay stable for same-bucket plans, and
    a plan whose shapes drifted from its budget bucket is refused."""
    d = partitioned
    rng = np.random.default_rng(4)
    tv = d["ds"].train_vertices()
    budget = ShapeBudget()
    plans = []
    for i in range(4):
        roots = [rng.choice(tv, 7 + i % 2, replace=False)
                 for _ in range(d["parts"])]
        plans.append(budget.plan(
            graph=d["ds"].graph, labels=d["ds"].labels, part=d["part"],
            owner=d["owner"], local_idx=d["local_idx"],
            local_rows=d["table"].shape[1], roots_per_model=roots,
            num_layers=2, fanout=4, strategy="hopgnn", sample_seed=i))
    up = PlanUploader(budget=budget)
    for p in plans:
        up.commit(p)
    assert up.uploads == 4 and up.shape_changes == 0

    bad = plans[0]
    bad.committed = None
    bad.batch_pad *= 2                # claims shapes outside its bucket
    with pytest.raises(AssertionError, match="drifted"):
        up.commit(bad)


def test_stacked_dispatch_falls_back_on_shape_split(partitioned):
    """A mid-epoch re-bucket can hand the stacker plans with different
    r_max buckets: it must fall back to per-plan dispatch (one extra
    retrace, like the unstacked loop), not crash in jnp.stack."""
    d = partitioned
    cfg = _cfg(d)
    tr = _trainer(d, cfg, pipeline=True, pipeline_stack=2)
    from repro.core import plan_iteration
    rng = np.random.default_rng(5)
    tv = d["ds"].train_vertices()
    roots = [rng.choice(tv, 9, replace=False) for _ in range(d["parts"])]
    a = _plan(d, roots)
    b = plan_iteration(                        # same pattern, split bucket
        d["ds"].graph, d["ds"].labels, d["part"], d["owner"],
        d["local_idx"], d["table"].shape[1], roots, num_layers=2,
        fanout=4, strategy="hopgnn", sample_seed=7,
        batch_pad=a.batch_pad, r_max=2 * a.r_max)
    assert (a.num_steps, a.pregather) == (b.num_steps, b.pregather)
    assert a.r_max != b.r_max
    step0 = tr.global_step
    losses = tr._dispatch_stacked([a, b])
    assert isinstance(losses, list) and len(losses) == 2
    assert tr.global_step == step0 + 2            # both plans executed
    assert all(np.isfinite(float(l)) for l in losses)


def test_midflight_overflow_rebuckets_without_upload_violation(partitioned):
    """A batch-size spike mid-epoch overflows batch_pad while the pipelined
    uploader has committed plans in flight: the budget re-buckets, the
    uploader accepts the new bucket as the expected signature (zero
    stability violations), exactly one extra retrace happens, and the run
    stays bit-identical to the synchronous fused loop."""
    engine.clear_compile_cache()
    d = partitioned
    cfg = _cfg(d)
    tv = d["ds"].train_vertices()

    def spiky_roots(epoch, it):
        rng = np.random.default_rng((11, epoch, it))
        # iterations 0-2 fit the seeded bucket; iteration 3 quadruples the
        # batch, overflowing batch_pad (which carries no probe headroom)
        n = 8 if (epoch, it) < (0, 3) else 36
        return [rng.choice(tv, n, replace=False) for _ in range(d["parts"])]

    tr_p = _trainer(d, cfg, pipeline=True, root_fn=spiky_roots)
    st_p = tr_p.fit(epochs=2, iters_per_epoch=5, batch_per_model=8)
    assert tr_p.budget.rebuckets >= 1
    assert tr_p._uploader.shape_changes == 0      # re-bucket, not drift
    assert tr_p._uploader.uploads == 10
    # after the overflow epoch, shapes are settled again: no new traces
    assert st_p[1].traces == 0

    tr_s = _trainer(d, cfg, pipeline=False, fused=True, root_fn=spiky_roots)
    st_s = tr_s.fit(epochs=2, iters_per_epoch=5, batch_per_model=8)
    assert _tree_equal(tr_p.params, tr_s.params)
    assert [s.loss for s in st_p] == [s.loss for s in st_s]
