"""Substrates: checkpointing, data pipeline, optimizers."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import get_config, smoke_variant
from repro.data import make_batch, token_batches
from repro.optim import adamw, clip_by_global_norm, cosine_schedule, sgd


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((), jnp.int32)]}
    save_checkpoint(tmp_path, 7, tree, extra={"lr": 0.1})
    restored, step, extra = load_checkpoint(tmp_path, tree)
    assert step == 7 and extra == {"lr": 0.1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_prunes_and_tracks_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.glob("step-*.npz"))
    assert len(kept) == 2 and kept[-1] == "step-00000005.npz"


def test_checkpoint_leaf_count_guard(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, {"a": jnp.zeros((2,)),
                                   "b": jnp.zeros((2,))})


def test_data_pipeline_deterministic_and_in_range():
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    b1 = make_batch(cfg, 4, 64, seed=9)
    b2 = make_batch(cfg, 4, 64, seed=9)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert int(b1["tokens"].max()) < cfg.vocab_size
    assert int(b1["tokens"].min()) >= 0
    batches = list(token_batches(cfg, 2, 32, steps=3, seed=1))
    assert len(batches) == 3
    assert not np.array_equal(batches[0]["tokens"], batches[1]["tokens"])


def test_data_pipeline_modalities():
    vlm = smoke_variant(get_config("pixtral-12b"))
    b = make_batch(vlm, 2, 32, seed=0)
    assert b["patches"].shape[2] == vlm.patch_dim
    assert b["patches"].shape[1] + b["tokens"].shape[1] == 32
    audio = smoke_variant(get_config("whisper-base"))
    b = make_batch(audio, 2, 32, seed=0)
    assert b["frames"].shape[1:] == (audio.encoder_seq, audio.encoder_d_model)


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_sgd_momentum_and_clip():
    opt = sgd(0.05, momentum=0.9)
    params = {"w": jnp.asarray([4.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert abs(float(params["w"][0])) < 0.05
    g, norm = clip_by_global_norm({"a": jnp.full((4,), 10.0)}, 1.0)
    assert float(jnp.sqrt(jnp.sum(jnp.square(g["a"])))) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 1e-6
    assert float(lr(55)) < float(lr(20))
