"""repro.features: tiered FeatureStore units, spill-to-disk parity, and the
streamed engine path's bit-identity against the resident baseline."""
import os

import numpy as np
import jax
import pytest

from repro.core import PlanOverflow, plan_iteration, run_iteration
from repro.features import FeatureStore, spill_shards
from repro.models.gnn import GNNConfig, init_gnn
from repro.optim import adam
from repro.train import ShapeBudget, Trainer


def _cfg(d, model="sage"):
    return GNNConfig(model=model, num_layers=2, hidden_dim=16,
                     feature_dim=d["ds"].feature_dim,
                     num_classes=d["ds"].num_classes, fanout=4)


def _trainer(d, cfg, table=None, **kw):
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"],
                   table=d["table"] if table is None else table,
                   cfg=cfg, **kw)


def _tree_equal(a, b):
    return all(bool((np.asarray(x) == np.asarray(y)).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _tiered(d, frac=3):
    return FeatureStore.from_array(
        d["table"], host_budget_bytes=max(1, d["table"].nbytes // frac))


# ---------------------------------------------------------------------------
# Store units
# ---------------------------------------------------------------------------

def test_resident_store_reads_match_table(partitioned):
    d = partitioned
    st = FeatureStore.from_array(d["table"], owner=d["owner"],
                                 local_idx=d["local_idx"])
    assert st.resident and st.hot_rows == st.local_rows
    assert st.as_dense() is not None
    rows = np.array([0, 3, 3, 1])
    assert np.array_equal(st.gather(1, rows), d["table"][1][rows])
    ids = np.arange(0, d["part"].size, 7)
    expect = d["table"][d["owner"][ids], d["local_idx"][ids]]
    assert np.array_equal(st.take_global(ids), expect)
    assert st.stats.t1_rows > 0 and st.stats.t2_rows == 0


def test_tiered_store_hot_sizing_and_miss_path(partitioned):
    d = partitioned
    table = d["table"]
    budget = table.nbytes // 4
    st = FeatureStore.from_array(table, host_budget_bytes=budget)
    assert not st.resident
    assert st.hot_rows == min(st.local_rows,
                              budget // (st.num_shards * st.row_bytes))
    with pytest.raises(ValueError):
        st.as_dense()
    # cold store: everything is a tier-2 miss
    rows = np.arange(min(10, st.local_rows))
    out = st.gather(0, rows)
    assert np.array_equal(out, table[0][rows])
    assert st.stats.t2_rows == rows.size and st.stats.t1_rows == 0
    # promote those rows; re-read is all tier-1, values identical
    st.readahead(0, rows)
    assert st.hot_installed_rows(0) == min(rows.size, st.hot_rows)
    s0 = st.stats.snapshot()
    out2 = st.gather(0, rows[:st.hot_rows])
    assert np.array_equal(out2, table[0][rows[:st.hot_rows]])
    delta = st.stats.delta(s0)
    assert delta.t2_rows == 0 and delta.t1_rows == rows[:st.hot_rows].size


def test_readahead_ranks_by_forecast_counts(partitioned):
    d = partitioned
    st = FeatureStore.from_array(
        d["table"], host_budget_bytes=2 * d["table"].shape[0]
        * st_row_bytes(d))
    assert st.hot_rows == 2
    rows = np.array([4, 1, 9, 6])
    counts = np.array([1, 5, 2, 9])
    installed = st.readahead(0, rows, counts=counts)
    assert installed == 2
    # highest expected read counts win: rows 6 (9 reads) and 1 (5 reads)
    hit, _ = st._hot[0].hit_split(np.array([1, 6, 4, 9]))
    assert hit.tolist() == [True, True, False, False]


def st_row_bytes(d):
    return d["table"].shape[-1] * d["table"].dtype.itemsize


# ---------------------------------------------------------------------------
# Spill to disk (tier 2)
# ---------------------------------------------------------------------------

def test_spilled_synthetic_dataset_is_bitwise_identical(tmp_path):
    """The chunked memmap writer draws from the SAME numpy bit stream as the
    one-shot in-RAM path (Generator fills sequentially), so spilling never
    changes the dataset."""
    from repro.graph import make_dataset
    ram = make_dataset("arxiv", scale=0.01, seed=3)
    sp = make_dataset("arxiv", scale=0.01, seed=3, spill_dir=str(tmp_path),
                      feature_budget_bytes=1, spill_chunk_rows=257)
    assert isinstance(sp.features, np.memmap)
    assert np.array_equal(np.asarray(sp.features), ram.features)
    assert np.array_equal(sp.labels, ram.labels)
    # a covering budget keeps the in-RAM path
    big = make_dataset("arxiv", scale=0.01, seed=3, spill_dir=str(tmp_path),
                       feature_budget_bytes=1 << 40)
    assert not isinstance(big.features, np.memmap)


def test_spill_shards_matches_shard_features(partitioned, tmp_path):
    d = partitioned
    st = FeatureStore.build(d["ds"].features, d["part"], d["parts"],
                            directory=str(tmp_path / "shards"),
                            host_budget_bytes=1, chunk_rows=123)
    assert st.spilled and not st.resident
    for s in range(d["parts"]):
        assert np.array_equal(np.asarray(st._backing[s]), d["table"][s])
        assert os.path.exists(tmp_path / "shards" / f"shard_{s:03d}.npy")
    # in-RAM build path lands on the classic table too
    st2 = FeatureStore.build(d["ds"].features, d["part"], d["parts"])
    assert np.array_equal(st2.as_dense(), d["table"])


# ---------------------------------------------------------------------------
# Streamed engine path
# ---------------------------------------------------------------------------

def _plan_kwargs(d, roots, **kw):
    out = dict(graph=d["ds"].graph, labels=d["ds"].labels, part=d["part"],
               owner=d["owner"], local_idx=d["local_idx"],
               local_rows=d["table"].shape[1], roots_per_model=roots,
               num_layers=2, fanout=4, strategy="hopgnn", sample_seed=7)
    out.update(kw)
    return out


def test_streamed_iteration_bitwise_matches_resident(partitioned, rng):
    """Same feature values per tree position ⇒ same grads/loss, whether the
    engine reads a resident device table or plan-carried feature blocks."""
    d = partitioned
    cfg = _cfg(d)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    roots = [rng.choice(d["ds"].train_vertices(), 8, replace=False)
             for _ in range(d["parts"])]
    plan_r = plan_iteration(**_plan_kwargs(d, roots))
    grads_r, loss_r = run_iteration(params, d["table"], plan_r, cfg)
    store = _tiered(d)
    plan_s = plan_iteration(**_plan_kwargs(d, roots), feature_store=store)
    assert plan_s.streamed and plan_s.l_max > 0
    assert plan_s.feat_local.shape == (d["parts"], plan_s.l_max,
                                       d["ds"].feature_dim)
    assert plan_s.tier_stats["tier2_rows"] > 0    # cold hot tier
    grads_s, loss_s = run_iteration(params, None, plan_s, cfg)
    assert float(loss_r) == float(loss_s)
    assert _tree_equal(grads_r, grads_s)


def test_streamed_plan_requires_pregather(partitioned):
    d = partitioned
    store = _tiered(d)
    roots = [np.arange(4) for _ in range(d["parts"])]
    with pytest.raises(ValueError, match="pregather"):
        plan_iteration(**_plan_kwargs(d, roots), feature_store=store,
                       pregather=False)


def test_non_streamed_plan_rejects_missing_table(partitioned, rng):
    d = partitioned
    cfg = _cfg(d)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    roots = [rng.choice(d["ds"].train_vertices(), 4, replace=False)
             for _ in range(d["parts"])]
    plan = plan_iteration(**_plan_kwargs(d, roots))
    with pytest.raises(ValueError, match="streamed"):
        run_iteration(params, None, plan, cfg)


def test_l_max_overflow_signals_and_budget_rebuckets(partitioned, rng):
    d = partitioned
    store = _tiered(d)
    roots = [rng.choice(d["ds"].train_vertices(), 8, replace=False)
             for _ in range(d["parts"])]
    with pytest.raises(PlanOverflow) as ei:
        plan_iteration(**_plan_kwargs(d, roots), feature_store=store,
                       l_max=1)
    assert ei.value.field == "l_max" and ei.value.needed > 1
    # the budget absorbs the overflow: one retryable grow, pow2 bucket
    b = ShapeBudget()
    plan = b.plan(**_plan_kwargs(d, roots), feature_store=store)
    assert plan.streamed
    key = len(roots)
    assert b.l_buckets[key] >= plan.l_max
    assert b.l_buckets[key] & (b.l_buckets[key] - 1) == 0
    assert b.bucket_shapes(key)[3] == b.l_buckets[key]


# ---------------------------------------------------------------------------
# Trainer integration (the correctness gates)
# ---------------------------------------------------------------------------

_RESIDENT_CONFIGS = [
    ("pregather", dict()),
    ("per_step", dict(pregather=False)),
    ("per_step_folded", dict(pregather=False, fold_returns=True)),
    ("cache_on", dict(cache_policy="degree", cache_budget_bytes=1 << 14)),
]


@pytest.mark.parametrize("name,kw", _RESIDENT_CONFIGS,
                         ids=[n for n, _ in _RESIDENT_CONFIGS])
def test_resident_store_trainer_bitwise_matches_raw_array(partitioned,
                                                          name, kw):
    """Back-compat gate: an all-resident FeatureStore IS the old feature
    path — params and losses bit-identical to handing Trainer the raw
    (N, rows, d) array, across engine modes."""
    d = partitioned
    cfg = _cfg(d)
    tr_a = _trainer(d, cfg, **kw)
    st_a = tr_a.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    tr_s = _trainer(d, cfg, table=FeatureStore.from_array(d["table"]), **kw)
    st_s = tr_s.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    assert not tr_s.streamed
    assert _tree_equal(tr_a.params, tr_s.params)
    assert [s.loss for s in st_a] == [s.loss for s in st_s]


@pytest.mark.parametrize("name,kw", [
    ("plain", dict()),
    ("cache_on", dict(cache_policy="degree", cache_budget_bytes=1 << 14)),
    ("stacked", dict(pipeline_stack=2)),
], ids=["plain", "cache_on", "stacked"])
def test_streamed_trainer_bitwise_matches_resident(partitioned, name, kw):
    """Out-of-core gate: a tiered store (streamed engine, readahead on the
    cache thread) trains bit-identically to the resident baseline."""
    d = partitioned
    cfg = _cfg(d)
    tr_r = _trainer(d, cfg, **kw)
    st_r = tr_r.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    tr_t = _trainer(d, cfg, table=_tiered(d), **kw)
    assert tr_t.streamed and tr_t.table is None
    st_t = tr_t.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    assert _tree_equal(tr_r.params, tr_t.params)
    assert [s.loss for s in st_r] == [s.loss for s in st_t]
    assert all(s.streamed for s in st_t) and not any(
        s.streamed for s in st_r)
    # tier accounting flows: gathers happened, readahead warmed tier 1
    assert st_t[0].tier1_rows + st_t[0].tier2_rows > 0
    assert st_t[1].tier1_rows > 0 and st_t[0].upload_bytes > 0


def test_streamed_trainer_rejects_per_step(partitioned):
    d = partitioned
    with pytest.raises(ValueError, match="pregather"):
        _trainer(d, _cfg(d), table=_tiered(d), pregather=False)


def test_streamed_trainer_on_disk_shards(partitioned, tmp_path):
    """End-to-end out-of-core: features only on disk (mmap tier 2), host
    hot tier under budget — losses match the resident baseline."""
    d = partitioned
    cfg = _cfg(d)
    st = FeatureStore.build(d["ds"].features, d["part"], d["parts"],
                            directory=str(tmp_path),
                            host_budget_bytes=d["table"].nbytes // 4)
    tr = _trainer(d, cfg, table=st)
    stats = tr.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    tr_r = _trainer(d, cfg)
    stats_r = tr_r.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    assert [s.loss for s in stats] == [s.loss for s in stats_r]
    assert _tree_equal(tr.params, tr_r.params)


def test_cache_refreshes_through_store_tiers(partitioned):
    """CacheStore.install_from resolves rows via the tier chain — the
    installed values must equal the raw table rows regardless of tier."""
    from repro.cache import CacheStore
    d = partitioned
    store = _tiered(d).bind(d["owner"], d["local_idx"])
    cs = CacheStore(d["parts"], d["ds"].feature_dim, c_max=8,
                    dtype=d["table"].dtype)
    sel = [np.sort(np.random.default_rng(s).choice(
        d["part"].size, 5, replace=False).astype(np.int64))
        for s in range(d["parts"])]
    cs.install_from(store, sel)
    for s in range(d["parts"]):
        expect = d["table"][d["owner"][sel[s]], d["local_idx"][sel[s]]]
        got = cs._host[s, :5]
        assert np.array_equal(got, expect)
