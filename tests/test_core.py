"""HopGNN core: planner, pre-gathering, merging, comm model, and the
gradient-parity (accuracy fidelity) invariant."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import plan_iteration, run_iteration, MergingController
from repro.core.comm_model import (ModelSpec, alpha_ratio, hopgnn_bytes,
                                   lo_bytes, model_centric_bytes,
                                   naive_fc_bytes, p3_bytes)
from repro.core.merging import merge_min_step
from repro.core.micrograph import hopgnn_assignment, model_centric_assignment
from repro.core.pregather import build_gather_plan
from repro.graph.sampler import micrograph_split, sample_tree_block
from repro.models.gnn import GNNConfig, init_gnn


def _roots(partitioned, per_model=12, seed=0):
    rng = np.random.default_rng(seed)
    tv = partitioned["ds"].train_vertices()
    return [rng.choice(tv, per_model, replace=False)
            for _ in range(partitioned["parts"])]


def _plan(partitioned, strategy, seed=7, **kw):
    d = partitioned
    return plan_iteration(
        d["ds"].graph, d["ds"].labels, d["part"], d["owner"],
        d["local_idx"], d["table"].shape[1], _roots(d),
        num_layers=2, fanout=4, strategy=strategy, sample_seed=seed, **kw)


# ---------------------------------------------------------------------------
# Assignment / redistribution
# ---------------------------------------------------------------------------

def test_hopgnn_assignment_preserves_batch_composition(partitioned):
    """§5.1: model d trains exactly its original mini-batch, only placement
    changes — the accuracy-fidelity precondition."""
    roots = _roots(partitioned)
    amat = hopgnn_assignment(roots, partitioned["part"])
    per_model = {d: [] for d in range(len(roots))}
    for (s, t), gs in amat.groups.items():
        for d, r in gs:
            per_model[d].append(r)
            # rotation schedule: model d is on server (d + t) % N at step t
            assert s == (d + t) % amat.num_shards
    for d, orig in enumerate(roots):
        got = np.sort(np.concatenate(per_model[d]))
        np.testing.assert_array_equal(got, np.sort(orig))


def test_root_redistribution_by_home(partitioned):
    roots = _roots(partitioned)
    amat = hopgnn_assignment(roots, partitioned["part"])
    for (s, t), gs in amat.groups.items():
        for d, r in gs:
            assert np.all(partitioned["part"][r] == s)  # homed correctly


# ---------------------------------------------------------------------------
# Pre-gathering (§5.2)
# ---------------------------------------------------------------------------

@given(st.integers(2, 6), st.integers(1, 40), st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_gather_plan_is_deduped_cover(n_shards, n_ids, seed):
    rng = np.random.default_rng(seed)
    n_vertices = 100
    owner = rng.integers(0, n_shards, n_vertices).astype(np.int32)
    local_idx = np.zeros(n_vertices, np.int32)
    for s in range(n_shards):
        ids = np.nonzero(owner == s)[0]
        local_idx[ids] = np.arange(ids.size)
    needed = [rng.integers(0, n_vertices, n_ids) for _ in range(n_shards)]
    plan = build_gather_plan(needed, owner, local_idx, n_shards,
                             local_rows=int(np.bincount(owner).max()))
    for s in range(n_shards):
        # every remote id needed has a slot; no remote id fetched twice
        remote = np.unique(needed[s][owner[needed[s]] != s])
        np.testing.assert_array_equal(plan.slot_map.shard_ids(s), remote)
        assert np.unique(plan.slot_map.shard_slots(s)).size == remote.size
        assert plan.req_count[s].sum() == remote.size      # dedup exact
        assert plan.req_count[s, s] == 0                   # never self-fetch


def test_pregather_saves_vs_per_step(partitioned):
    """§5.2: deduped cross-step fetch count ≤ per-step fetch count."""
    p_pre = _plan(partitioned, "hopgnn", pregather=True)
    p_per = _plan(partitioned, "hopgnn", pregather=False)
    assert p_pre.remote_rows_exact <= p_per.remote_rows_exact
    assert p_pre.remote_rows_exact <= p_pre.remote_rows_nodedup


def test_hopgnn_beats_model_centric_on_miss_rate(partitioned):
    """Fig. 14: micrograph training cuts the remote-feature miss rate."""
    ph = _plan(partitioned, "hopgnn")
    pm = _plan(partitioned, "model_centric")
    assert ph.miss_rate() < pm.miss_rate()


def test_lo_zero_remote(partitioned):
    pl = _plan(partitioned, "lo")
    assert pl.remote_rows_exact == 0
    assert pl.num_steps == 1


# ---------------------------------------------------------------------------
# Gradient parity (Table 3 as a theorem, not a statistic)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_gradient_parity_hopgnn_vs_model_centric(partitioned, model):
    d = partitioned
    cfg = GNNConfig(model=model, num_layers=2, hidden_dim=32,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    gm, lm = run_iteration(params, d["table"],
                           _plan(d, "model_centric"), cfg)
    gh, lh = run_iteration(params, d["table"], _plan(d, "hopgnn"), cfg)
    assert abs(float(lm) - float(lh)) < 1e-4
    for a, b in zip(jax.tree.leaves(gm), jax.tree.leaves(gh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_per_step_batched_exchange_gradient_parity(partitioned):
    """Per-step mode (batched index exchange hoisted ahead of the scan)
    must train bit-identically to pregather mode: same tree blocks, same
    feature rows, only the fetch schedule differs."""
    d = partitioned
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    gp, lp = run_iteration(params, d["table"],
                           _plan(d, "hopgnn", pregather=True), cfg)
    gs, ls = run_iteration(params, d["table"],
                           _plan(d, "hopgnn", pregather=False), cfg)
    assert float(lp) == float(ls)                  # bit-identical loss
    for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lo_gradient_differs(partitioned):
    """The LO baseline *changes* batch composition — its gradient must NOT
    match (that's the bias the paper warns about in §7.9)."""
    d = partitioned
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=32,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    gm, _ = run_iteration(params, d["table"], _plan(d, "model_centric"), cfg)
    gl, _ = run_iteration(params, d["table"], _plan(d, "lo"), cfg)
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(gm), jax.tree.leaves(gl))]
    assert max(diffs) > 1e-6


# ---------------------------------------------------------------------------
# Merging (§5.3)
# ---------------------------------------------------------------------------

def test_merge_min_step_conserves_roots(partitioned):
    roots = _roots(partitioned)
    amat = hopgnn_assignment(roots, partitioned["part"])
    merged = merge_min_step(amat)
    assert merged.num_steps == amat.num_steps - 1
    # per-model totals conserved (Fig. 10 invariant)
    np.testing.assert_array_equal(amat.model_step_counts().sum(0),
                                  merged.model_step_counts().sum(0))


def test_merge_min_step_dedupes_duplicate_target_slots():
    """A model with several groups at one (server, step) slot — the normal
    state after a previous merge round — must count that slot once when the
    folded roots are redistributed, or array_split over-weights it."""
    from repro.core.micrograph import AssignmentMatrix
    groups = {
        (0, 0): [(0, np.arange(0, 3)), (0, np.arange(3, 6))],  # dup slot
        (1, 1): [(0, np.arange(6, 9))],
        (0, 2): [(0, np.arange(9, 13))],                       # folded step
    }
    amat = AssignmentMatrix(num_shards=2, num_steps=3, groups=groups)
    merged = merge_min_step(amat, ts_min=2)
    assert merged.num_steps == 2
    # exact conservation of the model's roots
    got = np.sort(np.concatenate(
        [r for gs in merged.groups.values() for _, r in gs]))
    np.testing.assert_array_equal(got, np.arange(13))
    # even redistribution over the two *distinct* slots: 2 roots each
    # (the duplicated (0,0) slot previously got 3 of the 4)
    assert merged.roots_at(0, 0).size == 6 + 2
    assert merged.roots_at(1, 1).size == 3 + 2


def test_merging_controller_freezes_on_regression():
    roots = [np.arange(8) * 4 + i for i in range(4)]
    part = np.arange(64) % 4
    base = hopgnn_assignment(roots, part.astype(np.int32))
    ctl = MergingController(base=base)
    ctl.record_epoch_time(10.0)      # epoch 0 baseline
    s1 = ctl.assignment_for_epoch().num_steps
    ctl.record_epoch_time(8.0)       # improved -> merge again
    s2 = ctl.assignment_for_epoch().num_steps
    ctl.record_epoch_time(9.0)       # regressed -> revert to s1 + freeze
    assert ctl.frozen
    assert ctl.assignment_for_epoch().num_steps == s1  # pre-regression wins
    assert s2 == s1 - 1


def test_micrograph_locality_stats_per_root_home():
    """Locality must be scored against each root's own home server, not the
    first root's: a 2-root block whose subtrees are each fully local to
    their own root is 100 % local."""
    from repro.core.micrograph import micrograph_locality_stats
    part = np.array([0, 0, 1, 1])
    hops = [np.array([0, 2]),              # roots homed at 0 and 1
            np.array([0, 1, 2, 3])]        # each root's children all local
    local, remote = micrograph_locality_stats([hops], part)
    assert local == 1.0 and remote == 0.0
    # mixed case: root 1's children live on server 0 -> half remote
    hops2 = [np.array([0, 2]), np.array([0, 1, 0, 1])]
    local2, remote2 = micrograph_locality_stats([hops2], part)
    assert local2 == 0.5 and remote2 == 0.5


# ---------------------------------------------------------------------------
# Comm model (Fig. 5 / Fig. 7)
# ---------------------------------------------------------------------------

def _blocks_for(partitioned, seed=0):
    d = partitioned
    rng = np.random.default_rng(seed)
    roots = rng.integers(0, d["ds"].num_vertices, 16)
    blk = sample_tree_block(d["ds"].graph, roots, 3, 4, seed=5)
    micros = micrograph_split(blk)
    shard_of = [int(rng.integers(0, d["parts"])) for _ in micros]
    return micros, shard_of


def test_comm_model_strategies_ordered(partitioned):
    """Fig. 7/11 ordering on a locality partition: lo < hopgnn(SPMD) <
    model-centric, and naive pays intermediate-data overhead."""
    d = partitioned
    micros, shard_of = _blocks_for(d)
    spec = ModelSpec(feature_dim=128, hidden_dim=128, num_layers=3,
                     param_bytes=200_000)
    mc = model_centric_bytes(micros, d["owner"], shard_of, spec, d["parts"])
    nv = naive_fc_bytes(micros, d["owner"], spec, d["parts"])
    hp = hopgnn_bytes(int(mc["remote_rows"] * 0.4), d["parts"], spec,
                      d["parts"], replicated_params=True)
    lo = lo_bytes(spec, d["parts"])
    assert lo["total"] <= hp["total"] <= mc["total"]
    assert nv["intermediate_bytes"] > 0 and nv["migrations"] > 0
    p3 = p3_bytes(micros, d["owner"], shard_of, spec, d["parts"])
    assert p3["feature_bytes"] == 0          # P³ never ships raw features


def test_alpha_ratio_regime():
    """Fig. 5: α ≫ 1 for realistic GNN shapes (the motivation)."""
    # 3-layer subgraph, fanout 10, batch 1024 roots, dim 128 features
    remote_rows = 1024 * (10 + 100 + 1000) // 2
    a = alpha_ratio(remote_rows, 128, param_bytes=200_000)
    assert a > 13.4          # the paper's observed minimum


def test_hopgnn_paper_faithful_migration_cost():
    spec = ModelSpec(feature_dim=600, hidden_dim=16, num_layers=3,
                     param_bytes=50_000)
    faithful = hopgnn_bytes(1000, 4, spec, 4, replicated_params=False)
    spmd = hopgnn_bytes(1000, 4, spec, 4, replicated_params=True)
    assert faithful["model_bytes"] > 0 and spmd["model_bytes"] == 0
    assert faithful["total"] > spmd["total"]


# ---------------------------------------------------------------------------
# Executable P³ baseline (core/p3.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_p3_gradient_parity(partitioned, model):
    """P³'s dim-sliced input layer + psum is placement-only: gradients must
    equal model-centric training exactly (like HopGNN's parity)."""
    import jax.numpy as jnp
    from repro.core.p3 import plan_p3, run_p3_iteration
    d = partitioned
    cfg = GNNConfig(model=model, num_layers=2, hidden_dim=32,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    roots = _roots(d)
    gm, lm = run_iteration(params, d["table"],
                           _plan(d, "model_centric"), cfg)
    p3p = plan_p3(d["ds"].graph, d["ds"].labels, roots, num_layers=2,
                  fanout=4, hidden_dim=32, sample_seed=7)
    g3, l3 = run_p3_iteration(params, jnp.asarray(d["ds"].features),
                              p3p, cfg)
    assert abs(float(lm) - float(l3)) < 1e-4
    for a, b in zip(jax.tree.leaves(gm), jax.tree.leaves(g3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_p3_rejects_norm_fronted_models(partitioned):
    from repro.core.p3 import P3Unsupported, plan_p3, run_p3_iteration
    import jax.numpy as jnp
    d = partitioned
    cfg = GNNConfig(model="deepgcn", num_layers=2, hidden_dim=32,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    p3p = plan_p3(d["ds"].graph, d["ds"].labels, _roots(d), num_layers=2,
                  fanout=4, hidden_dim=32)
    with pytest.raises(P3Unsupported):
        run_p3_iteration(params, jnp.asarray(d["ds"].features), p3p, cfg)


def test_p3_never_moves_raw_features(partitioned):
    """P³'s activation bytes scale with hidden dim, never feature dim —
    the structural property behind its hidden-dim sensitivity (§7.2)."""
    from repro.core.p3 import plan_p3
    d = partitioned
    roots = _roots(d)
    small = plan_p3(d["ds"].graph, d["ds"].labels, roots, 2, 4,
                    hidden_dim=16, sample_seed=1)
    big = plan_p3(d["ds"].graph, d["ds"].labels, roots, 2, 4,
                  hidden_dim=256, sample_seed=1)
    assert big.activation_bytes() == 16 * small.activation_bytes()
