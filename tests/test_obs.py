"""repro.obs: span tracing, unified metrics registry, Perfetto export.

* recorder mechanics — nesting depth, disabled fast path, ring
  wraparound + dropped accounting, per-thread tracks, track override,
* registry — counter/gauge/histogram snapshot/delta (incl. instruments
  created after the snapshot),
* export — chrome-trace schema validity, manifests, JSONL round-trip,
* the training stack — bit-parity with tracing on (params + losses),
  lenient overhead bound (the strict 1.05x gate lives in
  benchmarks/obs.py, CI-gated), four-track + span coverage of a
  pipelined + cached run, EpochStats publication, TierStats/registry
  write-through, fault marks in the exported timeline.

The registry is process-global and cumulative across the suite, so all
assertions here are delta-based. Under the chaos lane
(REPRO_CHAOS_SEED) background faults add their own marks and retries —
tests assert presence, never absence.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.features import FeatureStore
from repro.models.gnn import GNNConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.export import (chrome_trace, config_digest, run_manifest,
                              trace_span_names, trace_track_names,
                              validate_chrome_trace, write_metrics_jsonl)
from repro.optim import adam
from repro.resilience import (FaultPlan, FaultSpec, RetryPolicy,
                              TransientCommError, resilient_call)
from repro.train import Trainer


@pytest.fixture(autouse=True)
def _trace_reset():
    """Tracing state is module-global: leave every test with the
    recorder off and drained so tier-1 neighbours see the seed state."""
    yield
    obs_trace.disable()
    obs_trace.clear()


def _cfg(d):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=16,
                     feature_dim=d["ds"].feature_dim,
                     num_classes=d["ds"].num_classes, fanout=4)


def _trainer(d, cfg, **kw):
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    kw.setdefault("table", d["table"])
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"], cfg=cfg, **kw)


# ---------------------------------------------------------------------------
# Recorder mechanics
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_order():
    obs_trace.enable()
    with obs_trace.span("outer", epoch=0):
        with obs_trace.span("inner"):
            pass
    recs = obs_trace.records()
    assert [r.name for r in recs] == ["outer", "inner"]
    outer, inner = recs
    assert outer.depth == 0 and inner.depth == 1
    assert outer.t0_ns <= inner.t0_ns and inner.t1_ns <= outer.t1_ns
    assert outer.tags == {"epoch": 0} and inner.tags is None
    assert outer.kind == "X" and outer.dur_ns >= 0


def test_disabled_is_shared_noop():
    obs_trace.disable()
    obs_trace.clear()
    s1 = obs_trace.span("hot", it=3)
    s2 = obs_trace.span("other")
    assert s1 is s2                       # one shared object, no alloc
    with s1:
        obs_trace.event("mark")
    assert obs_trace.records() == []
    assert not obs_trace.is_enabled()


def test_ring_wraparound_reports_dropped():
    obs_trace.enable(capacity=8)
    for i in range(20):
        obs_trace.event("e", idx=i)
    recs = obs_trace.records()
    assert len(recs) == 8                 # oldest overwritten, newest kept
    assert [r.tags["idx"] for r in recs] == list(range(12, 20))
    assert obs_trace.dropped() == 12


def test_clear_drops_records_keeps_state():
    obs_trace.enable()
    obs_trace.event("before")
    obs_trace.clear()
    assert obs_trace.records() == []
    assert obs_trace.is_enabled()
    obs_trace.event("after")
    assert [r.name for r in obs_trace.records()] == ["after"]


def test_threaded_recording_is_lossless_per_track():
    obs_trace.enable(capacity=4096)
    n_threads, n_spans = 4, 200

    def work(i):
        for k in range(n_spans):
            with obs_trace.span(f"w{i}", idx=k):
                pass

    ts = [threading.Thread(target=work, args=(i,), name=f"tsworker-{i}")
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    recs = obs_trace.records()
    for i in range(n_threads):
        mine = [r for r in recs if r.name == f"w{i}"]
        assert len(mine) == n_spans
        assert {r.track for r in mine} == {f"tsworker-{i}"}
    assert obs_trace.dropped() == 0


def test_track_override_records_virtual_lane():
    obs_trace.enable()
    with obs_trace.span("upload.commit", track="uploader", it=1):
        pass
    (rec,) = obs_trace.records()
    assert rec.track == "uploader"        # not MainThread
    doc = chrome_trace()
    assert "uploader" in trace_track_names(doc)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_snapshot_delta():
    reg = obs_metrics.registry()
    snap = reg.snapshot()
    # instruments created AFTER the snapshot must delta from 0
    obs_metrics.inc("testobs.a", 2)
    obs_metrics.inc("testobs.a", 3)
    obs_metrics.set_gauge("testobs.g", 7.5)
    obs_metrics.observe("testobs.h", 1.0)
    obs_metrics.observe("testobs.h", 3.0)
    d = reg.delta(snap)
    assert d["counters"]["testobs.a"] == 5
    assert d["gauges"]["testobs.g"] == 7.5
    assert d["histograms"]["testobs.h"]["count"] == 2
    assert d["histograms"]["testobs.h"]["total"] == 4.0
    h = reg.histogram("testobs.h").summary()
    assert h["mean"] == 2.0 and h["min"] == 1.0 and h["max"] == 3.0
    snap2 = reg.snapshot()
    obs_metrics.inc("testobs.a")
    assert reg.delta(snap2)["counters"]["testobs.a"] == 1


def test_registry_counter_thread_safe():
    reg = obs_metrics.registry()
    snap = reg.snapshot()

    def bump():
        for _ in range(500):
            obs_metrics.inc("testobs.race")

    ts = [threading.Thread(target=bump) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.delta(snap)["counters"]["testobs.race"] == 2000


# ---------------------------------------------------------------------------
# Export: manifests, schema, JSONL
# ---------------------------------------------------------------------------

def test_config_digest_stable_and_order_free():
    a = config_digest({"a": 1, "b": [2, 3]})
    b = config_digest({"b": [2, 3], "a": 1})
    assert a == b and len(a) == 12
    assert config_digest({"a": 2, "b": [2, 3]}) != a


def test_run_manifest_keys():
    m = run_manifest(seed=7, config={"x": 1}, extra={"note": "t"})
    for k in ("git_sha", "python", "jax", "numpy", "platform", "argv",
              "time_unix"):
        assert k in m, k
    assert m["seed"] == 7 and m["note"] == "t"
    assert len(m["config_digest"]) == 12


def test_chrome_trace_schema_valid():
    obs_trace.enable()
    with obs_trace.span("a", epoch=0):
        with obs_trace.span("b", track="uploader"):
            pass
    obs_trace.event("fault.test", site="x")
    doc = chrome_trace(manifest=run_manifest(seed=1, config={"k": 1}))
    assert validate_chrome_trace(doc) == []
    assert {"main", "uploader"} <= trace_track_names(doc)
    assert trace_span_names(doc) == {"a", "b"}
    assert doc["metadata"]["seed"] == 1
    assert "config_digest" in doc["metadata"]
    instants = {ev["name"] for ev in doc["traceEvents"]
                if ev.get("ph") == "i"}
    assert "fault.test" in instants
    assert doc["otherData"]["span_records"] == 3


def test_validate_catches_defects():
    bad = {"traceEvents": [{"name": "x", "ph": "X", "pid": 1, "tid": 9,
                            "ts": -1.0, "dur": 1.0}],
           "metadata": {}}
    problems = validate_chrome_trace(bad)
    assert any("bad ts" in p for p in problems)
    assert any("thread_name" in p for p in problems)
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]


def test_write_metrics_jsonl_roundtrip(tmp_path):
    p = write_metrics_jsonl(tmp_path / "m.jsonl", [{"a": 1}, {"b": "x"}],
                            manifest={"git_sha": "deadbeef"})
    lines = [json.loads(line) for line in p.read_text().splitlines()]
    assert lines[0] == {"manifest": {"git_sha": "deadbeef"}}
    assert lines[1:] == [{"a": 1}, {"b": "x"}]


def test_bench_json_carries_manifest(tmp_path):
    from benchmarks.common import Bench
    b = Bench("obstest")
    b.emit("case", "metric", 1)
    out = json.loads(b.save_json(path=tmp_path / "B.json",
                                 seed=5).read_text())
    assert out["results"]["case"]["metric"] == 1
    m = out["manifest"]
    assert {"git_sha", "python", "jax", "numpy", "platform"} <= set(m)
    assert m["seed"] == 5


# ---------------------------------------------------------------------------
# Training stack: parity, coverage, publication
# ---------------------------------------------------------------------------

EPOCHS, ITERS, BATCH = 3, 4, 8


@pytest.fixture(scope="module")
def traced_pair(partitioned):
    """One pipelined + cached config run twice — tracing off (reference)
    then on — with the on-run's registry delta, drained records, and
    exported document captured eagerly."""
    d = partitioned
    cfg = _cfg(d)
    kw = dict(cache_policy="lfu", cache_budget_bytes=1 << 20,
              loss_sync_iters=2)
    obs_trace.disable()
    obs_trace.clear()
    tr_off = _trainer(d, cfg, **kw)
    st_off = tr_off.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                        batch_per_model=BATCH)
    snap = obs_metrics.registry().snapshot()
    obs_trace.enable()
    try:
        tr_on = _trainer(d, cfg, **kw)
        st_on = tr_on.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                          batch_per_model=BATCH)
    finally:
        obs_trace.disable()
    delta = obs_metrics.registry().delta(snap)
    recs = obs_trace.records()
    doc = chrome_trace(manifest=run_manifest(seed=0))
    obs_trace.clear()
    return dict(tr_off=tr_off, tr_on=tr_on, st_off=st_off, st_on=st_on,
                delta=delta, recs=recs, doc=doc)


def test_tracing_is_bit_neutral(traced_pair):
    """Tracing on must be bit-identical to tracing off: losses exact,
    every parameter leaf bit-equal (tracing only reads clocks)."""
    tp = traced_pair
    assert [s.loss for s in tp["st_on"]] == [s.loss for s in tp["st_off"]]
    for a, b in zip(jax.tree.leaves(tp["tr_off"].params),
                    jax.tree.leaves(tp["tr_on"].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tracing_overhead_lenient(traced_pair):
    """Loose tier-1 bound on a noisy shared core; the strict 1.05x
    steady-iteration gate runs in benchmarks/obs.py under CI."""
    tp = traced_pair
    off = min(s.steady_time_s for s in tp["st_off"][1:])
    on = min(s.steady_time_s for s in tp["st_on"][1:])
    assert on <= 2.0 * off, (on, off)


def test_trace_covers_four_tracks_and_iteration_spans(traced_pair):
    doc = traced_pair["doc"]
    assert validate_chrome_trace(doc) == []
    assert {"main", "prefetch", "uploader",
            "cache+readahead"} <= trace_track_names(doc)
    assert {"plan.build", "plan.wait", "upload.commit", "dispatch",
            "loss.sync", "cache.refresh",
            "cache.forecast"} <= trace_span_names(doc)


def test_pipelined_spans_nest_sanely(traced_pair):
    recs = traced_pair["recs"]
    assert all(r.depth >= 0 for r in recs)
    builds = [r for r in recs if r.name == "plan.build"]
    assert builds and all(r.track.startswith("prefetch") for r in builds)
    # planner fan-out work nests under plan.build when run inline (1-core
    # container) or lands on its own planner track when a pool exists
    samples = [r for r in recs if r.name == "plan.sample"]
    assert samples
    assert all(r.depth >= 1 or r.track.startswith("plan") for r in samples)
    commits = [r for r in recs if r.name == "upload.commit"]
    assert commits and {r.track for r in commits} == {"uploader"}


def test_epoch_stats_published_to_registry(traced_pair):
    tp = traced_pair
    d = tp["delta"]
    assert d["histograms"]["epoch.time_s"]["count"] == EPOCHS
    assert obs_metrics.registry().gauge("epoch.loss").value == \
        tp["st_on"][-1].loss
    assert d["counters"]["epoch.remote_rows"] == \
        sum(s.remote_rows for s in tp["st_on"])
    assert d["counters"]["epoch.cache_hit_rows"] == \
        sum(s.cache_hit_rows for s in tp["st_on"])
    # cache mutations land too (installs happen on the worker thread)
    assert d["counters"].get("cache.installs", 0) >= 1


# ---------------------------------------------------------------------------
# Counter-surface unification (TierStats / CommCounters / faults / ckpt)
# ---------------------------------------------------------------------------

def test_tierstats_registry_write_through(partitioned, tmp_path):
    d = partitioned
    store = FeatureStore.build(
        np.asarray(d["ds"].features), d["part"], d["parts"],
        directory=str(tmp_path),
        host_budget_bytes=max(1, int(d["table"].nbytes) // 8))
    reg = obs_metrics.registry()
    snap = reg.snapshot()
    s0 = (store.stats.t1_rows, store.stats.t2_rows, store.stats.gathers,
          store.stats.readahead_rows)
    store.gather(0, np.arange(8))
    store.readahead(0, np.arange(8))
    delta = reg.delta(snap)["counters"]
    s1 = (store.stats.t1_rows, store.stats.t2_rows, store.stats.gathers,
          store.stats.readahead_rows)
    assert delta.get("features.t1_rows", 0) + \
        delta.get("features.t2_rows", 0) == (s1[0] - s0[0]) + (s1[1] - s0[1])
    assert delta["features.gathers"] == s1[2] - s0[2]
    assert delta["features.readahead_rows"] == s1[3] - s0[3] > 0


def test_resilient_call_lands_on_registry_and_trace():
    obs_trace.enable()
    reg = obs_metrics.registry()
    snap = reg.snapshot()
    state = {"calls": 0}

    def flaky():
        state["calls"] += 1
        if state["calls"] == 1:
            raise TransientCommError("injected")
        return 42

    out = resilient_call(flaky, policy=RetryPolicy(backoff_s=0.0001),
                         epoch=1, it=2)
    assert out == 42
    assert reg.delta(snap)["counters"]["comm.retries"] >= 1
    marks = [r for r in obs_trace.records()
             if r.kind == "i" and r.name == "comm.retry"]
    assert marks and marks[0].tags["attempt"] == 0
    assert marks[0].tags["epoch"] == 1 and marks[0].tags["it"] == 2


def test_fault_marks_appear_in_exported_trace(partitioned):
    """A faulted run's timeline must carry the injected-fault instant
    marks (tagged site/epoch/it) and the registry must count firings."""
    d = partitioned
    fp = FaultPlan([FaultSpec("comm_delay", epoch=0, it=1, delay_s=0.002),
                    FaultSpec("comm_drop", epoch=1, it=2, drops=1)])
    reg = obs_metrics.registry()
    snap = reg.snapshot()
    obs_trace.enable()
    try:
        tr = _trainer(d, _cfg(d))
        with fp.active():
            tr.fit(epochs=2, iters_per_epoch=4, batch_per_model=8)
    finally:
        obs_trace.disable()
    assert fp.fired_count() >= 2
    doc = chrome_trace()
    assert validate_chrome_trace(doc) == []
    instants = {ev["name"] for ev in doc["traceEvents"]
                if ev.get("ph") == "i"}
    assert {"fault.comm_delay", "fault.comm_drop"} <= instants
    delta = reg.delta(snap)["counters"]
    assert delta["faults.fired"] >= 2
    assert delta["faults.comm_delay"] >= 1
    assert delta["faults.comm_drop"] >= 1
    # the dropped exchange was retried, and the retry is on the registry
    assert delta["comm.retries"] >= 1


def test_readahead_spans_on_streamed_store(partitioned, tmp_path):
    d = partitioned
    store = FeatureStore.build(
        np.asarray(d["ds"].features), d["part"], d["parts"],
        directory=str(tmp_path),
        host_budget_bytes=max(1, int(d["table"].nbytes) // 4))
    obs_trace.enable()
    try:
        tr = _trainer(d, _cfg(d), table=store)
        tr.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    finally:
        obs_trace.disable()
    names = {r.name for r in obs_trace.records() if r.kind == "X"}
    assert {"features.readahead", "features.readahead.forecast"} <= names


def test_checkpoint_spans_and_counters(tmp_path):
    tree = {"w": np.arange(4.0, dtype=np.float32),
            "b": np.zeros(2, np.float32)}
    obs_trace.enable()
    snap = obs_metrics.registry().snapshot()
    save_checkpoint(tmp_path, 3, tree)
    restored, step, _ = load_checkpoint(tmp_path, tree)
    obs_trace.disable()
    names = {r.name for r in obs_trace.records() if r.kind == "X"}
    assert {"ckpt.save", "ckpt.load"} <= names
    delta = obs_metrics.registry().delta(snap)["counters"]
    assert delta["ckpt.saves"] == 1 and delta["ckpt.loads"] == 1
    assert step == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])
