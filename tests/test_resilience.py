"""repro.resilience: fault injection, supervised recovery, degradation.

The contract under test everywhere here is *bit-identity*: the stack's
determinism (plans are pure functions of (epoch, it, seeds, pattern,
cache_version); every pipeline/cache/tier mode is bit-identical to its
fallback) means an absorbed fault must leave zero numerical trace. Each
test injects a fault class, asserts it actually fired, and asserts the
run's losses/parameters equal the fault-free run's exactly.
"""
import json
import subprocess
import sys
import textwrap
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.features import CorruptFeatureError, FeatureStore
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.resilience import (BackgroundError, ChaosPlan,
                              CheckpointRollbackExhausted, CommCounters,
                              CommTimeout, FaultPlan, FaultSpec,
                              ResiliencePolicy, RetryPolicy,
                              ThreadSupervisor, TransientCommError,
                              resilient_call)
from repro.train import Trainer

ROOT = Path(__file__).resolve().parents[1]


def _cfg(d):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=16,
                     feature_dim=d["ds"].feature_dim,
                     num_classes=d["ds"].num_classes, fanout=4)


def _trainer(d, cfg, **kw):
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"], table=d["table"], cfg=cfg, **kw)


def _losses(stats):
    return [s.loss for s in stats]


def _run(d, plan=None, epochs=2, iters=4, **kw):
    tr = _trainer(d, _cfg(d), **kw)
    if plan is not None:
        with plan.active():
            stats = tr.fit(epochs=epochs, iters_per_epoch=iters,
                           batch_per_model=8)
    else:
        stats = tr.fit(epochs=epochs, iters_per_epoch=iters,
                       batch_per_model=8)
    return tr, stats


def _assert_params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def clean(partitioned):
    """Fault-free baseline for the default (resident, pipelined) config."""
    tr, stats = _run(partitioned)
    return tr, _losses(stats)


# ---------------------------------------------------------------------------
# Chaos parity, one fault class at a time
# ---------------------------------------------------------------------------

def test_comm_delay_is_absorbed(partitioned, clean):
    fp = FaultPlan([FaultSpec("comm_delay", epoch=0, it=1, delay_s=0.002),
                    FaultSpec("comm_delay", epoch=1, it=2, delay_s=0.002)])
    tr, stats = _run(partitioned, fp)
    assert fp.fired_count() == 2
    assert _losses(stats) == clean[1]
    assert all(s.epoch_attempts == 1 for s in stats)   # pure wall-clock
    _assert_params_equal(tr, clean[0])


def test_comm_drop_is_retried(partitioned, clean):
    fp = FaultPlan([FaultSpec("comm_drop", epoch=0, it=2, drops=2,
                              once=False)])
    _, stats = _run(partitioned, fp)
    assert _losses(stats) == clean[1]
    assert stats[0].comm_retries >= 2
    assert stats[0].comm_timeouts == 0


def test_prefetch_thread_death_replays_bit_identical(partitioned, clean):
    fp = FaultPlan([FaultSpec("thread_exc", epoch=1, it=1,
                              site="prefetch")])
    tr, stats = _run(partitioned, fp)
    assert fp.fired_count() == 1
    assert _losses(stats) == clean[1]
    assert stats[1].bg_errors >= 1
    assert stats[1].epoch_attempts == 2        # one in-mode replay
    assert tr.pipeline                          # no degradation needed
    _assert_params_equal(tr, clean[0])


def test_nan_loss_rolls_back_and_replays(partitioned, clean):
    fp = FaultPlan([FaultSpec("nan_loss", epoch=1, it=1)])
    tr, stats = _run(partitioned, fp)
    assert _losses(stats) == clean[1]
    assert stats[1].rollbacks == 1
    assert np.isfinite(stats[1].loss)
    _assert_params_equal(tr, clean[0])


def test_nan_divergence_exhausts_rollbacks(partitioned):
    # a NaN that re-fires on every replay is genuine divergence
    fp = FaultPlan([FaultSpec("nan_loss", epoch=0, it=0, once=False)])
    with pytest.raises(CheckpointRollbackExhausted):
        _run(partitioned, fp)


def test_stalled_prefetch_hits_deadline_and_recovers(partitioned, clean):
    policy = ResiliencePolicy(stall_deadline_s=0.25)
    fp = FaultPlan([FaultSpec("thread_stall", epoch=0, it=0,
                              site="prefetch", delay_s=1.0)])
    _, stats = _run(partitioned, fp, resilience=policy)
    assert _losses(stats) == clean[1]
    assert stats[0].epoch_attempts >= 2


def test_persistent_comm_drop_raises_comm_timeout(partitioned):
    fp = FaultPlan([FaultSpec("comm_drop", epoch=0, it=1, drops=99,
                              once=False)])
    with pytest.raises(CommTimeout):
        _run(partitioned, fp,
             resilience=ResiliencePolicy(
                 retry=RetryPolicy(max_retries=2, backoff_s=0.001)))


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------

def test_persistent_prefetch_fault_degrades_to_sync(partitioned, clean):
    """A prefetch thread that dies on every submission must walk the
    ladder: replay in-mode, then pipeline→sync with inline planning —
    which stops tripping the fault (inline planning is not the 'prefetch'
    site) and stays bit-identical by the pipeline≡sync gate."""
    fp = FaultPlan([FaultSpec("thread_exc", epoch=0, it=-1,
                              site="prefetch", once=False)])
    tr, stats = _run(partitioned, fp)
    assert _losses(stats) == clean[1]
    assert "pipeline_to_sync" in tr.degradations_taken
    assert not tr.pipeline
    assert stats[0].epoch_attempts >= 3
    _assert_params_equal(tr, clean[0])


def test_persistent_cache_fault_degrades_to_cache_off(partitioned):
    # the epoch-(e+1) cache compute runs during epoch e, so where the
    # failure surfaces depends on thread timing; two failing epochs make
    # the second site failure — and the cache_off rung — certain
    ctr, cs = _run(partitioned, epochs=4, cache_policy="lfu",
                   cache_budget_bytes=1 << 18)
    fp = FaultPlan([FaultSpec("thread_exc", epoch=1, site="cache",
                              once=False),
                    FaultSpec("thread_exc", epoch=2, site="cache",
                              once=False)])
    tr, stats = _run(partitioned, fp, epochs=4, cache_policy="lfu",
                     cache_budget_bytes=1 << 18)
    assert _losses(stats) == _losses(cs)       # cache parity gate
    assert "cache_off" in tr.degradations_taken
    assert tr.cache_store is None
    _assert_params_equal(tr, ctr)


def test_persistent_readahead_fault_degrades_to_resident_gather(
        partitioned):
    d = partitioned

    def tiered():
        return FeatureStore.build(d["ds"].features, d["part"], d["parts"],
                                  host_budget_bytes=1 << 20)
    ctr, cs = _run(dict(d, table=tiered()), epochs=4)
    fp = FaultPlan([FaultSpec("thread_exc", epoch=1, site="readahead",
                              once=False),
                    FaultSpec("thread_exc", epoch=2, site="readahead",
                              once=False)])
    tr, stats = _run(dict(d, table=tiered()), fp, epochs=4)
    assert _losses(stats) == _losses(cs)       # tier parity gate
    assert "resident_gather" in tr.degradations_taken
    assert tr.store.hot_bypass and not tr._readahead_enabled
    _assert_params_equal(tr, ctr)


# ---------------------------------------------------------------------------
# The headline gate: mixed recoverable plan, streamed store, bit-identical
# ---------------------------------------------------------------------------

def test_recoverable_faultplan_headline_gate(partitioned, tmp_path):
    """Thread kill + straggler + dropped exchange + corrupted disk rows +
    a NaN step, all in one run over the full streamed stack — training
    completes with losses AND parameters bit-identical to fault-free."""
    d = partitioned

    def run(plan, directory):
        store = FeatureStore.build(
            d["ds"].features, d["part"], d["parts"], directory=directory,
            host_budget_bytes=1 << 20, crc_chunk_rows=64)
        return _run(dict(d, table=store), plan, epochs=3, iters=6)

    tr1, cs = run(None, str(tmp_path / "clean"))
    fp = FaultPlan.recoverable(seed=3)
    tr2, fs = run(fp, str(tmp_path / "faulty"))

    kinds = {k for (k, *_rest) in fp.fired}
    assert kinds == {"thread_exc", "comm_delay", "comm_drop",
                     "disk_corrupt", "nan_loss"}
    assert _losses(fs) == _losses(cs)
    _assert_params_equal(tr1, tr2)
    assert tr2.store.stats.crc_failures >= 1       # corruption was caught
    assert tr2.store.stats.repaired_rows >= 1      # ...and repaired
    assert sum(s.rollbacks for s in fs) == 1


# ---------------------------------------------------------------------------
# ChaosPlan
# ---------------------------------------------------------------------------

def test_chaos_plan_is_deterministic_and_transient_only():
    a, b = ChaosPlan(seed=11, rate=0.5), ChaosPlan(seed=11, rate=0.5)
    for e in range(4):
        for i in range(16):
            sa = a._take("comm_delay", e, i)
            sb = b._take("comm_delay", e, i)
            assert [s.delay_s for s in sa] == [s.delay_s for s in sb]
    assert a._take("disk_corrupt", 0, 0) == []     # never destructive
    assert a._take("nan_loss", 0, 0) == []
    assert a._take("thread_exc", 0, 0) == []


def test_training_under_chaos_is_bit_identical(partitioned, clean):
    tr, stats = _run(partitioned, ChaosPlan(seed=5, rate=0.5,
                                            max_delay_s=0.001))
    assert _losses(stats) == clean[1]
    _assert_params_equal(tr, clean[0])


# ---------------------------------------------------------------------------
# Supervisor + retry wrapper units
# ---------------------------------------------------------------------------

def test_supervisor_surfaces_background_error_with_context():
    sup = ThreadSupervisor()
    pool = ThreadPoolExecutor(max_workers=1)
    try:
        def boom():
            raise RuntimeError("dead worker")
        fut = sup.submit(pool.submit, "prefetch", boom, epoch=3, it=7)
        with pytest.raises(BackgroundError) as ei:
            fut.result(timeout=5)
        assert ei.value.site == "prefetch"
        assert (ei.value.epoch, ei.value.it) == (3, 7)
        sup.mark_delivered(ei.value)
        sup.check()                     # delivered once — no double raise
        # an undelivered error raises at the next boundary check
        sup.submit(pool.submit, "cache", boom, epoch=4, it=-1).exception(
            timeout=5)
        with pytest.raises(BackgroundError) as ei2:
            sup.check()
        assert ei2.value.site == "cache" and ei2.value.epoch == 4
        assert sup.drain() == []
    finally:
        pool.shutdown(wait=False)


def test_resilient_call_retries_then_times_out():
    counters = CommCounters()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise TransientCommError("drop")
        return "ok"
    out = resilient_call(flaky, policy=RetryPolicy(backoff_s=0.0001),
                         counters=counters)
    assert out == "ok" and counters.retries == 2 and counters.timeouts == 0

    def dead():
        raise TransientCommError("drop")
    with pytest.raises(CommTimeout) as ei:
        resilient_call(dead, policy=RetryPolicy(max_retries=2,
                                                backoff_s=0.0001),
                       counters=counters, epoch=1, it=2)
    assert counters.timeouts == 1
    assert (ei.value.epoch, ei.value.it) == (1, 2)


# ---------------------------------------------------------------------------
# FeatureStore integrity (crc32 / quarantine / repair)
# ---------------------------------------------------------------------------

@pytest.fixture()
def crc_store(partitioned, tmp_path):
    d = partitioned
    st = FeatureStore.build(d["ds"].features, d["part"], d["parts"],
                            directory=str(tmp_path / "shards"),
                            host_budget_bytes=1 << 20, crc_chunk_rows=64)
    return d, st


def test_disk_corruption_detected_and_repaired(crc_store):
    d, st = crc_store
    rows = np.arange(128, dtype=np.int64)       # covers chunks 0 and 1
    ref = st.gather(0, rows).copy()
    st.corrupt_rows(0, np.array([3, 64, 65]), seed=9)
    out = st.gather(0, rows)
    np.testing.assert_array_equal(ref, out)
    assert st.stats.crc_failures >= 2           # both chunks tripped
    assert st.stats.repaired_rows >= 1


def test_corruption_without_source_refuses_to_serve(crc_store):
    d, st = crc_store
    st._source = None
    st.corrupt_rows(1, np.array([5]))
    with pytest.raises(CorruptFeatureError):
        st.gather(1, np.array([5]))


def test_checksum_sidecars_reload_without_rescan(partitioned, tmp_path):
    d = partitioned
    shards = str(tmp_path / "s")
    st = FeatureStore.build(d["ds"].features, d["part"], d["parts"],
                            directory=shards, host_budget_bytes=1 << 20,
                            crc_chunk_rows=64)
    st2 = FeatureStore(st._backing, host_budget_bytes=1 << 20,
                       owner=d["owner"], local_idx=d["local_idx"])
    st2.crc_chunk_rows = 64
    assert st2._load_sidecars(shards)
    np.testing.assert_array_equal(st._crc[0], st2._crc[0])


def test_mark_suspect_forces_reverification(crc_store):
    d, st = crc_store
    st.gather(0, np.arange(32))
    checked = st.stats.crc_checked_chunks
    st.gather(0, np.arange(32))
    assert st.stats.crc_checked_chunks == checked      # memoized
    st.mark_suspect(0, np.arange(32))
    st.gather(0, np.arange(32))
    assert st.stats.crc_checked_chunks == checked + 1  # re-verified


def test_verify_all_scrubs_and_repairs(crc_store):
    d, st = crc_store
    st.corrupt_rows(2, np.array([10]))
    assert st.verify_all() >= 1
    assert st.verify_all() == 0                        # now clean


# ---------------------------------------------------------------------------
# Crash-atomic checkpoints
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (4, 3)), "b": np.zeros(3, np.float32)}


def test_checkpoint_truncated_newest_falls_back(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, jax.tree.map(lambda x: x + 1, t))
    npz = tmp_path / "step-00000002.npz"
    npz.write_bytes(npz.read_bytes()[:40])             # torn write
    with pytest.warns(RuntimeWarning, match="corrupt"):
        tree, step, _ = load_checkpoint(tmp_path, t)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(t["w"]))


def test_checkpoint_explicit_step_fails_loudly(tmp_path):
    from repro.checkpoint import (CheckpointCorrupt, load_checkpoint,
                                  save_checkpoint)
    t = _tree()
    save_checkpoint(tmp_path, 5, t)
    (tmp_path / "step-00000005.json").write_text("{ not json")
    with pytest.raises(CheckpointCorrupt):
        load_checkpoint(tmp_path, t, step=5)


def test_checkpoint_missing_manifest_is_incomplete(tmp_path):
    from repro.checkpoint import (load_checkpoint, save_checkpoint,
                                  valid_steps)
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    save_checkpoint(tmp_path, 2, t)
    (tmp_path / "step-00000002.json").unlink()         # crash between files
    assert valid_steps(tmp_path) == [1]
    with pytest.warns(RuntimeWarning, match="incomplete"):
        _, step, _ = load_checkpoint(tmp_path, t)
    assert step == 1


def test_checkpoint_leaf_mismatch_still_valueerror(tmp_path):
    # the Trainer's bare-params legacy fallback depends on this contract
    from repro.checkpoint import load_checkpoint, save_checkpoint
    save_checkpoint(tmp_path, 1, _tree())
    with pytest.raises(ValueError, match="leaf count"):
        load_checkpoint(tmp_path, {"w": np.zeros((4, 3))})


def test_checkpoint_leaves_no_temp_files(tmp_path):
    from repro.checkpoint import save_checkpoint
    save_checkpoint(tmp_path, 3, _tree())
    assert not list(tmp_path.glob("*.tmp"))


# ---------------------------------------------------------------------------
# SIGKILL mid-epoch + resume (subprocess)
# ---------------------------------------------------------------------------

def _run_py(code: str, expect_signal=None) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=600)
    if expect_signal is not None:
        assert out.returncode == -expect_signal, out.stderr[-2000:]
        return {}
    assert out.returncode == 0, out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in:\n{out.stdout}\n{out.stderr}")


_SIGKILL_PRELUDE = """
import json, os, signal
import numpy as np
import jax
from repro.graph import make_dataset, ldg_partition
from repro.graph.partition import shard_features
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.train import Trainer

ds = make_dataset("arxiv", scale=0.02, seed=0)
part = ldg_partition(ds.graph, 4, passes=1)
table, owner, local_idx = shard_features(ds.features, part, 4)
cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=8,
                feature_dim=ds.feature_dim, num_classes=ds.num_classes,
                fanout=4)

def trainer(ckpt):
    return Trainer(graph=ds.graph, labels=ds.labels, part=part,
                   owner=owner, local_idx=local_idx, table=table, cfg=cfg,
                   optimizer=adam(5e-3), merging=False, root_seed=5,
                   train_vertices=ds.train_vertices(), ckpt_dir=ckpt)
"""


def test_sigkill_mid_epoch_resume_is_bit_identical(tmp_path):
    """Kill -9 the training process in the middle of epoch 2 (after epoch
    1's checkpoint is durable), resume from disk, and require the final
    parameters to match an uninterrupted run byte for byte."""
    ck = str(tmp_path / "ck")
    # phase 1: train, SIGKILL the interpreter mid-epoch-2
    _run_py(_SIGKILL_PRELUDE + f"""
tr = trainer({ck!r})
orig = tr.build_plan
def killing(epoch, it, batch):
    if (epoch, it) == (2, 1):
        os.kill(os.getpid(), signal.SIGKILL)
    return orig(epoch, it, batch)
tr.build_plan = killing
tr.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)
""", expect_signal=9)
    # phase 2: resume and finish; phase 3 (same process): straight run
    res = _run_py(_SIGKILL_PRELUDE + f"""
tr = trainer({ck!r})
stats = tr.fit(epochs=3, iters_per_epoch=3, batch_per_model=8,
               resume=True)
tr2 = trainer(None)
tr2.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)
same = all(np.array_equal(np.asarray(a), np.asarray(b))
           for a, b in zip(jax.tree.leaves(tr.params),
                           jax.tree.leaves(tr2.params)))
print("RESULT:" + json.dumps({{
    "resumed_epochs": [s.epoch for s in stats], "identical": same}}))
""")
    assert res["resumed_epochs"] == [2]
    assert res["identical"]
