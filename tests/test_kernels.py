"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Per the brief: sweep shapes/dtypes and assert_allclose against ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.gather_agg import gather_agg, gather_rows
from repro.kernels.linattn import linattn_chunked
from repro.kernels.ref import gather_agg_ref, gather_rows_ref, linattn_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d", [(16, 128), (64, 256), (33, 96)])
def test_gather_rows_sweep(rows, d, dtype):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.standard_normal((rows, d)), dtype)
    idx = jnp.asarray(rng.integers(0, rows, 29), jnp.int32)
    out = gather_rows(table, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gather_rows_ref(table, idx),
                                          np.float32), rtol=1e-6)


@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,f,d", [(8, 4, 128), (17, 10, 128), (5, 3, 64)])
def test_gather_agg_sweep(n, f, d, reduce, dtype):
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.standard_normal((40, d)), dtype)
    idx = jnp.asarray(rng.integers(0, 40, (n, f)), jnp.int32)
    out = gather_agg(table, idx, reduce=reduce, interpret=True)
    ref = gather_agg_ref(table, idx, reduce=reduce)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_dim_splits_lane_tiling():
    """d > LANE with d % LANE != 0 must tile the first d//LANE*LANE lanes
    at LANE width and carry only the tail as a sub-lane block (the old
    fallback put the whole dim in one block)."""
    from repro.kernels.gather_agg import LANE, _dim_splits
    assert _dim_splits(128) == [(0, 128, 128)]
    assert _dim_splits(256) == [(0, 256, 128)]
    assert _dim_splits(96) == [(0, 96, 96)]
    assert _dim_splits(192) == [(0, 128, LANE), (128, 64, 64)]
    assert _dim_splits(300) == [(0, 256, LANE), (256, 44, 44)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows_d192_lane_split(dtype):
    """d = 192: 128-lane tile + 64-wide tail, stitched back bit-exact."""
    rng = np.random.default_rng(6)
    table = jnp.asarray(rng.standard_normal((31, 192)), dtype)
    idx = jnp.asarray(rng.integers(0, 31, 27), jnp.int32)
    out = gather_rows(table, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(gather_rows_ref(table, idx)))


@pytest.mark.parametrize("reduce", ["sum", "mean", "max"])
def test_gather_agg_d192_lane_split(reduce):
    rng = np.random.default_rng(7)
    table = jnp.asarray(rng.standard_normal((23, 192)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 23, (9, 5)), jnp.int32)
    out = gather_agg(table, idx, reduce=reduce, interpret=True)
    ref = gather_agg_ref(table, idx, reduce=reduce)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(4, 40), st.integers(1, 8), st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_gather_agg_property(n, f, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((23, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 23, (n, f)), jnp.int32)
    out = gather_agg(table, idx, reduce="sum", interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(gather_agg_ref(table, idx, "sum")),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("BH,T,dk,dv,chunk", [
    (2, 64, 16, 16, 16), (3, 128, 32, 64, 64), (1, 96, 8, 8, 32),
])
def test_linattn_kernel_sweep(BH, T, dk, dv, chunk):
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, T, dv)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((BH, T, dk)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((BH, dk)), jnp.float32)
    o_ref, s_ref = linattn_ref(q, k, v, w, u)
    o, s = linattn_chunked(q, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=5e-4, atol=5e-4)


def test_linattn_jnp_matches_scan_and_is_differentiable():
    rng = np.random.default_rng(3)
    BH, T, dk, dv = 2, 64, 16, 16
    q = jnp.asarray(rng.standard_normal((BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, T, dv)), jnp.float32)
    w = jnp.asarray(0.7 + 0.29 * rng.random((BH, T, dk)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((dk,)), jnp.float32)
    o_ref, s_ref = linattn_ref(q, k, v, w, u)
    o, s = ops.linattn_chunked_jnp(q, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=5e-4, atol=5e-4)
    g = jax.grad(lambda q: ops.linattn_chunked_jnp(q, k, v, w, u)[0].sum())(q)
    assert np.isfinite(np.asarray(g)).all()


def test_linattn_decode_step_consistency():
    """T decode steps == one full-sequence pass (cache-correctness)."""
    rng = np.random.default_rng(4)
    BH, T, dk, dv = 2, 32, 8, 8
    q = jnp.asarray(rng.standard_normal((BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, T, dv)), jnp.float32)
    w = jnp.asarray(0.7 + 0.29 * rng.random((BH, T, dk)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((dk,)), jnp.float32)
    o_ref, s_ref = linattn_ref(q, k, v, w, u)
    S = jnp.zeros((BH, dk, dv))
    outs = []
    for t in range(T):
        o_t, S = ops.linattn_step(q[:, t], k[:, t], v[:, t], w[:, t], u, S)
        outs.append(o_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(o_ref), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(s_ref),
                               rtol=5e-4, atol=5e-4)


def test_ops_dispatch_cpu_defaults_to_ref():
    """On CPU the ops layer must route to the jnp reference (fast), with
    force_kernel exercising the interpreted Pallas path."""
    rng = np.random.default_rng(5)
    table = jnp.asarray(rng.standard_normal((10, 128)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 10, (4, 3)), jnp.int32)
    a = ops.gather_agg(table, idx)
    b = ops.gather_agg(table, idx, force_kernel=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(2, 6),
       st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_linattn_property_random_shapes(bh, chunks_, dk_pow, seed):
    """Hypothesis sweep: chunked kernel == token scan for random shapes."""
    rng = np.random.default_rng(seed)
    dk = 2 ** dk_pow
    chunk = 8
    T = chunk * chunks_
    q = jnp.asarray(rng.standard_normal((bh, T, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, T, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, T, dk)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((bh, T, dk)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((dk,)), jnp.float32)
    o_ref, s_ref = linattn_ref(q, k, v, w, u)
    o, s = linattn_chunked(q, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-3, atol=2e-3)


def test_gather_rows_used_by_engine(partitioned):
    """The device engine's feature gather must round-trip through the
    kernels.ops dispatch layer (integration of the Pallas path)."""
    import repro.core.distributed as dist
    import inspect
    src = inspect.getsource(dist._shard_grads)
    assert "ops.gather_rows" in src
