"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; multi-device integration tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_dataset():
    from repro.graph import make_dataset
    return make_dataset("arxiv", scale=0.03, seed=0)


@pytest.fixture(scope="session")
def partitioned(small_dataset):
    from repro.graph import ldg_partition
    from repro.graph.partition import shard_features
    parts = 4
    part = ldg_partition(small_dataset.graph, parts, passes=1)
    table, owner, local_idx = shard_features(small_dataset.features, part,
                                             parts)
    return dict(ds=small_dataset, parts=parts, part=part, table=table,
                owner=owner, local_idx=local_idx)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
