"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; multi-device integration tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves."""
import os

import numpy as np
import pytest


# A chaos kind consulted at least this many times over the suite has seen
# enough deterministic draws that zero firings means the schedule (or the
# fault point it feeds) silently regressed, not that the suite got lucky.
# Tuned against `make test-chaos` (seed 17): every kind is offered
# thousands of draws and fires double digits; kinds a short custom run
# barely touches stay exempt.
_CHAOS_MIN_OFFERED = 500


@pytest.fixture(scope="session", autouse=True)
def chaos_plan():
    """CI chaos-smoke hook: REPRO_CHAOS_SEED=<int> runs the whole suite
    under a transient-only ChaosPlan (deterministic low-rate comm delays,
    guarded drops, planner stalls, flapping peers). Every tier-1
    assertion — bit-parity, trace counts — must hold unchanged; that is
    the point. On teardown the coverage gate requires every chaos kind
    that was offered enough draws to have actually fired: a kind that
    stops firing means chaos coverage regressed silently."""
    seed = os.environ.get("REPRO_CHAOS_SEED")
    if not seed:
        yield None
        return
    from repro.resilience import CHAOS_KINDS, ChaosPlan
    plan = ChaosPlan(seed=int(seed)).install()
    yield plan
    plan.uninstall()
    fired = plan.fired_by_kind()
    missing = [k for k in CHAOS_KINDS
               if plan.offered.get(k, 0) >= _CHAOS_MIN_OFFERED
               and fired.get(k, 0) == 0]
    assert not missing, (
        f"chaos coverage regressed: kinds {missing} were offered "
        f"{ {k: plan.offered[k] for k in missing} } draws and never "
        f"fired (fired: {fired})")


@pytest.fixture(scope="session")
def small_dataset():
    from repro.graph import make_dataset
    return make_dataset("arxiv", scale=0.03, seed=0)


@pytest.fixture(scope="session")
def partitioned(small_dataset):
    from repro.graph import ldg_partition
    from repro.graph.partition import shard_features
    parts = 4
    part = ldg_partition(small_dataset.graph, parts, passes=1)
    table, owner, local_idx = shard_features(small_dataset.features, part,
                                             parts)
    return dict(ds=small_dataset, parts=parts, part=part, table=table,
                owner=owner, local_idx=local_idx)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
