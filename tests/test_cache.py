"""repro.cache: store/policy/prefetch units, cache-aware planner parity
(vectorized ≡ reference), bit-identical cache-on/off training, the
PlanOverflow → c_max re-bucket path, and the Trainer integration
(hit-rate/refresh accounting, compile-once across refreshes, staleness
guard)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

import repro.core.pregather as pg
from repro.cache import (CacheIndex, CacheStore, DegreePolicy, EpochPrefetcher,
                         LFUPolicy, budget_rows)
from repro.core import distributed as engine
from repro.core import plan_iteration, run_iteration
from repro.core.pregather import (PlanOverflow, _reference_build_gather_plan,
                                  build_gather_plan, workspace_indices,
                                  _reference_workspace_indices)
from repro.models.gnn import GNNConfig, init_gnn
from repro.optim import adam
from repro.train import ShapeBudget, Trainer
from repro.graph.structs import CSRGraph
from repro.graph.partition import shard_features


# ---------------------------------------------------------------------------
# Small deterministic world builders (fixed shapes → one jit trace)
# ---------------------------------------------------------------------------

N_VERT, N_SHARDS, FDIM = 96, 3, 4


def _world(seed: int):
    """Random small graph + even partition + features, fixed sizes."""
    rng = np.random.default_rng(seed)
    n_edges = int(rng.integers(N_VERT, 4 * N_VERT))
    src = rng.integers(0, N_VERT, n_edges)
    dst = rng.integers(0, N_VERT, n_edges)
    graph = CSRGraph.from_edges(N_VERT, src, dst)
    part = (np.arange(N_VERT) % N_SHARDS).astype(np.int32)
    feats = rng.standard_normal((N_VERT, FDIM)).astype(np.float32)
    labels = rng.integers(0, 3, N_VERT).astype(np.int32)
    table, owner, local_idx = shard_features(feats, part, N_SHARDS)
    return dict(graph=graph, part=part, feats=feats, labels=labels,
                table=table, owner=owner, local_idx=local_idx)


def _random_cache(w, rng, k_per_shard: int, c_max: int = 32) -> CacheStore:
    """A store holding an arbitrary valid cached set (not a policy output —
    correctness must hold for any admissible selection)."""
    store = CacheStore(N_SHARDS, FDIM, c_max=c_max)
    ids, rows = [], []
    for s in range(N_SHARDS):
        remote = np.nonzero(w["owner"] != s)[0]
        k = min(k_per_shard, remote.size)
        sel = rng.choice(remote, k, replace=False).astype(np.int64)
        ids.append(sel)
        rows.append(w["feats"][sel])
    store.install(ids, rows)
    return store


def _plan_pair(w, seed: int, store, pregather=True):
    rng = np.random.default_rng(seed)
    roots = [rng.choice(N_VERT, 6, replace=False).astype(np.int64)
             for _ in range(N_SHARDS)]
    kw = dict(num_layers=2, fanout=2, strategy="hopgnn",
              pregather=pregather, sample_seed=seed,
              batch_pad=8, r_max=128)
    args = (w["graph"], w["labels"], w["part"], w["owner"], w["local_idx"],
            w["table"].shape[1], roots)
    return (plan_iteration(*args, **kw),
            plan_iteration(*args, **kw, cache_index=store.index))


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------

def test_store_install_sorts_and_versions():
    w = _world(0)
    store = CacheStore(N_SHARDS, FDIM, c_max=8)
    ids = [np.array([7, 4, 1]), np.array([3]), np.zeros(0, np.int64)]
    # make the ids remote-valid is irrelevant to the store; it stores rows
    rows = [w["feats"][i] for i in ids]
    st0 = store.install(ids, rows)
    assert st0 == {"rows": 4, "bytes": 4 * FDIM * 4, "c_max": 8,
                   "version": 1}
    np.testing.assert_array_equal(store.index.ids[0], [1, 4, 7])
    np.testing.assert_array_equal(store.index.slots[0], [0, 1, 2])
    # table rows land sorted; padding stays zero
    np.testing.assert_array_equal(np.asarray(store.device_table)[0, :3],
                                  w["feats"][[1, 4, 7]])
    assert float(np.abs(np.asarray(store.device_table)[0, 3:]).sum()) == 0.0
    # reinstall bumps the version and replaces the set
    store.install([np.array([2])] + ids[1:], [w["feats"][[2]]] + rows[1:])
    assert store.version == 2 and store.index.version == 2
    assert store.rows_installed() == 2


def test_store_repads_to_next_pow2_bucket():
    store = CacheStore(2, FDIM, c_max=4)
    f = np.zeros((9, FDIM), np.float32)
    store.install([np.arange(3), np.arange(3)], [f[:3], f[:3]])
    assert store.c_max == 4 and store.repads == 0
    store.install([np.arange(9), np.arange(3)], [f, f[:3]])
    assert store.c_max == 16 and store.repads == 1      # pow2 ≥ 9
    assert store.index.c_max == 16


def test_store_rejects_duplicate_ids():
    store = CacheStore(1, FDIM, c_max=4)
    with pytest.raises(ValueError):
        store.install([np.array([5, 5])], [np.zeros((2, FDIM), np.float32)])


def test_hit_split():
    idx = CacheIndex(ids=[np.array([2, 5, 9])], slots=[np.array([0, 1, 2])],
                     c_max=4, version=1)
    hit, slot = idx.hit_split(0, np.array([5, 3, 9, 2, 11]))
    np.testing.assert_array_equal(hit, [True, False, True, True, False])
    np.testing.assert_array_equal(slot[hit], [1, 2, 0])


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def test_budget_rows_math():
    assert budget_rows(0, 128) == 0
    assert budget_rows(1024, 128, 4) == 2
    assert budget_rows(511, 128, 4) == 0


def test_degree_policy_picks_top_degree_remote():
    w = _world(1)
    pol = DegreePolicy(w["graph"], w["owner"])
    deg = w["graph"].degrees()
    for s in range(N_SHARDS):
        sel = pol.select(s, 5)
        assert sel.size == 5
        assert np.all(w["owner"][sel] != s)              # remote only
        remote = np.nonzero(w["owner"] != s)[0]
        worst_kept = deg[sel].min()
        dropped = np.setdiff1d(remote, sel)
        assert deg[dropped].max() <= worst_kept          # top-k by degree


def test_lfu_policy_ranks_by_frequency_and_decays():
    pol = LFUPolicy(1, decay=1.0)
    for _ in range(3):
        pol.observe(0, np.array([10, 11]))
    pol.observe(0, np.array([12]))
    np.testing.assert_array_equal(pol.select(0, 2), [10, 11])
    # exact forecast overrides history entirely
    sel = pol.select(0, 2, hot_ids=np.array([30, 12, 31]),
                     hot_counts=np.array([5, 1, 4]))
    np.testing.assert_array_equal(sel, [30, 31])
    # decay: old counts fade
    pol2 = LFUPolicy(1, decay=0.1)
    pol2.observe(0, np.array([1]), np.array([4.0]))
    pol2.select(0, 1)                                    # applies decay once
    pol2.observe(0, np.array([2]), np.array([1.0]))
    np.testing.assert_array_equal(pol2.select(0, 1), [2])


# ---------------------------------------------------------------------------
# Cache-aware planner: vectorized ≡ reference (both dedup paths)
# ---------------------------------------------------------------------------

def _check_plan_parity(seed, k_cache, dense, monkeypatch=None):
    if monkeypatch is not None:
        monkeypatch.setattr(pg, "_DENSE_DEDUP_MAX_CELLS",
                            (1 << 28) if dense else 0)
    w = _world(seed)
    rng = np.random.default_rng(seed + 77)
    store = _random_cache(w, rng, k_cache)
    needed = [rng.integers(0, N_VERT, int(rng.integers(0, 300)))
              for _ in range(N_SHARDS)]
    a = build_gather_plan(needed, w["owner"], w["local_idx"], N_SHARDS,
                          w["table"].shape[1], cache=store.index)
    b = _reference_build_gather_plan(needed, w["owner"], w["local_idx"],
                                     N_SHARDS, w["table"].shape[1],
                                     cache=store.index)
    np.testing.assert_array_equal(a.req, b.req)
    np.testing.assert_array_equal(a.req_count, b.req_count)
    assert a.r_max == b.r_max and a.c_max == b.c_max == store.c_max
    np.testing.assert_array_equal(a.cache_hits, b.cache_hits)
    np.testing.assert_array_equal(a.slot_map.starts, b.slot_map.starts)
    np.testing.assert_array_equal(a.slot_map.ids, b.slot_map.ids)
    np.testing.assert_array_equal(a.slot_map.slots, b.slot_map.slots)
    # hop translation parity through the cached slots
    for s in range(N_SHARDS):
        if needed[s].size == 0:
            continue
        hops = [needed[s][rng.integers(0, needed[s].size, 64)]]
        wa = workspace_indices(hops, s, w["owner"], w["local_idx"], a)
        wb = _reference_workspace_indices(hops, s, w["owner"],
                                          w["local_idx"], b)
        np.testing.assert_array_equal(wa[0], wb[0])
    # hit slots live in the cached region, miss slots above it
    local_rows = w["table"].shape[1]
    for s in range(N_SHARDS):
        ids = a.slot_map.shard_ids(s)
        slots = a.slot_map.shard_slots(s)
        hit, _ = store.index.hit_split(s, ids)
        assert np.all(slots[hit] < local_rows + a.c_max)
        assert np.all(slots[hit] >= local_rows)
        assert np.all(slots[~hit] >= local_rows + a.c_max)


@given(st.integers(0, 10_000), st.integers(0, 30), st.booleans())
@settings(max_examples=25, deadline=None)
def test_cache_plan_parity_property(seed, k_cache, dense):
    """Vectorized cache-aware planner ≡ per-vertex reference on random
    graphs, cached sets, and both dedup paths."""
    old = pg._DENSE_DEDUP_MAX_CELLS
    pg._DENSE_DEDUP_MAX_CELLS = (1 << 28) if dense else 0
    try:
        _check_plan_parity(seed, k_cache, dense)
    finally:
        pg._DENSE_DEDUP_MAX_CELLS = old


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("dense", [True, False])
def test_cache_plan_parity_seeded(seed, dense, monkeypatch):
    _check_plan_parity(seed, k_cache=(seed * 5) % 31, dense=dense,
                       monkeypatch=monkeypatch)


# ---------------------------------------------------------------------------
# Bit-identical iteration: cache-on ≡ cache-off (the tentpole invariant)
# ---------------------------------------------------------------------------

def _grad_dmax(g0, g1):
    return max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))


@given(st.integers(0, 10_000), st.integers(1, 24))
@settings(max_examples=8, deadline=None)
def test_cached_run_iteration_bit_identical_property(seed, k_cache):
    """Cache-enabled run_iteration ≡ cache-disabled, bit for bit, for
    random graphs/selections/budgets. Shapes are pinned (batch_pad/r_max/
    c_max fixed) so the whole property run shares one compiled program."""
    w = _world(seed)
    rng = np.random.default_rng(seed + 1)
    store = _random_cache(w, rng, k_cache)       # c_max pinned to 32
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=8,
                    feature_dim=FDIM, num_classes=3, fanout=2)
    params = init_gnn(jax.random.PRNGKey(seed % 7), cfg)
    p0, p1 = _plan_pair(w, seed, store)
    assert p1.cache_hit_rows + p1.remote_rows_exact == p0.remote_rows_exact
    g0, l0 = run_iteration(params, w["table"], p0, cfg)
    g1, l1 = run_iteration(params, w["table"], p1, cfg,
                           cache=store.device_table)
    assert float(l0) == float(l1)
    assert _grad_dmax(g0, g1) == 0.0


@pytest.mark.parametrize("pregather", [True, False])
def test_cached_run_iteration_bit_identical_seeded(pregather):
    """Always-on variant of the property test, covering per-step mode
    (where the cache also dedups across steps) and the folded/unfolded
    feature-return paths."""
    w = _world(3)
    rng = np.random.default_rng(3)
    store = _random_cache(w, rng, 16)
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=8,
                    feature_dim=FDIM, num_classes=3, fanout=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    p0, p1 = _plan_pair(w, 3, store, pregather=pregather)
    g0, l0 = run_iteration(params, w["table"], p0, cfg)
    g1, l1 = run_iteration(params, w["table"], p1, cfg,
                           cache=store.device_table)
    assert float(l0) == float(l1) and _grad_dmax(g0, g1) == 0.0
    if not pregather:
        gf, lf = run_iteration(params, w["table"], p1, cfg,
                               cache=store.device_table, fold_returns=True)
        gu, lu = run_iteration(params, w["table"], p1, cfg,
                               cache=store.device_table, fold_returns=False)
        assert float(lf) == float(lu) == float(l0)
        assert _grad_dmax(gf, gu) == 0.0


def test_run_iteration_guards_cache_table():
    w = _world(5)
    rng = np.random.default_rng(5)
    store = _random_cache(w, rng, 8)
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=8,
                    feature_dim=FDIM, num_classes=3, fanout=2)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    _, p1 = _plan_pair(w, 5, store)
    with pytest.raises(ValueError, match="no cache table"):
        run_iteration(params, w["table"], p1, cfg)           # cache missing
    with pytest.raises(ValueError, match="c_max"):
        run_iteration(params, w["table"], p1, cfg,
                      cache=jnp.zeros((N_SHARDS, 8, FDIM)))  # wrong height


# ---------------------------------------------------------------------------
# PlanOverflow("c_max") → ShapeBudget re-bucket
# ---------------------------------------------------------------------------

def test_c_max_overflow_and_rebucket():
    w = _world(7)
    rng = np.random.default_rng(7)
    store = _random_cache(w, rng, 16, c_max=16)
    roots = [rng.choice(N_VERT, 6, replace=False).astype(np.int64)
             for _ in range(N_SHARDS)]
    kw = dict(graph=w["graph"], labels=w["labels"], part=w["part"],
              owner=w["owner"], local_idx=w["local_idx"],
              local_rows=w["table"].shape[1], roots_per_model=roots,
              num_layers=2, fanout=2, strategy="hopgnn", sample_seed=7)
    # direct overflow: a c_max budget below the index height is structured
    with pytest.raises(PlanOverflow) as ei:
        plan_iteration(**kw, cache_index=store.index, c_max=8)
    assert (ei.value.field, ei.value.needed, ei.value.limit) == \
        ("c_max", 16, 8)

    # ShapeBudget: learns c_max from the first plan, then re-buckets
    # explicitly when the store re-pads (cache-size drift)
    budget = ShapeBudget()
    p1 = budget.plan(**kw, cache_index=store.index)
    assert budget.c_max == 16 and p1.c_max == 16 and budget.rebuckets == 0
    big = np.nonzero(w["owner"] != 0)[0][:20].astype(np.int64)
    store.install([big] + [store.index.ids[s] for s in (1, 2)],
                  [w["feats"][big]] + [w["feats"][store.index.ids[s]]
                                       for s in (1, 2)])
    assert store.c_max == 32                     # re-padded past the budget
    p2 = budget.plan(**kw, cache_index=store.index)
    assert budget.rebuckets == 1 and budget.c_max == 32 and p2.c_max == 32
    # shapes stable afterwards: same bucket, no further growth
    p3 = budget.plan(**kw, cache_index=store.index)
    assert budget.rebuckets == 1 and p3.c_max == 32


# ---------------------------------------------------------------------------
# Deterministic epoch prefetch
# ---------------------------------------------------------------------------

def test_prefetcher_predicts_plan_requests_exactly(partitioned):
    """The replayed hot sets must equal the remote request sets the
    Trainer's plans actually make (same roots, same stateless sampler)."""
    d = partitioned
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=16,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    tr = Trainer(graph=d["ds"].graph, labels=d["ds"].labels, part=d["part"],
                 owner=d["owner"], local_idx=d["local_idx"],
                 table=d["table"], cfg=cfg, optimizer=adam(5e-3),
                 merging=False, train_vertices=d["ds"].train_vertices(),
                 cache_policy="lfu",
                 cache_budget_bytes=64 * d["ds"].feature_dim * 4)
    tr._prefetch_batch = 8
    pf = tr._cache_prefetcher
    for it in range(2):
        pred = pf.iteration_requests(1, it)
        plan = tr.build_plan(1, it, 8)
        for s in range(d["parts"]):
            np.testing.assert_array_equal(np.sort(pred[s]),
                                          plan.remote_ids[s])


# ---------------------------------------------------------------------------
# Trainer integration
# ---------------------------------------------------------------------------

def _mk_trainer(d, **kw):
    cfg = GNNConfig(model="sage", num_layers=2, hidden_dim=16,
                    feature_dim=d["ds"].feature_dim,
                    num_classes=d["ds"].num_classes, fanout=4)
    kw.setdefault("optimizer", adam(5e-3))
    kw.setdefault("merging", False)
    kw.setdefault("train_vertices", d["ds"].train_vertices())
    return Trainer(graph=d["ds"].graph, labels=d["ds"].labels,
                   part=d["part"], owner=d["owner"],
                   local_idx=d["local_idx"], table=d["table"], cfg=cfg, **kw)


def test_trainer_cache_training_is_bit_identical(partitioned):
    """Same seeds, cache on vs off: identical per-epoch losses and final
    parameters, while the cache actually serves hits and refreshes never
    retrace (trace_log clean after epoch 0)."""
    d = partitioned
    engine.clear_compile_cache()
    t0 = _mk_trainer(d)
    s0 = t0.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)

    engine.clear_compile_cache()
    t1 = _mk_trainer(d, cache_policy="lfu",
                     cache_budget_bytes=2048 * d["ds"].feature_dim * 4)
    s1 = t1.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)

    assert [st.loss for st in s1] == [st.loss for st in s0]
    for a, b in zip(jax.tree.leaves(t0.params), jax.tree.leaves(t1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # covering budget + exact forecast: steady epochs are all-hit
    assert s1[1].cache_hit_rate == 1.0 and s1[2].cache_hit_rate == 1.0
    assert s1[1].remote_rows == 0
    assert s1[1].cache_bytes_saved > 0
    # epoch-boundary refreshes must not retrace (the compile-once contract)
    assert s1[1].traces == 0 and s1[2].traces == 0
    # misses + hits must equal the cache-off remote rows
    for off, on in zip(s0, s1):
        assert on.cache_hit_rows + on.remote_rows == off.remote_rows


def test_trainer_degree_cache_hits_without_prefetch_thread(partitioned):
    d = partitioned
    engine.clear_compile_cache()
    tr = _mk_trainer(d, cache_policy="degree",
                     cache_budget_bytes=256 * d["ds"].feature_dim * 4)
    stats = tr.fit(epochs=2, iters_per_epoch=3, batch_per_model=8)
    assert tr.cache_store.installs == 1          # static: one install, ever
    assert all(st.cache_hit_rows > 0 for st in stats)
    assert stats[1].traces == 0


def test_trainer_rejects_stale_cache_plan(partitioned):
    d = partitioned
    tr = _mk_trainer(d, cache_policy="degree",
                     cache_budget_bytes=64 * d["ds"].feature_dim * 4)
    tr._cache_select_install()
    plan = tr.build_plan(0, 0, 8)
    tr._cache_select_install()                   # version bump → plan stale
    with pytest.raises(RuntimeError, match="stale cache plan"):
        tr.train_step(plan)


def test_trainer_zero_budget_disables_cache(partitioned):
    d = partitioned
    tr = _mk_trainer(d, cache_policy="lfu", cache_budget_bytes=0)
    assert not tr.cache_enabled
    stats = tr.fit(epochs=1, iters_per_epoch=2, batch_per_model=8)
    assert stats[0].cache_hit_rows == 0


# ---------------------------------------------------------------------------
# Merge-pattern-aware prediction (the ROADMAP "cache vs merging gap")
# ---------------------------------------------------------------------------

def test_prefetcher_fold_steps_predicts_merged_requests(partitioned):
    """Under a §5.3 merge the requesting shard moves for the merged roots:
    the unfolded replay must mispredict some shard's request set, and the
    fold_steps hook (folding exactly like build_plan) must restore exact
    prediction."""
    from repro.core.merging import MergingController
    from repro.core.micrograph import hopgnn_assignment
    d = partitioned
    tr = _mk_trainer(d, cache_policy="lfu",
                     cache_budget_bytes=64 * d["ds"].feature_dim * 4,
                     merging=True, root_seed=11)
    tr._prefetch_batch = 8
    steps = d["parts"] - 1
    tr.controller = MergingController(
        base=hopgnn_assignment(tr._roots_for(0, 0, 8), d["part"]))
    tr.controller.restore(num_steps=steps, frozen=True)

    pf = tr._cache_prefetcher                 # fold_steps wired by Trainer
    unfolded = EpochPrefetcher(
        graph=d["ds"].graph, part=d["part"], owner=d["owner"],
        num_shards=d["parts"], num_layers=2, fanout=4,
        roots_for=tr._prefetch_roots_for,
        sample_seed_for=lambda e, i: tr.sample_seed_base + e * 10_000 + i,
        strategy="hopgnn")

    mismatch = False
    for it in range(2):
        pred = pf.iteration_requests(1, it)
        pred_raw = unfolded.iteration_requests(1, it)
        plan = tr.build_plan(1, it, 8)        # folds via controller pattern
        assert plan.num_steps == steps
        for s in range(d["parts"]):
            np.testing.assert_array_equal(np.sort(pred[s]),
                                          plan.remote_ids[s])
            if not np.array_equal(np.sort(pred_raw[s]), plan.remote_ids[s]):
                mismatch = True
    assert mismatch        # the gap is real: unfolded prediction is wrong


def test_merged_frozen_pattern_recovers_full_hit_rate(partitioned):
    """Regression for the prediction gap: with an active (frozen) merge
    and a covering LFU budget, prefetch-driven steady epochs must be
    all-hit — exactly like the unmerged benchmark configuration."""
    d = partitioned
    engine.clear_compile_cache()
    from repro.core.merging import MergingController
    from repro.core.micrograph import hopgnn_assignment
    tr = _mk_trainer(d, cache_policy="lfu", merging=True, root_seed=11,
                     cache_budget_bytes=4096 * d["ds"].feature_dim * 4)
    tr.controller = MergingController(
        base=hopgnn_assignment(tr._roots_for(0, 0, 8), d["part"]))
    tr.controller.restore(num_steps=d["parts"] - 1, frozen=True)
    stats = tr.fit(epochs=3, iters_per_epoch=3, batch_per_model=8)
    assert all(st.num_steps == d["parts"] - 1 for st in stats)
    # epoch 0 runs cold (no forecast yet); steady epochs are all-hit
    assert stats[1].cache_hit_rate == 1.0 and stats[2].cache_hit_rate == 1.0
    assert stats[1].remote_rows == 0 and stats[2].remote_rows == 0
    assert stats[1].traces == 0 and stats[2].traces == 0
