"""Distribution: shard_map GNN training vs emulation (subprocess with 8
host devices), and sharding-spec construction for every assigned arch."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_variant

ROOT = Path(__file__).resolve().parents[1]


def _run_subprocess(code: str, devices: int = 8) -> dict:
    """Run `code` in a fresh interpreter with N host devices; it must print
    a single JSON line starting with RESULT:."""
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(code))
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # force the CPU backend: without this, a libtpu install probes
             # GCP instance metadata for ~8 minutes before falling back
             "JAX_PLATFORMS": "cpu"},
        timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in:\n{out.stdout}\n{out.stderr}")


@pytest.mark.slow
def test_shard_map_matches_emulation():
    """The shard_map engine (real all_to_all/psum over 4 devices) must give
    the same loss and gradients as the single-device emulation."""
    res = _run_subprocess("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.graph import make_dataset, ldg_partition
        from repro.graph.partition import shard_features
        from repro.core import plan_iteration, run_iteration
        from repro.models.gnn import GNNConfig, init_gnn

        ds = make_dataset('arxiv', scale=0.02, seed=0)
        n = 4
        part = ldg_partition(ds.graph, n, passes=1)
        table, owner, local_idx = shard_features(ds.features, part, n)
        rng = np.random.default_rng(0)
        tv = ds.train_vertices()
        roots = [rng.choice(tv, 8, replace=False) for _ in range(n)]
        plan = plan_iteration(ds.graph, ds.labels, part, owner, local_idx,
                              table.shape[1], roots, num_layers=2, fanout=4,
                              strategy='hopgnn', pregather=True,
                              sample_seed=3)
        cfg = GNNConfig(model='sage', num_layers=2, hidden_dim=16,
                        feature_dim=ds.feature_dim,
                        num_classes=ds.num_classes, fanout=4)
        params = init_gnn(jax.random.PRNGKey(0), cfg)

        g_emu, l_emu = run_iteration(params, table, plan, cfg, mesh=None)
        mesh = jax.make_mesh((n,), ('data',))
        g_map, l_map = run_iteration(params, table, plan, cfg, mesh=mesh)
        dmax = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(g_emu), jax.tree.leaves(g_map)))
        print('RESULT:' + json.dumps(
            {'l_emu': float(l_emu), 'l_map': float(l_map), 'dmax': dmax}))
    """)
    assert abs(res["l_emu"] - res["l_map"]) < 1e-5
    assert res["dmax"] < 1e-5


@pytest.mark.slow
def test_shard_map_matches_emulation_per_step():
    """Per-step mode with the batched index exchange: real collectives over
    4 devices must match the single-device emulation bit-for-bit."""
    res = _run_subprocess("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.graph import make_dataset, ldg_partition
        from repro.graph.partition import shard_features
        from repro.core import plan_iteration, run_iteration
        from repro.models.gnn import GNNConfig, init_gnn

        ds = make_dataset('arxiv', scale=0.02, seed=0)
        n = 4
        part = ldg_partition(ds.graph, n, passes=1)
        table, owner, local_idx = shard_features(ds.features, part, n)
        rng = np.random.default_rng(0)
        tv = ds.train_vertices()
        roots = [rng.choice(tv, 8, replace=False) for _ in range(n)]
        plan = plan_iteration(ds.graph, ds.labels, part, owner, local_idx,
                              table.shape[1], roots, num_layers=2, fanout=4,
                              strategy='hopgnn', pregather=False,
                              sample_seed=3)
        cfg = GNNConfig(model='sage', num_layers=2, hidden_dim=16,
                        feature_dim=ds.feature_dim,
                        num_classes=ds.num_classes, fanout=4)
        params = init_gnn(jax.random.PRNGKey(0), cfg)

        g_emu, l_emu = run_iteration(params, table, plan, cfg, mesh=None)
        mesh = jax.make_mesh((n,), ('data',))
        g_map, l_map = run_iteration(params, table, plan, cfg, mesh=mesh)
        dmax = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(g_emu), jax.tree.leaves(g_map)))
        print('RESULT:' + json.dumps(
            {'l_emu': float(l_emu), 'l_map': float(l_map), 'dmax': dmax}))
    """)
    assert res["l_emu"] == res["l_map"]
    assert res["dmax"] == 0.0


def test_per_step_iteration_collective_counts():
    """Acceptance: the batched index exchange makes unfolded per-step mode
    run exactly T+1 all_to_alls per iteration (T feature returns + 1
    batched index shipment; the seed ran 2T); folding the feature returns
    (serve_features_batched) brings it to exactly 2 — the same count as
    pregather mode. Trace-only (jax.make_jaxpr — no compile, no
    execution), so the subprocess is cheap enough for the tier-1 lane."""
    res = _run_subprocess("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.graph import make_dataset, ldg_partition
        from repro.graph.partition import shard_features
        from repro.core import plan_iteration
        from repro.core import distributed as engine
        from repro.models.gnn import GNNConfig, init_gnn

        ds = make_dataset('arxiv', scale=0.01, seed=0)
        n = 4
        part = ldg_partition(ds.graph, n, passes=1)
        table, owner, local_idx = shard_features(ds.features, part, n)
        rng = np.random.default_rng(0)
        tv = ds.train_vertices()
        roots = [rng.choice(tv, 4, replace=False) for _ in range(n)]
        cfg = GNNConfig(model='sage', num_layers=2, hidden_dim=8,
                        feature_dim=ds.feature_dim,
                        num_classes=ds.num_classes, fanout=2)
        params = init_gnn(jax.random.PRNGKey(0), cfg)
        mesh = jax.make_mesh((n,), ('data',))
        cache = jnp.zeros((n, 0, ds.feature_dim), jnp.float32)
        out = {}
        for key, pregather, fold in (('per_step', False, False),
                                     ('per_step_folded', False, True),
                                     ('pregather', True, False)):
            plan = plan_iteration(ds.graph, ds.labels, part, owner,
                                  local_idx, table.shape[1], roots,
                                  num_layers=2, fanout=2,
                                  strategy='hopgnn', pregather=pregather,
                                  sample_seed=3)
            fn = engine.get_compiled_iteration(cfg, pregather, mesh=mesh,
                                               fold_returns=fold)
            dev = jax.tree.map(jnp.asarray, plan.device_args())
            c = engine.collective_counts(fn, params, jnp.asarray(table),
                                         cache, dev,
                                         jnp.asarray(1.0, jnp.float32))
            out[key] = c.get('all_to_all', 0)
            out['T'] = plan.num_steps
        print('RESULT:' + json.dumps(out))
    """, devices=4)
    assert res["per_step"] == res["T"] + 1      # was 2T before batching
    assert res["per_step_folded"] == 2          # T feature returns folded
    assert res["pregather"] == 2


@pytest.mark.slow
def test_transformer_sharded_train_step_runs():
    """A reduced transformer train step under a real 4×2 mesh with the
    production sharding rules executes and returns finite loss."""
    res = _run_subprocess("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import get_config, smoke_variant
        from repro.data import make_batch
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_host_mesh
        from repro.launch.train import make_train_step, pick_optimizer
        from repro.models.transformer import init_params
        from repro.models.transformer.common import set_mesh_axes

        cfg = smoke_variant(get_config('qwen2-moe-a2.7b'))
        mesh = make_host_mesh(data=4, model=2)
        set_mesh_axes(dp=('data',), tp=('model',))
        params = init_params(jax.random.PRNGKey(0), cfg)
        p_specs = shd.param_pspecs(params)
        opt = pick_optimizer(cfg)
        opt_state = opt.init(params)
        o_specs = shd.opt_pspecs(opt_state, p_specs)
        batch = make_batch(cfg, 8, 32, seed=0)
        b_specs = shd.batch_pspecs(cfg, mesh, batch)
        step = jax.jit(make_train_step(cfg, opt),
                       in_shardings=(shd.to_shardings(mesh, p_specs),
                                     shd.to_shardings(mesh, o_specs),
                                     shd.to_shardings(mesh, b_specs)),
                       out_shardings=(shd.to_shardings(mesh, p_specs),
                                      shd.to_shardings(mesh, o_specs), None))
        with mesh:
            params2, opt_state, m = step(params, opt_state, batch)
            params3, _, m2 = step(params2, opt_state, batch)
        print('RESULT:' + json.dumps({'loss': float(m['loss']),
                                      'loss2': float(m2['loss'])}))
    """)
    assert res["loss"] > 0 and res["loss2"] > 0
    import math
    assert math.isfinite(res["loss"]) and math.isfinite(res["loss2"])


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_pspecs_cover_every_leaf(arch_id):
    """Every parameter leaf gets a PartitionSpec of matching rank, and every
    named axis dim is divisible-or-replicated sanely."""
    from repro.launch.sharding import param_pspecs
    cfg = get_config(arch_id)
    shapes = jax.eval_shape(
        lambda: __import__('repro.models.transformer', fromlist=['m'])
        .init_params(jax.random.PRNGKey(0), cfg))
    specs = param_pspecs(shapes)
    leaves_s, _ = jax.tree.flatten(shapes)
    leaves_p = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves_s) == len(leaves_p)
    for sh, sp in zip(leaves_s, leaves_p):
        assert isinstance(sp, jax.sharding.PartitionSpec)
        assert len(sp) <= len(sh.shape), (sh.shape, sp)
        for dim, ax in zip(sh.shape, tuple(sp) + (None,) * 8):
            if ax in ("data", "model"):
                # 16-way shards: dims must be ≥16 or sharding is wasteful
                assert dim % 8 == 0 or dim >= 16, (arch_id, sh.shape, sp)
