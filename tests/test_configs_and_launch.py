"""Configs, dry-run machinery, and roofline analyzer units."""
import json
import math

import jax
import pytest

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_applicable, smoke_variant)
from repro.launch.dryrun import collective_bytes
from repro.models.transformer.model import scan_length

# published (approximate) parameter counts, billions
PUBLISHED_B = {
    "h2o-danube-3-4b": 4.0, "pixtral-12b": 12.4, "nemotron-4-340b": 340.0,
    "qwen2.5-3b": 3.1, "whisper-base": 0.073, "qwen2-1.5b": 1.5,
    "recurrentgemma-9b": 9.0, "rwkv6-7b": 7.6,
    "qwen2-moe-a2.7b": 14.3, "deepseek-moe-16b": 16.4,
}
PUBLISHED_ACTIVE_B = {"qwen2-moe-a2.7b": 2.7, "deepseek-moe-16b": 2.8}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_count_matches_published(arch_id):
    """Exact configs must land within 35 % of the published count (vocab
    padding + analytic approximations allowed)."""
    cfg = get_config(arch_id)
    ours = cfg.param_count() / 1e9
    ref = PUBLISHED_B[arch_id]
    assert 0.65 * ref <= ours <= 1.45 * ref, (arch_id, ours, ref)
    if arch_id in PUBLISHED_ACTIVE_B:
        act = cfg.active_param_count() / 1e9
        ref_a = PUBLISHED_ACTIVE_B[arch_id]
        assert 0.7 * ref_a <= act <= 1.4 * ref_a, (arch_id, act, ref_a)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_variant_respects_bounds(arch_id):
    sv = smoke_variant(get_config(arch_id))
    assert sv.d_model <= 512
    assert sv.num_layers <= max(2, len(tuple(sv.block_pattern or ())))
    if sv.moe_num_experts:
        assert sv.moe_num_experts <= 4
    assert sv.family == get_config(arch_id).family


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_complete(arch_id, shape):
    cfg = get_config(arch_id)
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        assert "full attention" in reason
        return
    specs = input_specs(cfg, shape)
    sh = SHAPES[shape]
    if sh.kind == "decode":
        assert specs["token"].shape == (sh.global_batch,)
    else:
        total = sum(v.shape[1] for k, v in specs.items()
                    if k in ("tokens", "patches"))
        if cfg.family == "vlm":
            assert total == sh.seq_len          # patches + text = seq
        else:
            assert specs["tokens"].shape == (sh.global_batch, sh.seq_len)


def test_long500k_skips_are_exactly_the_full_attention_archs():
    skipped = {a for a in ARCH_IDS
               if not shape_applicable(get_config(a), "long_500k")[0]}
    assert skipped == {"pixtral-12b", "nemotron-4-340b", "qwen2.5-3b",
                       "whisper-base", "qwen2-1.5b", "qwen2-moe-a2.7b",
                       "deepseek-moe-16b"}


def test_scan_length_per_family():
    assert scan_length(get_config("nemotron-4-340b")) == 96
    assert scan_length(get_config("recurrentgemma-9b")) == 12   # 38 // 3
    assert scan_length(get_config("whisper-base")) == 6


def test_collective_census_parser():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
  %tup = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
  %rs = bf16[64]{0} reduce-scatter(%z), dimensions={0}
  %cp-start = bf16[32,32]{1,0} collective-permute(%w)
  %not_a_collective = f32[999]{0} add(%p, %q)
"""
    c = collective_bytes(hlo)
    assert c["count_by_op"] == {"all-gather": 1, "all-reduce": 1,
                                "all-to-all": 1, "reduce-scatter": 1,
                                "collective-permute": 1}
    assert c["bytes_by_op"]["all-gather"] == 16 * 128 * 2
    assert c["bytes_by_op"]["all-reduce"] == 256 * 4
    assert c["bytes_by_op"]["all-to-all"] == 2 * 8 * 8 * 4
    assert c["bytes_by_op"]["reduce-scatter"] == 64 * 2
    assert c["bytes_by_op"]["collective-permute"] == 32 * 32 * 2
    assert c["total_bytes"] == sum(c["bytes_by_op"].values())


def test_roofline_analyze_terms():
    from benchmarks.roofline import PEAK_FLOPS, analyze
    rec = {"mesh": "16x16", "shape": "train_4k", "arch": "x",
           "flops": 1.97e14, "bytes_accessed": 8.19e11,
           "collective_bytes_total": 5.0e10,
           "active_params": 1e9}
    a = analyze(rec)
    assert math.isclose(a["compute_s"], 1000 / 1000, rel_tol=1e-6)
    assert math.isclose(a["memory_s"], 1.0, rel_tol=1e-6)
    assert math.isclose(a["collective_s"], 1.0, rel_tol=1e-6)
    assert a["dominant"] in ("compute", "memory", "collective")
    # MODEL_FLOPS = 6 * 1e9 * (256*4096) / 256 chips
    expect = 6 * 1e9 * 256 * 4096 / 256
    assert math.isclose(a["model_flops_per_chip"], expect, rel_tol=1e-9)


def test_kv_tp_repeat_preserves_semantics():
    """Replicated KV heads must not change attention output."""
    import dataclasses
    import numpy as np
    import jax.numpy as jnp
    from repro.data import make_batch
    from repro.models.transformer import forward, init_params
    cfg = dataclasses.replace(smoke_variant(get_config("h2o-danube-3-4b")),
                              dtype="float32")
    cfg2 = dataclasses.replace(cfg, kv_tp_repeat=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 32, seed=0)
    l1, _ = forward(params, cfg, batch)
    l2, _ = forward(params, cfg2, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)
