"""Exporters: Chrome-trace/Perfetto timelines, JSONL metrics, manifests.

``chrome_trace()`` turns the ring-buffer span records into the Chrome
trace-event JSON format — load the file at https://ui.perfetto.dev or
``chrome://tracing``. One timeline track per recording thread:

- ``main``            the dispatch loop (plan waits, dispatch, syncs)
- ``prefetch``        the plan-prefetch thread (plan build + uploads)
- ``uploader``        ping-pong slot commits (virtual track: the commit
                      runs on the prefetch thread but is its own lane)
- ``cache+readahead`` the shared cache/readahead worker
- ``planner-N``       planner fan-out pool threads (when cores allow)

``run_manifest()`` stamps artifacts with git sha, jax/numpy/python
versions, and platform so any BENCH_*.json or trace file can be matched
to the commit that produced it; ``write_metrics_jsonl()`` emits a
manifest header line followed by one JSON object per row.
"""
from __future__ import annotations

import hashlib
import json
import platform
import subprocess
import sys
import time
from pathlib import Path
from typing import Optional

from repro.obs import trace as _trace

__all__ = ["run_manifest", "config_digest", "chrome_trace",
           "export_chrome_trace", "validate_chrome_trace",
           "trace_track_names", "trace_span_names", "write_metrics_jsonl"]

_REPO_ROOT = Path(__file__).resolve().parents[3]


def _git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except Exception:                                   # noqa: BLE001
        pass
    return "unknown"


def config_digest(config) -> str:
    """Short stable digest of any JSON-serializable config object."""
    blob = json.dumps(config, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def run_manifest(seed: Optional[int] = None, config=None,
                 extra: Optional[dict] = None) -> dict:
    """Provenance stamp shared by every artifact writer: git sha,
    interpreter + library versions, platform, optional seed and config
    digest."""
    try:
        import jax
        jax_ver = jax.__version__
    except Exception:                                   # noqa: BLE001
        jax_ver = "unavailable"
    try:
        import numpy as np
        np_ver = np.__version__
    except Exception:                                   # noqa: BLE001
        np_ver = "unavailable"
    m = {
        "git_sha": _git_sha(),
        "python": platform.python_version(),
        "jax": jax_ver,
        "numpy": np_ver,
        "platform": platform.platform(),
        "argv": " ".join(sys.argv),
        "time_unix": round(time.time(), 3),
    }
    if seed is not None:
        m["seed"] = int(seed)
    if config is not None:
        m["config_digest"] = config_digest(config)
    if extra:
        m.update(extra)
    return m


def _track_label(raw: str) -> str:
    """Friendly Perfetto lane names for the repo's known threads."""
    if raw == "uploader":
        return "uploader"
    if raw == "MainThread":
        return "main"
    if raw.startswith("prefetch"):
        return "prefetch"
    if raw.startswith("cache"):
        return "cache+readahead"
    if raw.startswith("plan"):
        tail = raw.rsplit("_", 1)[-1]
        return f"planner-{tail}" if tail.isdigit() else "planner"
    return raw


# Stable lane ordering in the Perfetto UI; unknown tracks sort after.
_TRACK_ORDER = {"main": 0, "prefetch": 1, "uploader": 2,
                "cache+readahead": 3}


def chrome_trace(records=None, manifest: Optional[dict] = None) -> dict:
    """Build a Chrome trace-event document from drained span records
    (defaults to the live recorder's). Complete spans become ``ph:"X"``
    events with µs timestamps relative to the recording epoch; instant
    marks become ``ph:"i"`` thread-scoped instants; every track gets a
    ``thread_name`` metadata event."""
    recs = _trace.records() if records is None else list(records)
    t0 = _trace.epoch_ns()
    labels: list[str] = []
    for r in recs:
        lab = _track_label(r.track)
        if lab not in labels:
            labels.append(lab)
    labels.sort(key=lambda s: (_TRACK_ORDER.get(s, 99), s))
    tid = {lab: i + 1 for i, lab in enumerate(labels)}

    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": "repro"},
    }]
    for lab, i in tid.items():
        events.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": i, "args": {"name": lab}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": 1,
                       "tid": i, "args": {"sort_index": i}})
    for r in recs:
        ev = {"name": r.name, "cat": "repro", "pid": 1,
              "tid": tid[_track_label(r.track)],
              "ts": (r.t0_ns - t0) / 1e3}
        if r.kind == "X":
            ev["ph"] = "X"
            ev["dur"] = (r.t1_ns - r.t0_ns) / 1e3
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        if r.tags:
            ev["args"] = {k: (v if isinstance(v, (int, float, bool))
                              else str(v)) for k, v in r.tags.items()}
        events.append(ev)
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": manifest if manifest is not None else run_manifest(),
        "otherData": {"dropped_records": _trace.dropped(),
                      "span_records": len(recs)},
    }


def export_chrome_trace(path, records=None,
                        manifest: Optional[dict] = None) -> Path:
    """Write :func:`chrome_trace` to ``path`` and return it."""
    doc = chrome_trace(records, manifest)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc))
    return path


def validate_chrome_trace(doc: dict) -> list[str]:
    """Structural check against the Chrome trace-event format; returns
    a list of problems (empty ⇒ loadable by Perfetto/chrome://tracing)."""
    problems: list[str] = []
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    named_tids = set()
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"event {i}: missing name")
        if not isinstance(ev.get("pid"), int) or \
                not isinstance(ev.get("tid"), int):
            problems.append(f"event {i}: pid/tid must be ints")
        if ph == "M":
            if ev["name"] == "thread_name":
                named_tids.add(ev.get("tid"))
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
        if ph == "i" and ev.get("s") not in ("t", "p", "g"):
            problems.append(f"event {i}: instant missing scope")
        if ev.get("tid") not in named_tids:
            problems.append(f"event {i}: tid {ev.get('tid')} has no "
                            "thread_name metadata")
    if not isinstance(doc.get("metadata"), dict):
        problems.append("metadata manifest missing")
    return problems


def trace_track_names(doc: dict) -> set:
    """Track labels present in an exported document."""
    return {ev["args"]["name"] for ev in doc.get("traceEvents", [])
            if ev.get("ph") == "M" and ev.get("name") == "thread_name"}


def trace_span_names(doc: dict) -> set:
    """Names of complete spans present in an exported document."""
    return {ev["name"] for ev in doc.get("traceEvents", [])
            if ev.get("ph") == "X"}


def write_metrics_jsonl(path, rows, manifest: Optional[dict] = None,
                        ) -> Path:
    """Emit a JSONL metrics artifact: first line is
    ``{"manifest": {...}}``, then one JSON object per row."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as f:
        f.write(json.dumps(
            {"manifest": manifest if manifest is not None
             else run_manifest()}) + "\n")
        for row in rows:
            f.write(json.dumps(row, default=str) + "\n")
    return path
