"""Unified metrics registry: named counters, gauges, histograms.

One process-wide :class:`MetricsRegistry` (module helpers ``inc`` /
``set_gauge`` / ``observe`` write to it) is the single surface all the
repo's scattered counter objects flow through: ``TierStats`` row/byte
and crc counters (repro.features), ``CommCounters`` retries/timeouts
(repro.resilience), engine retraces, fault firings, checkpoint traffic,
and the per-epoch ``EpochStats`` published by the Trainer. The legacy
dataclasses stay — they are cheap, lock-scoped views used by tests and
the merging controller — but every mutation site now *also* lands in
the registry, so one ``snapshot()``/``delta()`` answers "what happened"
without digging through sub-objects.

Naming scheme: dotted ``subsystem.metric`` —

- ``features.*``   tier rows/bytes, crc checks/failures/repairs
- ``cache.*``      installs, rows, device uploads
- ``comm.*``       resilient_call retries/timeouts
- ``engine.*``     jit traces (retraces after epoch 0 are defects)
- ``faults.*``     injected-fault firings, per kind
- ``ckpt.*``       checkpoint saves/loads
- ``epoch.*``      EpochStats published once per epoch

Counters are monotonic (deltas are meaningful); gauges are last-write
instantaneous values; histograms keep count/total/min/max (enough for
mean + envelope without per-sample storage).
"""
from __future__ import annotations

import threading
from typing import Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "registry", "inc", "set_gauge", "observe",
           "publish_epoch_stats"]


class Counter:
    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0
        self._lock = threading.Lock()

    def add(self, n: int = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> int:
        return self._v


class Gauge:
    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    @property
    def value(self) -> float:
        return self._v


class Histogram:
    __slots__ = ("name", "count", "total", "vmin", "vmax", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v

    def summary(self) -> dict:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "total": 0.0, "mean": 0.0,
                        "min": 0.0, "max": 0.0}
            return {"count": self.count, "total": self.total,
                    "mean": self.total / self.count,
                    "min": self.vmin, "max": self.vmax}


class MetricsRegistry:
    """Create-on-first-use registry with one snapshot/delta API."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name))
        return h

    def snapshot(self) -> dict:
        """Point-in-time copy: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count,total,mean,min,max}}}``."""
        with self._lock:
            cs = list(self._counters.values())
            gs = list(self._gauges.values())
            hs = list(self._hists.values())
        return {"counters": {c.name: c.value for c in cs},
                "gauges": {g.name: g.value for g in gs},
                "histograms": {h.name: h.summary() for h in hs}}

    def delta(self, prev: dict) -> dict:
        """Change since a prior :meth:`snapshot`. Counters subtract
        (names absent from ``prev`` count from 0), gauges report their
        current value, histograms subtract count/total."""
        now = self.snapshot()
        pc = prev.get("counters", {})
        ph = prev.get("histograms", {})
        return {
            "counters": {k: v - pc.get(k, 0)
                         for k, v in now["counters"].items()},
            "gauges": dict(now["gauges"]),
            "histograms": {
                k: {"count": s["count"] - ph.get(k, {}).get("count", 0),
                    "total": s["total"] - ph.get(k, {}).get("total", 0.0)}
                for k, s in now["histograms"].items()},
        }

    def reset(self) -> None:
        """Drop every instrument (tests / fresh runs)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    return _REGISTRY


def inc(name: str, n: int = 1) -> None:
    _REGISTRY.counter(name).add(n)


def set_gauge(name: str, v: float) -> None:
    _REGISTRY.gauge(name).set(v)


def observe(name: str, v: float) -> None:
    _REGISTRY.histogram(name).observe(v)


# EpochStats fields that are instantaneous (gauges). Remaining int
# fields are additive across epochs (counters); remaining float fields
# are per-epoch times fed into histograms (count/total/min/max keeps
# both the sum and the envelope).
_EPOCH_GAUGES = frozenset({
    "epoch", "loss", "acc", "cache_hit_rate", "num_steps",
})
_EPOCH_SKIP = frozenset({"degradations"})


def publish_epoch_stats(st, prefix: str = "epoch") -> None:
    """Route one finished epoch's ``EpochStats`` into the registry as
    ``epoch.<field>`` instruments: gauges for instantaneous values
    (loss, hit rate, ...), counters for additive ints (rows, retries,
    rollbacks, ...), histograms for per-epoch times (time_s,
    steady_time_s, plan_time_s, ...)."""
    import dataclasses
    for f in dataclasses.fields(st):
        if f.name in _EPOCH_SKIP:
            continue
        v = getattr(st, f.name)
        if v is None or isinstance(v, (tuple, list, str)):
            continue
        name = f"{prefix}.{f.name}"
        if f.name in _EPOCH_GAUGES:
            set_gauge(name, float(v))
        elif isinstance(v, float):
            observe(name, v)
        else:
            inc(name, int(v))
    degr = getattr(st, "degradations", ()) or ()
    if degr:
        inc(f"{prefix}.degradations", len(degr))
