"""Span tracing: a thread-safe, preallocated ring-buffer recorder.

Design constraints (this module sits on the training hot path):

- **Near-zero cost when disabled.** ``span()``/``event()`` check one
  module-level bool and return a shared no-op context manager — no
  allocation, no clock read, no lock.
- **Lock-free when enabled.** Each thread records into its own
  preallocated ring (``threading.local``); the hot path is two
  ``perf_counter_ns`` reads and one list-slot store per span. The global
  lock is touched only on first use per thread and at drain time.
- **Nesting-safe.** A per-thread depth counter stamps every span with
  its nesting level, so the exporter can rebuild the flame even though
  spans are recorded at *exit* (children land before parents).
- **Read-only w.r.t. training state.** Tracing reads clocks and writes
  host-side tuples; it never touches params, plans, rngs, or device
  buffers — tracing on is bit-identical to tracing off by construction.

Timeline semantics in the non-blocking pipelined loop: a ``dispatch``
span measures *host-side enqueue* (near-zero in steady state), not
device execution. Device time shows up in the synced windows the loop
already has — the ``loss.sync`` / ``trace.sync`` spans wrapping
``block_until_ready`` — so device cost per window is read off the sync
spans, exactly like the engine's steady-state timing contract.

Track ids are thread names by default; a ``track=`` override lets work
that borrows another thread record on its logical track (the uploader
commit runs on the prefetch thread but belongs on the "uploader"
track). Export to Perfetto via :mod:`repro.obs.export`.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

__all__ = ["enable", "disable", "is_enabled", "clear", "span", "event",
           "records", "dropped", "epoch_ns", "SpanRecord"]

_DEFAULT_CAPACITY = 1 << 14          # records per thread track

_lock = threading.Lock()
_enabled = False
_capacity = _DEFAULT_CAPACITY
_generation = 0                      # bumped by enable()/clear(): stale
#                                      thread-local rings are abandoned
_epoch_ns = 0                        # perf_counter_ns at enable/clear
_tracks: list = []                   # live _Track registry (drain order)
_tls = threading.local()


class _Track:
    """Per-thread preallocated ring. Only its owner thread writes; the
    GIL makes the slot store + counter bump safe to read concurrently
    (a drain may miss the very latest record, never see a torn one)."""

    __slots__ = ("thread", "gen", "buf", "n", "depth")

    def __init__(self, thread: str, gen: int, capacity: int):
        self.thread = thread
        self.gen = gen
        self.buf: list = [None] * capacity
        self.n = 0                   # total records ever pushed
        self.depth = 0               # current span nesting level

    def push(self, rec) -> None:
        self.buf[self.n % len(self.buf)] = rec
        self.n += 1


def _get_track() -> _Track:
    tr = getattr(_tls, "track", None)
    if tr is None or tr.gen != _generation:
        tr = _Track(threading.current_thread().name, _generation, _capacity)
        _tls.track = tr
        with _lock:
            if tr.gen == _generation:    # lost race with clear(): drop
                _tracks.append(tr)
    return tr


@dataclass(frozen=True)
class SpanRecord:
    """One drained record. ``kind`` is ``"X"`` (complete span) or
    ``"i"`` (instant event); times are perf_counter_ns."""
    kind: str
    name: str
    track: str
    t0_ns: int
    t1_ns: int
    depth: int
    tags: Optional[dict]

    @property
    def dur_ns(self) -> int:
        return self.t1_ns - self.t0_ns


class _Noop:
    """Shared do-nothing context manager returned while disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _Noop()


class _Span:
    __slots__ = ("name", "track", "tags", "_t0", "_tr")

    def __init__(self, name: str, track: Optional[str], tags):
        self.name = name
        self.track = track
        self.tags = tags or None

    def __enter__(self):
        tr = _get_track()
        self._tr = tr
        tr.depth += 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        tr = self._tr
        tr.depth -= 1
        tr.push(("X", self.name, self.track or tr.thread,
                 self._t0, t1, tr.depth, self.tags))
        return False


def span(name: str, track: Optional[str] = None, **tags):
    """Context manager timing a named region on the calling thread's
    track (or the ``track=`` override). ``**tags`` become Perfetto args.
    When tracing is disabled this is one bool check and a shared no-op
    object — safe to leave on the hottest paths."""
    if not _enabled:
        return _NOOP
    return _Span(name, track, tags)


def event(name: str, track: Optional[str] = None, **tags) -> None:
    """Record an instant mark (fault firing, retry, retrace, ...)."""
    if not _enabled:
        return
    tr = _get_track()
    t = time.perf_counter_ns()
    tr.push(("i", name, track or tr.thread, t, t, tr.depth, tags or None))


def enable(capacity: int = _DEFAULT_CAPACITY) -> None:
    """Start recording (drops anything previously recorded).
    ``capacity`` is the per-thread ring size; overflow overwrites the
    oldest records and is reported by :func:`dropped`."""
    global _enabled, _capacity, _generation, _epoch_ns
    with _lock:
        _capacity = int(capacity)
        _generation += 1
        _tracks.clear()
        _epoch_ns = time.perf_counter_ns()
        _enabled = True


def disable() -> None:
    """Stop recording; already-recorded spans stay drainable."""
    global _enabled
    _enabled = False


def is_enabled() -> bool:
    return _enabled


def clear() -> None:
    """Drop all recorded spans (keeps the enabled/disabled state)."""
    global _generation, _epoch_ns
    with _lock:
        _generation += 1
        _tracks.clear()
        _epoch_ns = time.perf_counter_ns()


def epoch_ns() -> int:
    """perf_counter_ns origin of the current recording session."""
    return _epoch_ns


def records() -> list[SpanRecord]:
    """Drain a consistent-enough snapshot of every track, oldest first
    globally (sorted by start time). Non-destructive."""
    with _lock:
        tracks = list(_tracks)
    out: list[SpanRecord] = []
    for tr in tracks:
        n, cap = tr.n, len(tr.buf)
        for i in range(max(0, n - cap), n):
            rec = tr.buf[i % cap]
            if rec is not None:
                out.append(SpanRecord(*rec))
    out.sort(key=lambda r: (r.t0_ns, -r.depth))
    return out


def dropped() -> int:
    """Total records overwritten by ring wraparound since enable()."""
    with _lock:
        tracks = list(_tracks)
    return sum(max(0, tr.n - len(tr.buf)) for tr in tracks)
