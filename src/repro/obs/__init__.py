"""repro.obs — span tracing, metrics registry, Perfetto export.

Three small pieces threaded through the whole training stack:

- :mod:`repro.obs.trace`   ring-buffer span recorder (``span``/``event``)
- :mod:`repro.obs.metrics` unified counter/gauge/histogram registry
- :mod:`repro.obs.export`  Chrome-trace/Perfetto + JSONL emitters with
  a run manifest (git sha, versions, platform)

Tracing is off by default and must never change numerics: a run with
``trace.enable()`` is bit-identical to the same run without (CI-gated
in benchmarks/obs.py along with a ≤1.05× steady-iteration overhead
gate).
"""
from repro.obs import metrics, trace  # noqa: F401
from repro.obs.export import (chrome_trace, export_chrome_trace,  # noqa: F401
                              run_manifest, write_metrics_jsonl)
from repro.obs.metrics import registry  # noqa: F401
from repro.obs.trace import event, span  # noqa: F401

__all__ = ["trace", "metrics", "span", "event", "registry",
           "run_manifest", "chrome_trace", "export_chrome_trace",
           "write_metrics_jsonl"]
