"""repro.train — compile-once training loop for the LeapGNN engine.

Shape budgets (quantized device shapes, one bucket per merge pattern plus
the global cache height c_max), a prefetching double-buffered planner
backed by a multi-core planning thread pool, the async device pipeline
(fused donated optimizer step, non-blocking dispatch with epoch-level loss
sync, ping-pong plan uploads, optional K-stacked scan dispatch — see
pipeline.py for the timing semantics and the donation contract), the §5.3
merging controller with a compile-free timing signal, the repro.cache
remote-feature cache (policy-driven resident hot rows, deterministic
merge-pattern-aware epoch prefetch, refresh off the critical path), eval,
and checkpoint/resume — one Trainer instead of per-file hand-rolled epoch
loops. See loop.py for the design notes, including the planning-pool
contract; the vectorized host planner itself (SlotMap layout: per-shard
id-sorted segments + cached dense translation rows) lives in
repro.core.pregather.
"""
from repro.train.budget import ShapeBudget, next_bucket
from repro.train.loop import EpochStats, Trainer, merging_walk
from repro.train.pipeline import (EpochRunResult, PlanUploader,
                                  run_pipelined_epoch)

__all__ = ["ShapeBudget", "next_bucket", "EpochStats", "Trainer",
           "merging_walk", "EpochRunResult", "PlanUploader",
           "run_pipelined_epoch"]
