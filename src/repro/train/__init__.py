"""repro.train — compile-once training loop for the LeapGNN engine.

Shape budgets (quantized device shapes), a prefetching double-buffered
planner, the §5.3 merging controller with a compile-free timing signal,
eval, and checkpoint/resume — one Trainer instead of per-file hand-rolled
epoch loops. See loop.py for the design notes.
"""
from repro.train.budget import ShapeBudget, next_bucket
from repro.train.loop import EpochStats, Trainer, merging_walk

__all__ = ["ShapeBudget", "next_bucket", "EpochStats", "Trainer",
           "merging_walk"]
