"""Shape budgets: quantized device shapes for a compile-once hot path.

The planner (repro.core.strategies) emits rectangular arrays sized to the
*exact* needs of one iteration — ``batch_pad`` to the largest root group,
``r_max`` to the largest per-peer fetch. Exact sizes differ between
iterations, so every plan used to carry fresh device shapes and the jitted
iteration retraced on nearly every step; epoch wall-times then measured XLA
compilation rather than execution (the bug the merging controller's timing
signal inherited).

A :class:`ShapeBudget` fixes ``batch_pad``/``r_max`` per run instead: sizes
are quantized to power-of-two buckets learned from the first plan, every
subsequent plan is forced into the same bucket (padding roots are local and
zero-weighted; padded request slots fetch row 0 and are never read, so
numerics are unchanged — see the budgeted-gradient-parity test), and an
overflow re-buckets explicitly to the next power of two. One bucket ⇒ one
jit trace; re-buckets are counted and visible.

Buckets are kept **per merge pattern** (keyed by the plan's ``num_steps``):
merging folds the same roots into fewer, larger (shard, step) groups, so a
pattern change legitimately needs a larger ``batch_pad`` — but growing one
global bucket would retrace *every* pattern and, worse, reverting the merge
would keep the oversized shapes forever. With per-pattern buckets a §5.3
examination walk (T → T-1 → revert to T) reuses the T bucket untouched:
pattern changes never force a global re-bucket.

``c_max`` — the height of the cached workspace region a plan was built
against (repro.cache) — is a third budgeted dimension, but a *global* one:
the cache store is shared across merge patterns, so its shape is too. The
planner raises ``PlanOverflow("c_max", ...)`` when a cache index outgrows
the budget (a store re-pad after cache-size drift) and :meth:`grow`
re-buckets it explicitly, exactly like the other two dimensions.
"""
from __future__ import annotations

import dataclasses


def next_bucket(n: int, minimum: int = 1) -> int:
    """Smallest power of two ≥ max(n, minimum, 1)."""
    n = max(int(n), int(minimum), 1)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class ShapeBudget:
    """Per-run quantized sizes for the planner's rectangular arrays.

    ``batch_pad``/``r_max`` given to the constructor seed every new
    pattern's bucket (both nonzero: used as-is, no probe; one nonzero: a
    floor merged with the probe). After each :meth:`plan` call they mirror
    the *active* pattern's bucket, so existing callers keep reading the
    shapes the last plan was built with. ``buckets`` maps
    ``num_steps -> [batch_pad, r_max]`` and is the source of truth.
    """

    batch_pad: int = 0
    r_max: int = 0
    c_max: int = 0            # cached-region height (global, not per-pattern)
    l_max: int = 0            # streamed compacted-local height (per-pattern;
    #                           repro.features — 0 when not streaming)
    min_batch_pad: int = 8
    min_r_max: int = 8
    min_l_max: int = 8
    max_rebuckets: int = 8
    # Probe headroom for r_max: the probe only sees one iteration's exact
    # per-peer fetch counts, and those vary batch-to-batch (sampling is
    # data-dependent), so bucketing the bare probe routinely overflows a
    # few iterations later — one PlanOverflow re-bucket, one full XLA
    # recompile mid-training (measured ~100× an iteration). Bucketing
    # probe × headroom instead absorbs ordinary variance; padded request
    # slots fetch row 0 and are never read, so the cost is exchange-buffer
    # bytes, not numerics. batch_pad gets no headroom: padded roots carry
    # real (weight-0) tree compute, and overflow there is assignment-skew
    # driven, which the per-pattern buckets already isolate.
    r_max_headroom: float = 1.5
    # l_max headroom (streamed mode): the touched-local set varies batch to
    # batch like per-peer fetches do, but less violently (it is bounded by
    # the whole tree, most of which IS local) — a lighter pad suffices.
    l_max_headroom: float = 1.25
    # --- counters (observability; the compile-once tests read these) ---
    rebuckets: int = 0
    plans_built: int = 0
    probes: int = 0
    buckets: dict = dataclasses.field(default_factory=dict)
    # num_steps -> l_max bucket, kept SEPARATE from ``buckets`` so existing
    # readers of the [batch_pad, r_max] pairs never see a layout change
    l_buckets: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        # constructor-given sizes become the seed for every new bucket
        self._seed = (int(self.batch_pad), int(self.r_max))
        self._active_key = None

    def signature(self) -> tuple[int, int]:
        return (self.batch_pad, self.r_max)

    def bucket_shapes(self, num_steps) -> "tuple[int, int, int, int] | None":
        """(batch_pad, r_max, c_max, l_max) of the bucket serving this merge
        pattern, or None if the pattern hasn't been planned yet. The
        pipeline uploader's ping-pong stability check reads this: every
        committed plan of a pattern must carry exactly these shapes, or
        an upload would imply a retrace. ``l_max`` is 0 for patterns that
        have never planned streamed."""
        b = self.buckets.get(int(num_steps))
        return None if b is None else (int(b[0]), int(b[1]), int(self.c_max),
                                       int(self.l_buckets.get(int(num_steps),
                                                              0)))

    def grow(self, field: str, needed: int) -> None:
        """Explicit overflow re-bucketing: jump to the next power-of-two
        bucket that fits ``needed`` (strictly larger than the current one).
        Only the active pattern's bucket grows — others keep their shapes
        (and their compiled programs)."""
        self.rebuckets += 1
        if field == "batch_pad":
            self.batch_pad = next_bucket(needed, self.batch_pad + 1)
        elif field == "r_max":
            self.r_max = next_bucket(needed, self.r_max + 1)
        elif field == "c_max":
            # global (cross-pattern) dimension — see module doc
            self.c_max = next_bucket(needed, self.c_max + 1)
            return
        elif field == "l_max":
            # per-pattern like batch_pad/r_max, but stored in l_buckets
            self.l_max = next_bucket(needed, self.l_max + 1)
            if self._active_key is not None:
                self.l_buckets[self._active_key] = self.l_max
            return
        else:
            raise ValueError(f"unknown budget field {field!r}")
        if self._active_key is not None:
            self.buckets[self._active_key] = [self.batch_pad, self.r_max]

    @staticmethod
    def _pattern_key(plan_kwargs: dict):
        """The plan's merge pattern (num_steps), derived without planning:
        an explicit assignment carries it; otherwise hopgnn's rotation has
        one step per model and the one-step strategies have 1."""
        assignment = plan_kwargs.get("assignment")
        if assignment is not None:
            return int(assignment.num_steps)
        roots = plan_kwargs.get("roots_per_model")
        if plan_kwargs.get("strategy", "hopgnn") == "hopgnn" \
                and roots is not None:
            return len(roots)
        return 1 if roots is not None else "default"

    def plan(self, planner=None, **plan_kwargs):
        """Build an IterationPlan under this budget (bucketed shapes).

        ``planner`` defaults to :func:`repro.core.plan_iteration`; any
        callable with the same keyword contract (and raising
        :class:`repro.core.PlanOverflow` on overflow) works.
        """
        from repro.core.pregather import PlanOverflow
        if planner is None:
            from repro.core.strategies import plan_iteration as planner
        key = self._pattern_key(plan_kwargs)
        fs = plan_kwargs.get("feature_store")
        streamed = fs is not None and not getattr(fs, "resident", True)
        bucket = self.buckets.get(key)
        need_l = streamed and key not in self.l_buckets
        probe = None

        def _probe():
            # First plan of this pattern: probe exact sizes once, then
            # bucket. The probe is host-side numpy only — it never touches
            # the device engine, so it costs one extra planning pass per
            # *pattern* and nothing after. (In streamed mode the probe does
            # pay a host feature gather; still once per pattern.)
            self.probes += 1
            return planner(**plan_kwargs)

        if bucket is None:
            seed_bp, seed_rm = self._seed
            if seed_bp and seed_rm and not need_l:
                bucket = [seed_bp, seed_rm]
            else:
                probe = _probe()
                bucket = [next_bucket(probe.batch_pad,
                                      max(self.min_batch_pad, seed_bp)),
                          next_bucket(int(probe.r_max
                                          * max(self.r_max_headroom, 1.0)),
                                      max(self.min_r_max, seed_rm))]
            self.buckets[key] = bucket
        if need_l:
            if probe is None:
                probe = _probe()
            self.l_buckets[key] = next_bucket(
                int(probe.l_max * max(self.l_max_headroom, 1.0)),
                self.min_l_max)
        self._active_key = key
        self.batch_pad, self.r_max = bucket
        # c_max ceiling only applies to cache-aware plans; passing 0/None
        # lets the first such plan teach the budget its height.
        cache_kw = {}
        if plan_kwargs.get("cache_index") is not None:
            cache_kw = dict(c_max=self.c_max or None)
        stream_kw = {}
        if streamed:
            self.l_max = self.l_buckets[key]
            stream_kw = dict(l_max=self.l_max)
        for _ in range(self.max_rebuckets + 1):
            try:
                out = planner(**plan_kwargs, batch_pad=self.batch_pad,
                              r_max=self.r_max, **cache_kw, **stream_kw)
                self.plans_built += 1
                if getattr(out, "c_max", 0) > self.c_max:
                    self.c_max = int(out.c_max)    # first learn, no rebucket
                return out
            except PlanOverflow as e:
                self.grow(e.field, e.needed)
                if e.field == "c_max":
                    cache_kw = dict(c_max=self.c_max)
                elif e.field == "l_max":
                    stream_kw = dict(l_max=self.l_max)
        raise RuntimeError(
            f"shape budget failed to converge after {self.max_rebuckets} "
            f"re-buckets (batch_pad={self.batch_pad}, r_max={self.r_max})")

    # ------------------------------------------------------------------
    # Serving buckets (repro.serve): the same compile-once discipline for
    # online inference micro-batches. Serving has two quantized dimensions:
    # the padded root count (a pow2 ladder up to the server's max batch,
    # keyed "serve:<batch_pad>" in ``buckets``) and the padded host-fetch
    # height u_max of that rung (stored as the rung's second slot, grown
    # with r_max_headroom exactly like training fetches). Keys are strings,
    # so serve rungs ride state_dict()/load_state() untouched — a server
    # restored from a training checkpoint's budget state plans straight
    # into the warmed shapes and never retraces.
    # ------------------------------------------------------------------

    def serve_batch_pad(self, batch: int) -> int:
        """Quantized root count for a serving micro-batch of ``batch``
        requests: the pow2 rung ≥ max(batch, min_batch_pad). A new rung
        starts with no fetch bucket (``serve_fetch_pad`` learns it)."""
        bp = next_bucket(batch, self.min_batch_pad)
        key = f"serve:{bp}"
        if key not in self.buckets:
            self.buckets[key] = [bp, 0]
            self.probes += 1
        return bp

    def serve_fetch_pad(self, batch_pad: int, fetch_rows: int) -> int:
        """Padded host-fetch height (u_max) for rung ``batch_pad``.

        First call on a rung buckets ``fetch_rows × r_max_headroom`` (the
        warmup probe); later calls reuse the bucket, re-bucketing (counted
        in ``rebuckets`` — one retrace downstream) only on overflow."""
        key = f"serve:{int(batch_pad)}"
        b = self.buckets.setdefault(key, [int(batch_pad), 0])
        if b[1] == 0:
            b[1] = next_bucket(int(fetch_rows * max(self.r_max_headroom, 1.0)),
                               self.min_r_max)
        elif fetch_rows > b[1]:
            self.rebuckets += 1
            b[1] = next_bucket(fetch_rows, b[1] + 1)
        return int(b[1])

    def serve_rungs(self) -> list:
        """The learned serve ladder: sorted [(batch_pad, u_max), ...]."""
        out = [(int(v[0]), int(v[1])) for k, v in self.buckets.items()
               if isinstance(k, str) and k.startswith("serve:")]
        return sorted(out)

    # ------------------------------------------------------------------
    # Persistence (repro.checkpoint): a resumed run must reuse the exact
    # buckets of the original run, or its first epoch re-probes/re-traces.
    # ------------------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-serializable bucket state. Buckets are stored as
        ``[key, ...]`` rows (not an object) so integer pattern keys survive
        the JSON round-trip with their type intact."""
        return {
            "buckets": [[k, int(v[0]), int(v[1])]
                        for k, v in self.buckets.items()],
            "l_buckets": [[k, int(v)] for k, v in self.l_buckets.items()],
            "c_max": int(self.c_max),
            "batch_pad": int(self.batch_pad),
            "r_max": int(self.r_max),
            "l_max": int(self.l_max),
            "r_max_headroom": float(self.r_max_headroom),
            "l_max_headroom": float(self.l_max_headroom),
            "rebuckets": int(self.rebuckets),
        }

    def load_state(self, state: dict) -> None:
        """Restore :meth:`state_dict` output: every pattern the original
        run learned plans straight into its old bucket — no probe, no
        overflow, and (process-wide compile cache permitting) no retrace
        on the resumed run's first epoch."""
        def _k(k):
            return k if isinstance(k, str) else int(k)
        self.buckets = {_k(k): [int(bp), int(rm)]
                        for k, bp, rm in state.get("buckets", [])}
        self.l_buckets = {_k(k): int(l)
                          for k, l in state.get("l_buckets", [])}
        self.c_max = int(state.get("c_max", self.c_max))
        self.batch_pad = int(state.get("batch_pad", self.batch_pad))
        self.r_max = int(state.get("r_max", self.r_max))
        self.l_max = int(state.get("l_max", self.l_max))
        self.r_max_headroom = float(state.get("r_max_headroom",
                                              self.r_max_headroom))
        self.l_max_headroom = float(state.get("l_max_headroom",
                                              self.l_max_headroom))
        self.rebuckets = int(state.get("rebuckets", self.rebuckets))
        self._active_key = None
