"""Shape budgets: quantized device shapes for a compile-once hot path.

The planner (repro.core.strategies) emits rectangular arrays sized to the
*exact* needs of one iteration — ``batch_pad`` to the largest root group,
``r_max`` to the largest per-peer fetch. Exact sizes differ between
iterations, so every plan used to carry fresh device shapes and the jitted
iteration retraced on nearly every step; epoch wall-times then measured XLA
compilation rather than execution (the bug the merging controller's timing
signal inherited).

A :class:`ShapeBudget` fixes ``batch_pad``/``r_max`` per run instead: sizes
are quantized to power-of-two buckets learned from the first plan, every
subsequent plan is forced into the same bucket (padding roots are local and
zero-weighted; padded request slots fetch row 0 and are never read, so
numerics are unchanged — see the budgeted-gradient-parity test), and an
overflow re-buckets explicitly to the next power of two. One bucket ⇒ one
jit trace; re-buckets are counted and visible.
"""
from __future__ import annotations

import dataclasses


def next_bucket(n: int, minimum: int = 1) -> int:
    """Smallest power of two ≥ max(n, minimum, 1)."""
    n = max(int(n), int(minimum), 1)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class ShapeBudget:
    """Per-run quantized sizes for the planner's rectangular arrays.

    ``batch_pad``/``r_max`` of 0 mean "not yet learned": the first
    :meth:`plan` call probes exact sizes and buckets them (never below the
    ``min_*`` floors, which give headroom against immediate re-bucketing).
    """

    batch_pad: int = 0
    r_max: int = 0
    min_batch_pad: int = 8
    min_r_max: int = 8
    max_rebuckets: int = 8
    # --- counters (observability; the compile-once tests read these) ---
    rebuckets: int = 0
    plans_built: int = 0

    def signature(self) -> tuple[int, int]:
        return (self.batch_pad, self.r_max)

    def grow(self, field: str, needed: int) -> None:
        """Explicit overflow re-bucketing: jump to the next power-of-two
        bucket that fits ``needed`` (strictly larger than the current one)."""
        self.rebuckets += 1
        if field == "batch_pad":
            self.batch_pad = next_bucket(needed, self.batch_pad + 1)
        elif field == "r_max":
            self.r_max = next_bucket(needed, self.r_max + 1)
        else:
            raise ValueError(f"unknown budget field {field!r}")

    def plan(self, planner=None, **plan_kwargs):
        """Build an IterationPlan under this budget (bucketed shapes).

        ``planner`` defaults to :func:`repro.core.plan_iteration`; any
        callable with the same keyword contract (and raising
        :class:`repro.core.PlanOverflow` on overflow) works.
        """
        from repro.core.pregather import PlanOverflow
        if planner is None:
            from repro.core.strategies import plan_iteration as planner
        if not (self.batch_pad and self.r_max):
            # First call: probe exact sizes once, then bucket. The probe is
            # host-side numpy only — it never touches the device engine, so
            # it costs one extra planning pass on iteration 0 and nothing
            # after.
            probe = planner(**plan_kwargs)
            self.batch_pad = max(self.batch_pad,
                                 next_bucket(probe.batch_pad,
                                             self.min_batch_pad))
            self.r_max = max(self.r_max,
                             next_bucket(probe.r_max, self.min_r_max))
        for _ in range(self.max_rebuckets + 1):
            try:
                out = planner(**plan_kwargs, batch_pad=self.batch_pad,
                              r_max=self.r_max)
                self.plans_built += 1
                return out
            except PlanOverflow as e:
                self.grow(e.field, e.needed)
        raise RuntimeError(
            f"shape budget failed to converge after {self.max_rebuckets} "
            f"re-buckets (batch_pad={self.batch_pad}, r_max={self.r_max})")
