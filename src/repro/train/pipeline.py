"""Async device pipeline (PR 5 tentpole): keep the device saturated.

BENCH_end_to_end.json showed steady *wall* time ~27× steady *device* time —
pure host overhead: an eager per-leaf optimizer update (tens of op
dispatches per iteration), a blocking ``float(loss)`` sync every step, and
a fresh host→device conversion of every plan array on every call. This
module closes that gap with three cooperating pieces:

* **Fused dispatch** — the Trainer steps through
  ``repro.core.distributed.get_compiled_train_step``: iteration + optimizer
  update as ONE compiled program with ``params``/``opt_state`` donation.
  One dispatch per iteration replaces the grads round-trip plus the eager
  update chain.
* **Non-blocking loop** (:func:`run_pipelined_epoch`) — losses stay on
  device and are synced at the epoch boundary, so the host races ahead
  building and uploading plans while the device executes. Backpressure:
  every ``loss_sync_iters`` dispatches (Trainer default 16; 0 disables)
  the loop blocks on the newest loss, bounding how many in-flight
  iterations — each pinning its committed plan buffers — can queue on a
  device-bound config.
* **Plan upload double-buffering** (:class:`PlanUploader`) — the plan
  prefetch thread ``device_put``s plan i+1's device args into ping-pong
  slots while plan i executes, and stamps the plan (``plan.committed``) so
  the engine's arg-prep fast path skips the per-leaf conversion walk on the
  critical path. Slots alternate so the upload for i+1 never retires the
  buffers iteration i is still consuming; shape stability against the
  ShapeBudget bucket is asserted (a shape change would mean a retrace).
* **K-stacking** (optional, ``pipeline_stack=K``) — K same-bucket plans are
  stacked on a leading axis and the fused step is ``lax.scan``-ed over
  them: one dispatch per K iterations, for regimes where per-iteration
  device time is smaller than dispatch overhead.

Timing semantics (this changes what EpochStats fields mean in pipelined
mode): per-iteration wall times are *dispatch* times — the device has not
necessarily finished when the call returns. Steady-state time is therefore
measured on a synced window: the epoch's dispatch loop runs free, a
``block_until_ready`` closes the window, and the window wall over its
iteration count is the steady per-iteration estimate. Whenever a dispatch
(re)traces, the window restarts *after* a sync — so the estimate stays
compile-free and the §5.3 merging controller keeps getting the signal the
Trainer promised it (see repro.core.merging).

Donation contract: the fused step donates params/opt_state. The Trainer
therefore owns its parameter buffers — caller-supplied initial params are
copied once at construction — and always continues from the returned
trees. Never hold a reference to a pre-step params tree across a step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.core import distributed as engine
from repro.obs.trace import event as obs_event, span as obs_span


class PlanUploader:
    """Ping-pong host→device committer for IterationPlan device args.

    ``commit(plan)`` runs on the plan prefetch thread: it ``device_put``s
    the plan's device_args tree (and the f32 denom scalar) and stamps the
    plan (``plan.committed``) for the engine's arg-prep fast path. The
    ping-pong pairing is realized by the in-flight plans themselves: plan
    i's committed tree is still referenced (and being consumed by the
    device) while plan i+1's upload lands in its own fresh buffers, so an
    upload can never retire arrays the previous dispatch still needs.

    Shape discipline: within one merge pattern every upload must carry the
    same shape signature (uploads never imply a retrace). Deviations are
    counted in ``shape_changes`` (raised under ``strict``) — a legitimate
    change exists only at an explicit budget re-bucket; with ``budget``
    given, every committed plan is also checked against the ShapeBudget
    bucket it claims to be built under, which updates the expected
    signature exactly when the bucket itself grew.
    """

    def __init__(self, budget=None, strict: bool = False, view=None):
        self.budget = budget
        self.strict = strict
        self.view = view               # MembershipView (world-stale refusal)
        self._sigs: dict = {}          # pattern (num_steps) -> signature
        self._buckets: dict = {}       # pattern -> bucket_shapes snapshot
        self.uploads = 0
        self.shape_changes = 0

    def commit(self, plan) -> None:
        if self.view is not None:
            # refuse to ship a dead world's bytes to the device: a plan
            # stamped under an older membership generation must not commit
            # (repro.membership; the dispatch boundary re-checks, but the
            # upload is the first place stale buffers would be staged)
            ei = getattr(plan, "epoch_it", (-1, -1))
            self.view.check_generation(getattr(plan, "generation", -1),
                                       epoch=ei[0], it=ei[1])
        expect = None
        if self.budget is not None:
            expect = self.budget.bucket_shapes(plan.num_steps)
            if expect is not None:
                bp, rm, cm, lm = expect
                l_max = getattr(plan, "l_max", 0)
                if (plan.batch_pad, plan.r_max) != (bp, rm) \
                        or plan.c_max not in (0, cm) \
                        or l_max not in (0, lm):
                    raise AssertionError(
                        f"plan shapes ({plan.batch_pad}, {plan.r_max}, "
                        f"{plan.c_max}, {l_max}) drifted from budget bucket "
                        f"({bp}, {rm}, {cm}, {lm}) for pattern "
                        f"{plan.num_steps}")
        dev = jax.tree.map(
            lambda x: x if isinstance(x, jax.Array) else jax.device_put(x),
            plan.device_args())
        denom = jax.device_put(np.float32(plan.global_batch))
        sig = engine._shape_sig(dev)
        key = plan.num_steps
        prev = self._sigs.get(key)
        if prev is not None and prev != sig:
            if self._buckets.get(key) != expect:
                # explicit budget re-bucket: the new signature is the
                # expected one from here on (one retrace, counted by the
                # engine trace log, not a stability violation)
                pass
            else:
                self.shape_changes += 1
                if self.strict:
                    raise AssertionError(
                        f"upload shape change within pattern {key}: "
                        f"{prev} -> {sig}")
        self._sigs[key] = sig
        self._buckets[key] = expect
        plan.committed = {"dev": dev, "denom": denom}
        self.uploads += 1


def stack_committed(plans):
    """Stack K plans' device args on a new leading axis for the scanned
    fused step. Committed plans stack their already-resident buffers
    (device-side stack, no host copy); uncommitted ones are uploaded
    leaf-by-leaf first."""
    import jax.numpy as jnp
    devs, denoms = [], []
    for p in plans:
        if p.committed is not None:
            devs.append(p.committed["dev"])
            denoms.append(p.committed["denom"])
        else:
            devs.append(jax.tree.map(engine._as_device, p.device_args()))
            denoms.append(jnp.asarray(float(p.global_batch), jnp.float32))
    dev_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *devs)
    return dev_stack, jnp.stack(denoms)


@dataclasses.dataclass
class EpochRunResult:
    """What one epoch's iteration loop hands back to Trainer.fit —
    produced by both the pipelined loop here and the Trainer's synchronous
    loop, so fit() assembles EpochStats identically for both."""

    losses: List[float]          # per-iteration losses, in order
    wall_s: float                # dispatch-loop wall incl. final sync
    steady_iter_s: Optional[float]   # compile-free synced-window estimate
    #                                  (None: every window contained a trace)
    dispatch_s: float            # host time spent inside dispatch calls
    traces: int                  # engine trace-log delta over the epoch
    remote_rows: int
    cache_hit_rows: int
    num_steps: int
    # --- streamed feature path (repro.features; zeros when resident) ---
    tier1_rows: int = 0          # host hot-tier rows served to plan gathers
    tier2_rows: int = 0          # backing/mmap rows served (hot-tier misses)
    upload_bytes: int = 0        # plan-carried feature bytes shipped to dev


def run_pipelined_epoch(trainer, epoch: int, iters: int,
                        batch_per_model: int, submit: Callable,
                        stack: int = 1,
                        loss_sync_iters: int = 0) -> EpochRunResult:
    """One epoch of non-blocking fused dispatch.

    ``submit(fn, *args)`` is the Trainer's plan-prefetch submitter (thread
    pool or inline). Up to ``stack + 1`` plan builds are kept in flight so
    a K-stacked dispatch never starves; each build commits its device
    upload on the prefetch thread (PlanUploader), overlapping the transfer
    with device execution of the previous dispatch.
    """
    K = max(1, int(stack))
    tc_start = engine.trace_count()
    t_epoch = time.perf_counter()

    futs: deque = deque()          # (it, future) pairs, in order
    next_it = 0
    done = 0

    def top_up(minimum: int = 0) -> None:
        nonlocal next_it
        while next_it < iters and (len(futs) < K + 1
                                   or next_it < done + minimum):
            futs.append((next_it, submit(trainer.build_plan, epoch,
                                         next_it, batch_per_model)))
            next_it += 1

    top_up(minimum=1)
    raw_losses: list = []
    remote = hits = 0
    t1 = t2 = up = 0
    num_steps = 0
    dispatch_s = 0.0
    window_t: Optional[float] = None
    window_iters = 0
    steady: Optional[float] = None
    since_sync = 0
    while done < iters:
        k = min(K, iters - done)
        top_up(minimum=k)
        # _plan_result applies the stall deadline (a wedged prefetch
        # thread raises StallError instead of hanging the epoch) and
        # re-raises a supervised build failure with its (epoch, it)
        plans = []
        for _ in range(k):
            it_i, fut = futs.popleft()
            with obs_span("plan.wait", epoch=epoch, it=it_i):
                plans.append(trainer._plan_result(fut, epoch, it_i))
        top_up()
        if window_t is None:
            # the window opens at the first dispatch, after the (serial)
            # first plan build — plan waits *inside* the window are real
            # pipeline stalls and belong in the steady estimate
            window_t = time.perf_counter()
        tc0 = engine.trace_count()
        td0 = time.perf_counter()
        # guarded dispatch: pending background errors surface here (the
        # "next dispatch boundary" contract) and transient comm faults
        # retry during argument staging, pre-donation
        with obs_span("dispatch", epoch=epoch, it=done):
            loss = trainer._dispatch(plans, epoch, done)
        dispatch_s += time.perf_counter() - td0
        raw_losses.append(loss)
        for p in plans:
            remote += p.remote_rows_exact
            hits += p.cache_hit_rows
            ts = getattr(p, "tier_stats", None)
            if ts:
                t1 += ts["tier1_rows"]
                t2 += ts["tier2_rows"]
                up += ts["upload_bytes"]
        num_steps = plans[-1].num_steps
        done += k
        since_sync += k
        if engine.trace_count() > tc0:
            # this dispatch (re)traced: drain the queue and restart the
            # steady window after the sync so compile time never leaks
            # into the merging controller's signal
            obs_event("pipeline.retrace", epoch=epoch, it=done - 1)
            with obs_span("trace.sync", epoch=epoch, it=done - 1):
                jax.block_until_ready(trainer.params)
            window_t = time.perf_counter()
            window_iters = 0
        else:
            window_iters += k
        if loss_sync_iters and since_sync >= loss_sync_iters:
            # device-time reconciliation point: this synced window (and
            # the epoch-boundary one below) is where device execution
            # becomes visible to the host timeline — dispatch spans only
            # measure host-side enqueue in the non-blocking loop
            with obs_span("loss.sync", epoch=epoch, it=done - 1):
                jax.block_until_ready(loss)    # queue-depth throttle
            # deferred-loss NaN/Inf guard: this window's loss is on host
            # now — divergence is detected here, not an epoch later
            trainer._check_finite(loss, epoch, done - 1)
            since_sync = 0
    with obs_span("loss.sync", epoch=epoch, it=iters - 1, boundary=True):
        jax.block_until_ready(trainer.params)
    t_end = time.perf_counter()
    if window_iters:
        steady = (t_end - window_t) / window_iters
    losses = [float(v) for l in raw_losses
              for v in np.atleast_1d(np.asarray(l))]
    return EpochRunResult(losses=losses, wall_s=t_end - t_epoch,
                          steady_iter_s=steady, dispatch_s=dispatch_s,
                          traces=engine.trace_count() - tc_start,
                          remote_rows=remote, cache_hit_rows=hits,
                          num_steps=num_steps, tier1_rows=t1, tier2_rows=t2,
                          upload_bytes=up)
