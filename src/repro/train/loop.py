"""Compile-once Trainer: the iteration hot path as one reusable subsystem.

One ``Trainer`` folds together everything the examples and benchmarks used
to hand-roll per file:

* **Shape budget** — one :class:`~repro.train.budget.ShapeBudget` per run
  quantizes ``batch_pad``/``r_max`` so every IterationPlan shares device
  shapes and the jitted iteration (repro.core.distributed's compiled-fn
  cache) traces once per bucket, not once per step.
* **Plan prefetch** — a background thread double-buffers plan *i+1* while
  the device executes plan *i* (the SPMD analogue of GraphBolt-style
  feature prefetching), and the plan under construction fans its
  per-(shard, step) sampling and per-shard SlotMap translation out over a
  small planning thread pool (``planner_threads``, numpy releases the
  GIL). Contract: one plan in flight at a time, up to ``planner_threads``
  cores inside it, results independent of the pool (deterministic order);
  per-epoch planning time and plan counts land in
  :class:`EpochStats` (``plan_time_s`` / ``plans_built``).
* **Merging** — a §5.3 :class:`MergingController` driven by the *correct*
  timing signal: steady-state device time per epoch, computed by excluding
  iterations on which the engine's trace log recorded an XLA (re)trace.
  Epoch wall time with compilation in it inverts the paper's signal.
* **Remote-feature cache** — an optional repro.cache layer
  (``cache_policy=\"degree\"|\"lfu\"``, ``cache_budget_bytes``): per-shard
  hot remote rows stay device-resident, the planner splits needed ids into
  cache hits and misses, and the deterministic sampler lets next epoch's
  hot set be precomputed and the store refreshed off the critical path
  (``cache_prefetch``). The store is pre-sized to the byte budget's
  power-of-two row bucket, so content refreshes never change device shapes
  — the compile-once contract holds across refreshes.

* **Async device pipeline** (repro.train.pipeline; default ON) — the
  optimizer update is fused into the compiled iteration (one donated
  dispatch per step instead of a grads round-trip plus an eager per-leaf
  update), losses stay on device until the epoch boundary, and the plan
  prefetch thread additionally commits plan i+1's host→device upload into
  ping-pong buffers while plan i executes. Timing semantics change with
  it: per-iteration walls are *dispatch* times; steady-state is measured
  on a synced window (see pipeline.py). ``pipeline=False`` restores the
  per-iteration blocking loop; ``fused=False`` additionally restores the
  pre-pipeline eager optimizer path (the A/B benchmarks compare them).
  Donation contract: the fused step consumes the params/opt_state buffers
  it is given — the Trainer copies caller-supplied initial params once and
  always continues from the returned trees.

* **Eval + checkpoint/resume** — iteration-boundary checkpoints of
  (params, optimizer state, merge pattern) and tree-block evaluation using
  features gathered back out of the sharded table.

Typical use::

    trainer = Trainer(graph=ds.graph, labels=ds.labels, part=part,
                      owner=owner, local_idx=local_idx, table=table,
                      cfg=cfg, optimizer=adamw(3e-3),
                      train_vertices=ds.train_vertices())
    stats = trainer.fit(epochs=3, iters_per_epoch=8, batch_per_model=16)
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FuturesTimeout
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core import distributed as engine
from repro.features import FeatureStore
from repro.core.merging import MergingController
from repro.core.micrograph import hopgnn_assignment
from repro.core.strategies import IterationPlan, Strategy
from repro.graph.sampler import sample_tree_block
from repro.membership import MembershipView, PeerProbe, StaleGeneration, \
    peer_of
from repro.models.gnn.models import GNNConfig, gnn_forward, init_gnn
from repro.obs import metrics as obs_metrics
from repro.obs.trace import span as obs_span
from repro.optim import Optimizer, adamw
from repro.resilience import (BackgroundError, CheckpointRollbackExhausted,
                              CommCounters, CommTimeout, NonFiniteLoss,
                              ResiliencePolicy, StallError, ThreadSupervisor,
                              resilient_call)
from repro.resilience import faults as _rfaults
from repro.resilience.faults import InjectedFault
from repro.train.budget import ShapeBudget


@dataclasses.dataclass
class EpochStats:
    """Per-epoch record returned by :meth:`Trainer.fit`."""

    epoch: int
    loss: float                 # mean iteration loss
    time_s: float               # raw wall time (planning + compile + exec)
    steady_time_s: float        # compile-free device estimate (see fit())
    traces: int                 # jit traces that occurred during this epoch
    num_steps: int              # merge pattern in effect
    remote_rows: int            # Σ plan.remote_rows_exact
    acc: Optional[float] = None
    compile_free: bool = True   # False: every iteration traced, so
    #                             steady_time_s still contains compile time
    plan_time_s: float = 0.0    # host planning time (prefetch thread; this
    #                             overlaps device time, so it only costs
    #                             wall-clock when it exceeds the device time)
    plans_built: int = 0        # plans constructed during this epoch
    # --- remote-feature cache (repro.cache; zeros when cache is off) ---
    cache_hit_rows: int = 0     # Σ plan.cache_hit_rows (deduped hits)
    cache_hit_rate: float = 0.0  # hits / (hits + misses) over the epoch
    cache_bytes_saved: int = 0  # hit rows × row bytes (gross fabric savings)
    cache_refresh_s: float = 0.0  # blocking refresh time at the epoch
    #                               boundary (prefetch overlap already taken)
    # --- async pipeline (repro.train.pipeline; see its timing notes) ---
    pipelined: bool = False     # this epoch ran the non-blocking fused loop
    dispatch_s: float = 0.0     # host time inside dispatch calls (pipelined
    #                             mode only; the device keeps running after
    #                             each dispatch returns)
    # --- tiered feature store (repro.features; zeros when resident) ---
    streamed: bool = False      # out-of-core mode: plans carry features
    tier1_rows: int = 0         # host hot-tier rows served to plan gathers
    tier2_rows: int = 0         # backing/mmap rows served (hot-tier misses)
    tier1_bytes: int = 0
    tier2_bytes: int = 0
    upload_bytes: int = 0       # plan-carried feature bytes shipped to dev
    readahead_s: float = 0.0    # blocking tier-2→tier-1 install time at the
    #                             epoch boundary (forecast overlap excluded)
    # --- resilience (repro.resilience; zeros when the policy is off) ---
    faults_injected: int = 0    # FaultPlan firings during this epoch
    comm_retries: int = 0       # transient exchange failures re-issued
    comm_timeouts: int = 0      # exchanges that exhausted retries/deadline
    bg_errors: int = 0          # background-thread failures recorded
    epoch_attempts: int = 1     # 1 = clean; >1 = replays after recovery
    rollbacks: int = 0          # NaN/Inf rollbacks to the epoch snapshot
    degradations: tuple = ()    # ladder rungs taken while running this epoch
    # --- feature-integrity surface (repro.features crc, via repro.obs) ---
    crc_failures: int = 0       # backing-tier checksum mismatches this epoch
    repaired_rows: int = 0      # rows re-gathered from the source after a
    #                             quarantined chunk failed verification
    # --- membership (repro.membership; static world: gen 0, 0 recoveries) ---
    membership_generation: int = 0   # world generation at epoch end
    membership_recoveries: int = 0   # confirmed peer deaths recovered while
    #                                  running this epoch (rejoin or shrink)


class Trainer:
    """Compile-once training loop over the repro.core planner + engine."""

    def __init__(self, *, graph, labels, part, owner, local_idx, table,
                 cfg: GNNConfig,
                 optimizer: Optional[Optimizer] = None,
                 params=None,
                 strategy: Strategy = "hopgnn",
                 pregather: bool = True,
                 merging: Optional[bool] = None,
                 selector: str = "min",
                 mesh=None,
                 budget: Optional[ShapeBudget] = None,
                 prefetch: bool = True,
                 planner_threads: Optional[int] = None,
                 train_vertices: Optional[np.ndarray] = None,
                 root_fn: Optional[Callable[[int, int], Sequence]] = None,
                 root_seed: int = 0,
                 sample_seed_base: int = 0,
                 init_seed: int = 0,
                 ckpt_dir: Optional[str] = None,
                 ckpt_keep: int = 3,
                 cache_policy: Optional[str] = None,
                 cache_budget_bytes: int = 0,
                 cache_prefetch: bool = True,
                 pipeline: bool = True,
                 pipeline_stack: int = 1,
                 fused: Optional[bool] = None,
                 loss_sync_iters: int = 16,
                 fold_returns: Optional[bool] = None,
                 resilience=None):
        self.graph = graph
        self.labels = np.asarray(labels)
        self.part = np.asarray(part)
        self.owner = np.asarray(owner)
        self.local_idx = np.asarray(local_idx)
        # repro.features: every feature read goes through one tiered store.
        # A plain (N, local_rows, d) array is wrapped resident (bit-identical
        # to the pre-store Trainer); a tiered store switches the engine to
        # streamed mode — plans carry their feature blocks, no device table.
        if isinstance(table, FeatureStore):
            self.store = table.bind(self.owner, self.local_idx)
        else:
            self.store = FeatureStore.from_array(
                np.asarray(table), owner=self.owner,
                local_idx=self.local_idx)
        self.streamed = not self.store.resident
        if self.streamed and not pregather:
            raise ValueError(
                "a tiered FeatureStore requires pregather=True: per-step "
                "exchange gathers from a device-resident table, which "
                "out-of-core mode exists to avoid")
        # device-resident once: re-uploading the feature table every
        # iteration was part of the per-step overhead this subsystem removes
        self.table = (jnp.asarray(self.store.as_dense())
                      if self.store.resident else None)
        self.cfg = cfg
        self.optimizer = optimizer or adamw(1e-3)
        # async pipeline / fused dispatch (repro.train.pipeline)
        self.pipeline = bool(pipeline)
        self.pipeline_stack = max(1, int(pipeline_stack))
        # fused defaults ON regardless of pipeline: pipeline=False alone is
        # the blocking-but-fused loop (bit-identical to pipelined); the
        # pre-pipeline eager-optimizer path needs an explicit fused=False
        self.fused = True if fused is None else bool(fused)
        if self.pipeline and not self.fused:
            raise ValueError("pipeline=True requires the fused train step "
                             "(fused=False only with pipeline=False)")
        self.loss_sync_iters = int(loss_sync_iters)
        self.fold_returns = fold_returns
        if params is None:
            params = init_gnn(jax.random.PRNGKey(init_seed), cfg)
        elif self.fused:
            # the fused step donates params buffers; copy once so the
            # caller's tree stays valid (donation contract, pipeline.py)
            params = jax.tree.map(jnp.array, params)
        self.params = params
        self.opt_state = self.optimizer.init(self.params)
        self._uploader = None          # PlanUploader, created in fit()
        self._empty_cache = None       # shared (N, 0, d) cache-off table
        self.strategy: Strategy = strategy
        self.pregather = pregather
        self.merging = (strategy == "hopgnn") if merging is None else merging
        self.selector = selector
        self.mesh = mesh
        self.budget = budget if budget is not None else ShapeBudget()
        self.train_vertices = (None if train_vertices is None
                               else np.asarray(train_vertices))
        self.root_fn = root_fn
        self.root_seed = root_seed
        self.sample_seed_base = sample_seed_base
        self.ckpt_dir = ckpt_dir
        self.ckpt_keep = ckpt_keep
        self.controller: Optional[MergingController] = None
        self.global_step = 0
        self._resume_pattern: Optional[tuple] = None  # (steps, frozen, time)
        self._prefetch = prefetch
        # Planning pool contract: build_plan fans its per-(shard, step)
        # sampling and per-shard index translation out on this pool (the
        # numpy planner kernels release the GIL); it is distinct from the
        # single prefetch thread, which only double-buffers whole plans —
        # so one in-flight plan uses up to planner_threads cores while the
        # device executes the previous plan. planner_threads <= 1 disables
        # the pool (serial planning inside the prefetch thread).
        if planner_threads is None:
            # affinity-aware: on a 1-core container cgroup, cpu_count()
            # reports host cores and would oversubscribe the planner
            try:
                cores = len(os.sched_getaffinity(0))
            except AttributeError:          # non-Linux
                cores = os.cpu_count() or 1
            planner_threads = min(4, cores)
        self.planner_threads = int(planner_threads)
        self._plan_pool: Optional[ThreadPoolExecutor] = None  # lazy; see
        #   _get_plan_pool / fit()'s finally for the lifecycle
        self._plan_time_lock = threading.Lock()
        self._plan_time_acc = 0.0
        self._plans_built_acc = 0
        # --- remote-feature cache (repro.cache) ---
        self.cache_policy_name = cache_policy
        self.cache_prefetch = bool(cache_prefetch)
        self.cache_rows = 0
        self.cache_store = None
        self._cache_policy = None
        self._cache_prefetcher = None
        self._cache_lock = threading.Lock()
        self._cache_fut = None
        if cache_policy:
            from repro.cache import CacheStore, budget_rows, make_policy
            from repro.train.budget import next_bucket
            d = self.store.feature_dim
            self.cache_rows = budget_rows(cache_budget_bytes, d,
                                          self.store.dtype.itemsize)
            if self.cache_rows > 0:
                # pre-size to the budget's pow2 bucket: a cold (even empty)
                # cache already has its final device shape, so content
                # refreshes never retrace
                self.cache_store = CacheStore(
                    self.num_shards, d, c_max=next_bucket(self.cache_rows),
                    dtype=self.store.dtype)
                self._cache_policy = make_policy(
                    cache_policy, graph=self.graph, owner=self.owner,
                    num_shards=self.num_shards)
                self._cache_prefetcher = self._make_prefetcher()
        # --- tiered-store readahead (repro.features; streamed mode) ---
        # the exact next-epoch forecast that refreshes the device cache also
        # drives tier-2 → tier-1 promotion, so a prefetcher exists whenever
        # the store is tiered, cache layer or not
        self._prefetch_batch = 0           # bound per fit() call
        self._readahead_fut = None
        self._readahead_enabled = self.streamed and self.store.hot_rows > 0
        if self._readahead_enabled and self._cache_prefetcher is None:
            self._cache_prefetcher = self._make_prefetcher()
        # --- resilience (repro.resilience; None/True -> default policy,
        # False -> off). The default policy is always-on and cheap: one
        # params/opt snapshot per epoch, a deque peek per dispatch, an
        # isfinite on each synced loss window.
        self.resilience = ResiliencePolicy.resolve(resilience)
        self._supervisor = (ThreadSupervisor()
                            if self.resilience is not None else None)
        self._comm_counters = CommCounters()
        self._inline_planning = False      # degraded: plans built inline
        self._site_failures: dict = {}     # site -> failures seen this fit
        self._rollbacks_total = 0
        self.degradations_taken: list = []  # cumulative rung log
        # --- membership (repro.membership): per-shard liveness plus the
        # epoch-stamped world generation every plan is stamped with (and
        # refused under when it goes stale — see _dispatch)
        self.membership = (MembershipView(self.num_shards)
                           if self.resilience is not None
                           and self.resilience.membership else None)
        self.membership_recoveries = 0     # confirmed deaths recovered
        self._membership_ckpt_loaded = False  # last recovery resumed from
        #                                       the shared checkpoint

    def _make_prefetcher(self):
        from repro.cache import EpochPrefetcher
        return EpochPrefetcher(
            graph=self.graph, part=self.part, owner=self.owner,
            num_shards=self.num_shards,
            num_layers=self.cfg.num_layers, fanout=self.cfg.fanout,
            roots_for=self._prefetch_roots_for,
            sample_seed_for=lambda e, i:
                self.sample_seed_base + e * 10_000 + i,
            strategy=self.strategy,
            fold_steps=self._prefetch_fold)

    @classmethod
    def from_env(cls, env: dict, cfg: GNNConfig, **kw) -> "Trainer":
        """Build from a benchmarks.common.setup() environment dict."""
        kw.setdefault("train_vertices", env["ds"].train_vertices())
        return cls(graph=env["ds"].graph, labels=env["ds"].labels,
                   part=env["part"], owner=env["owner"],
                   local_idx=env["local_idx"], table=env["table"],
                   cfg=cfg, **kw)

    # ------------------------------------------------------------------
    # Host-side planning (runs on the prefetch thread)
    # ------------------------------------------------------------------

    @property
    def num_shards(self) -> int:
        return self.store.num_shards

    def _roots_for(self, epoch: int, it: int, batch_per_model: int):
        if self.root_fn is not None:
            return [np.asarray(r, np.int64)
                    for r in self.root_fn(epoch, it)]
        if self.train_vertices is None:
            raise ValueError("need train_vertices (or a root_fn)")
        rng = np.random.default_rng((self.root_seed, epoch, it))
        return [rng.choice(self.train_vertices, batch_per_model,
                           replace=False)
                for _ in range(self.num_shards)]

    def _assignment_for(self, roots):
        """Merge-pattern application: fold each fresh rotation assignment to
        the controller's current depth. (The seed loop dropped the merged
        assignment and re-planned the full rotation — merging never actually
        took effect on the device.)"""
        if self.strategy != "hopgnn" or not self.merging:
            return None
        base = hopgnn_assignment(roots, self.part)
        if self.controller is None:
            self.controller = MergingController(base=base,
                                                selector=self.selector)
            if self._resume_pattern is not None:
                steps, frozen, last_time = self._resume_pattern
                if steps:
                    self.controller.restore(steps, frozen,
                                            last_time=last_time)
                self._resume_pattern = None
        return self.controller.apply_to(base)

    def build_plan(self, epoch: int, it: int,
                   batch_per_model: int) -> IterationPlan:
        with obs_span("plan.build", epoch=epoch, it=it):
            return self._build_plan(epoch, it, batch_per_model)

    def _build_plan(self, epoch: int, it: int,
                    batch_per_model: int) -> IterationPlan:
        t0 = time.perf_counter()
        # fault points: fire only under an installed FaultPlan, and
        # thread-death only when this thread is supervised as "prefetch"
        # (the inline-planning fallback must not re-trip the same fault)
        _rfaults.sleep_point("prefetch", epoch, it)
        _rfaults.raise_if_thread("prefetch", epoch, it)
        roots = self._roots_for(epoch, it, batch_per_model)
        assignment = self._assignment_for(roots)
        cache_index = (self.cache_store.index
                       if self.cache_store is not None else None)
        plan = self.budget.plan(
            graph=self.graph, labels=self.labels, part=self.part,
            owner=self.owner, local_idx=self.local_idx,
            local_rows=self.store.local_rows,
            roots_per_model=roots, num_layers=self.cfg.num_layers,
            fanout=self.cfg.fanout, strategy=self.strategy,
            pregather=self.pregather, assignment=assignment,
            cache_index=cache_index,
            feature_store=self.store if self.streamed else None,
            executor=self._get_plan_pool(),
            sample_seed=self.sample_seed_base + epoch * 10_000 + it)
        if self._cache_policy is not None and not self._cache_policy.static \
                and not self.cache_prefetch and plan.remote_ids is not None:
            # trailing-LFU mode: learn frequencies from the requests the
            # plans actually made (prefetch mode predicts them instead)
            with self._cache_lock:
                for s in range(self.num_shards):
                    self._cache_policy.observe(s, plan.remote_ids[s])
        plan.epoch_it = (epoch, it)   # provenance for the comm fault point
        # world provenance: the membership generation this plan was built
        # under; _dispatch refuses the plan once the generation moves on
        plan.generation = (self.membership.generation
                           if self.membership is not None else -1)
        if self._uploader is not None:
            # async pipeline: commit the host→device upload here, on the
            # prefetch thread, so plan i+1's transfer overlaps plan i's
            # device execution and the dispatch path never converts leaves.
            # The commit runs under the "uploader" site so an injected
            # uploader death is distinguishable from a planner death (they
            # degrade differently: uploader-off vs pipeline-to-sync).
            with obs_span("upload.commit", track="uploader",
                          epoch=epoch, it=it):
                if _rfaults.current_site.get() is not None:
                    tok = _rfaults.current_site.set("uploader")
                    try:
                        _rfaults.raise_if_thread("uploader", epoch, it)
                        self._uploader.commit(plan)
                    finally:
                        _rfaults.current_site.reset(tok)
                else:
                    self._uploader.commit(plan)
        with self._plan_time_lock:
            self._plan_time_acc += time.perf_counter() - t0
            self._plans_built_acc += 1
        return plan

    def _get_plan_pool(self) -> Optional[ThreadPoolExecutor]:
        """Planning pool, created on first use and torn down with fit()
        (so many short-lived Trainers don't accumulate idle threads)."""
        if self._plan_pool is None and self.planner_threads > 1:
            self._plan_pool = ThreadPoolExecutor(
                max_workers=self.planner_threads, thread_name_prefix="plan")
        return self._plan_pool

    def _close_plan_pool(self) -> None:
        pool, self._plan_pool = self._plan_pool, None
        if pool is not None:
            pool.shutdown(wait=False, cancel_futures=True)

    def _drain_plan_stats(self) -> tuple[float, int]:
        with self._plan_time_lock:
            out = (self._plan_time_acc, self._plans_built_acc)
            self._plan_time_acc = 0.0
            self._plans_built_acc = 0
        return out

    # ------------------------------------------------------------------
    # Remote-feature cache (repro.cache)
    # ------------------------------------------------------------------

    @property
    def cache_enabled(self) -> bool:
        return self.cache_store is not None

    def _prefetch_roots_for(self, epoch: int, it: int):
        """Deterministic root replay for the epoch prefetcher (same draw as
        build_plan will make — root_fn / (root_seed, epoch, it) seeded)."""
        return self._roots_for(epoch, it, self._prefetch_batch)

    def _prefetch_fold(self, amat):
        """Merge-pattern application for the epoch prefetcher: fold the
        predicted rotation exactly like build_plan will, so an active §5.3
        merge no longer shifts requests away from the predicted hot sets
        (the ROADMAP "cache vs merging prediction gap"). Exact for the
        paper's deterministic "min" selector; the RD baseline's random
        folds consume controller RNG state and cannot be replayed ahead of
        time, so those predictions stay unfolded (correctness unaffected —
        mispredicted rows simply miss)."""
        ctl = self.controller
        if (ctl is None or self.strategy != "hopgnn" or not self.merging
                or self.selector != "min"):
            return amat
        from repro.core.merging import fold_assignment
        return fold_assignment(amat, ctl.pattern_steps, self.selector)

    def _cache_select_install(self, hot=None) -> dict:
        """Run the admission policy (optionally against predicted hot sets)
        and refresh the device cache straight from the FeatureStore's tier
        chain (tier-0 refresh path; repro.features)."""
        with self._cache_lock:
            if hot is not None:
                sel = [self._cache_policy.select(s, self.cache_rows,
                                                 hot_ids=ids, hot_counts=cnt)
                       for s, (ids, cnt) in enumerate(hot)]
            else:
                sel = [self._cache_policy.select(s, self.cache_rows)
                       for s in range(self.num_shards)]
        return self.cache_store.install_from(self.store, sel)

    def _cache_compute(self, epoch: int, iters: int):
        """Cache-thread job: predict epoch's requests (deterministic
        sampler), select the cached set, gather its rows. Returns the
        ready-to-install (ids, rows) pair."""
        with obs_span("cache.forecast", epoch=epoch):
            _rfaults.sleep_point("cache", epoch, -1)
            _rfaults.raise_if_thread("cache", epoch, -1)
            hot = self._cache_prefetcher.epoch_requests(epoch, iters)
            with self._cache_lock:
                sel = [self._cache_policy.select(s, self.cache_rows,
                                                 hot_ids=ids, hot_counts=cnt)
                       for s, (ids, cnt) in enumerate(hot)]
            rows = [self._features_of(ids) for ids in sel]
            return sel, rows

    def _cache_epoch_begin(self, epoch: int, first_epoch: int, epochs: int,
                           iters: int, batch_per_model: int,
                           cache_exec) -> float:
        """Refresh the store at the epoch boundary (plans for this epoch
        are built only after this returns) and schedule the next epoch's
        prefetch. Returns the *blocking* refresh seconds — prefetch work
        that overlapped the previous epoch's device time costs nothing
        here."""
        if not self.cache_enabled:
            return 0.0
        with obs_span("cache.refresh", epoch=epoch):
            t0 = time.perf_counter()
            self._prefetch_batch = batch_per_model
            if self._cache_fut is not None:
                ids, rows = self._cache_fut.result()
                self._cache_fut = None
                self.cache_store.install(ids, rows)
            elif epoch == first_epoch and self._cache_policy.static:
                # degree policy: one static selection, installed before the
                # first plan and never refreshed
                self._cache_select_install()
            elif not self._cache_policy.static and cache_exec is None \
                    and epoch > first_epoch:
                # trailing LFU (prefetch off): select from frequencies
                # observed in earlier epochs' plans
                self._cache_select_install()
            if cache_exec is not None and not self._cache_policy.static \
                    and epoch + 1 < epochs:
                self._cache_fut = self._submit_site(
                    cache_exec, "cache", self._cache_compute,
                    epoch + 1, iters)
            # force the host→device upload NOW so it lands in
            # cache_refresh_s, not inside the first (steady-timed)
            # train_step of the epoch
            self.cache_store.device_table
            return time.perf_counter() - t0

    # ------------------------------------------------------------------
    # Tiered-store readahead (repro.features, tier 2 -> tier 1)
    # ------------------------------------------------------------------

    def _readahead_compute(self, epoch: int, iters: int):
        """Cache-thread job: the per-OWNING-shard (ids, counts) forecast of
        every row each shard will *serve* next epoch — exact under the
        deterministic sampler, same replay the cache refresh uses."""
        with obs_span("features.readahead.forecast", epoch=epoch):
            _rfaults.sleep_point("readahead", epoch, -1)
            _rfaults.raise_if_thread("readahead", epoch, -1)
            return self._cache_prefetcher.epoch_touched(epoch, iters)

    def _readahead_install(self, touched) -> int:
        """Swap the forecast rows into each shard's host hot tier. Sorted by
        backing row so the store's unique() keeps counts aligned."""
        installed = 0
        for p, (ids, cnt) in enumerate(touched):
            rows = self.local_idx[ids]
            order = np.argsort(rows, kind="stable")
            installed += self.store.readahead(p, rows[order],
                                              counts=cnt[order])
        return installed

    def _readahead_epoch_begin(self, epoch: int, first_epoch: int,
                               epochs: int, iters: int,
                               batch_per_model: int, cache_exec) -> float:
        """Promote next epoch's rows at the epoch boundary — no plan is in
        flight then, so the wholesale hot-tier swap never races a gather
        (the store's thread contract). The forecast for epoch e+1 runs on
        the cache thread *during* epoch e; only the first epoch (and the
        install itself) block. Runs BEFORE the cache refresh so tier-0
        refresh gathers hit the freshly-warmed hot tier."""
        if not self._readahead_enabled:
            return 0.0
        with obs_span("features.readahead", epoch=epoch):
            t0 = time.perf_counter()
            self._prefetch_batch = batch_per_model
            if self._readahead_fut is not None:
                touched = self._readahead_fut.result()
                self._readahead_fut = None
                self._readahead_install(touched)
            else:
                self._readahead_install(
                    self._readahead_compute(epoch, iters))
            if cache_exec is not None and epoch + 1 < epochs:
                self._readahead_fut = self._submit_site(
                    cache_exec, "readahead", self._readahead_compute,
                    epoch + 1, iters)
            return time.perf_counter() - t0

    def _submit_site(self, exec_, site: str, fn, *args):
        """Submit a background job under supervision (site + (epoch, it)
        context recorded at raise time; see repro.resilience)."""
        if self._supervisor is None:
            return exec_.submit(fn, *args)
        return self._supervisor.submit(exec_.submit, site, fn, *args,
                                       epoch=args[0] if args else -1, it=-1)

    # ------------------------------------------------------------------
    # Device stepping
    # ------------------------------------------------------------------

    def _cache_table_for(self, plan: IterationPlan):
        """Device cache table for this plan, with the staleness check.
        Cache-off plans share one zero-width table (no per-iteration
        allocation)."""
        if plan.c_max:
            store = self.cache_store
            if store is None or plan.cache_version != store.version:
                raise RuntimeError(
                    f"stale cache plan: plan version {plan.cache_version} "
                    f"vs store "
                    f"{store.version if store is not None else 'absent'}")
            return store.device_table
        return self._empty_table()

    def _empty_table(self):
        """Shared (N, 0, d) zero-width device table — stands in for both a
        disabled cache and (streamed mode) the absent feature table."""
        if self._empty_cache is None:
            self._empty_cache = engine.empty_cache_table(
                self.num_shards, self.store.feature_dim, self.store.dtype)
        return self._empty_cache

    def train_step(self, plan: IterationPlan):
        """Pre-pipeline step: grads round-trip + eager optimizer update.
        Kept as the ``fused=False`` path (and the benchmarks' A/B
        baseline); the pipelined loop dispatches :meth:`_dispatch_fused`
        instead."""
        cache_tab = self._cache_table_for(plan)
        grads, loss = engine.run_iteration(self.params, self.table, plan,
                                           self.cfg, mesh=self.mesh,
                                           cache=cache_tab,
                                           fold_returns=self.fold_returns)
        self.params, self.opt_state = self.optimizer.update(
            grads, self.opt_state, self.params)
        self.global_step += 1
        return loss

    def _dispatch_fused(self, plan: IterationPlan):
        """One fused, donated, non-blocking dispatch: iteration + optimizer
        update in a single compiled program. Returns the *device* loss —
        no host sync happens here."""
        cache_tab = self._cache_table_for(plan)
        fn = engine.get_compiled_train_step(
            self.cfg, plan.pregather, self.optimizer, mesh=self.mesh,
            fold_returns=engine.resolve_fold_returns(plan,
                                                     self.fold_returns),
            streamed=bool(getattr(plan, "streamed", False)))
        table, cache_tab, dev, denom = engine.prepare_iteration_args(
            self.table, plan, cache_tab)
        self.params, self.opt_state, loss = fn(
            self.params, self.opt_state, table, cache_tab, dev, denom)
        self.global_step += 1
        return self._maybe_poison([plan], loss)

    def _dispatch_stacked(self, plans: Sequence[IterationPlan]):
        """One scanned dispatch covering ``len(plans)`` same-bucket
        iterations (pipeline_stack > 1). Returns the (K,) device losses."""
        from repro.train.pipeline import stack_committed
        p0 = plans[0]
        for p in plans[1:]:
            if (p.pregather != p0.pregather
                    or p.cache_version != p0.cache_version
                    or p.num_steps != p0.num_steps):
                raise ValueError("stacked plans must share mode, cache "
                                 "version, and merge pattern")
            if (p.batch_pad, p.r_max, p.c_max, p.l_max) != \
                    (p0.batch_pad, p0.r_max, p0.c_max, p0.l_max):
                # a mid-epoch budget re-bucket split the group's shapes
                # (rare: only when sampling variance beats the r_max
                # headroom); fall back to per-plan dispatch — one extra
                # retrace, exactly like the unstacked loop, instead of a
                # jnp.stack shape crash
                return [self._dispatch_fused(q) for q in plans]
        # the host comm boundary: stacked dispatch stages its own args, so
        # it owns its fault point (fused goes through prepare_iteration_args)
        engine.comm_fault_point(p0)
        cache_tab = self._cache_table_for(p0)
        fn = engine.get_compiled_train_step(
            self.cfg, p0.pregather, self.optimizer, mesh=self.mesh,
            fold_returns=engine.resolve_fold_returns(p0, self.fold_returns),
            stacked=True, streamed=bool(getattr(p0, "streamed", False)))
        dev_stack, denoms = stack_committed(plans)
        table = (engine._as_device(self.table) if self.table is not None
                 else self._empty_table())
        self.params, self.opt_state, losses = fn(
            self.params, self.opt_state, table,
            cache_tab, dev_stack, denoms)
        self.global_step += len(plans)
        return self._maybe_poison(plans, losses)

    # ------------------------------------------------------------------
    # Resilience plumbing (repro.resilience)
    # ------------------------------------------------------------------

    def _maybe_poison(self, plans, loss):
        """nan_loss fault point: poison this step's loss AND parameters
        (models numerical divergence — recovery requires the rollback, not
        just dropping one loss sample). No-op without an active plan."""
        if _rfaults.active_plan() is None:
            return loss
        for p in plans:
            ei = getattr(p, "epoch_it", None)
            if ei is None or not _rfaults.take("nan_loss", ei[0], ei[1]):
                continue
            nan = jnp.nan
            self.params = jax.tree.map(
                lambda x: x * nan
                if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
                self.params)
            loss = jax.tree.map(lambda v: v * nan, loss)
        return loss

    def _dispatch(self, plans: Sequence[IterationPlan], epoch: int,
                  it: int):
        """Guarded dispatch used by both epoch loops: surface any pending
        background error first (the "next dispatch boundary" contract),
        then run the dispatch under the comm retry guard. Transient comm
        faults fire during argument staging, BEFORE the compiled program
        is invoked, so a retry never re-donates dead buffers."""
        if self.membership is not None:
            # world-stale refusal: a plan (and its committed upload /
            # prefetched rows) built under an older membership generation
            # must never reach the device — the replay rebuilds it
            for p in plans:
                self.membership.check_generation(
                    getattr(p, "generation", -1), epoch=epoch, it=it)
        if self._supervisor is not None:
            self._supervisor.check()
        if len(plans) == 1:
            plan = plans[0]
            fn = ((lambda: self._dispatch_fused(plan)) if self.fused
                  else (lambda: self.train_step(plan)))
        else:
            fn = lambda: self._dispatch_stacked(plans)
        if self.resilience is None:
            return fn()
        return resilient_call(fn, policy=self.resilience.retry,
                              counters=self._comm_counters,
                              epoch=epoch, it=it)

    def _plan_result(self, fut, epoch: int, it: int):
        """Wait for a plan future under the stall deadline — a wedged
        prefetch thread becomes a StallError instead of hanging fit()."""
        policy = self.resilience
        if policy is None or policy.stall_deadline_s is None:
            return fut.result()
        try:
            return fut.result(timeout=policy.stall_deadline_s)
        except (TimeoutError, FuturesTimeout):
            raise StallError("prefetch", epoch, it,
                             policy.stall_deadline_s) from None

    def _check_finite(self, loss, epoch: int, it: int) -> None:
        """NaN/Inf guard on a synced loss window (deferred-loss contract:
        this is only called on values already off the device)."""
        policy = self.resilience
        if policy is None or not policy.guard_nonfinite:
            return
        v = np.asarray(loss)
        if not np.all(np.isfinite(v)):
            bad = v.ravel()[~np.isfinite(v.ravel())]
            raise NonFiniteLoss(epoch, it, float(bad[0]))

    def _snapshot_state(self) -> dict:
        """Epoch-start in-memory snapshot for rollback+replay. Deep device
        copies: the fused step donates params/opt buffers, so aliasing the
        live trees would hand the snapshot to the donor."""
        return {"params": jax.tree.map(jnp.array, self.params),
                "opt": jax.tree.map(jnp.array, self.opt_state),
                "step": self.global_step}

    def _restore_state(self, snap: dict) -> None:
        # copy again on restore — the next dispatch donates what we hand
        # it, and the snapshot must survive a second rollback
        self.params = jax.tree.map(jnp.array, snap["params"])
        self.opt_state = jax.tree.map(jnp.array, snap["opt"])
        self.global_step = snap["step"]

    def _degrade(self, site: Optional[str]) -> Optional[str]:
        """Take one degradation-ladder rung for a failing site. Every rung
        lands on a mode that is bit-identical to the one it leaves (the
        PR-5 pipeline≡sync, PR-3 cache parity, and PR-6 tier-parity gates)
        — recovery costs throughput, never numerics."""
        if site == "uploader" and self._uploader is not None:
            # plans stop committing; dispatch converts leaves inline
            self._uploader = None
            return "uploader_off"
        if site in ("prefetch", "uploader", "comm"):
            if self.pipeline or not self._inline_planning:
                self.pipeline = False
                self._inline_planning = True
                self._uploader = None
                return "pipeline_to_sync"
            return None
        if site == "cache":
            if self.cache_store is not None:
                self.cache_store = None
                self._cache_policy = None
                self._cache_fut = None
                return "cache_off"
            return None
        if site in ("readahead", "store"):
            if self._readahead_enabled or not self.store.hot_bypass:
                self._readahead_enabled = False
                self._readahead_fut = None
                self.store.bypass_hot(True)
                return "resident_gather"
            return None
        return None

    def _recover(self, e: BaseException, epoch: int) -> Optional[str]:
        """Decide the recovery action for a failed epoch attempt. First
        failure of a site replays in-mode (transients and once-faults clear
        on replay — no permanent throughput loss); a repeat failure takes
        the site's ladder rung. NaN/Inf always means rollback+replay,
        bounded by ``max_rollbacks``."""
        policy = self.resilience
        site = getattr(e, "site", None)
        if isinstance(e, BackgroundError):
            self._supervisor.mark_delivered(e)
            site = getattr(e.__cause__, "site", site)
        self._supervisor.drain()
        # abandon in-flight epoch-boundary futures: replay recomputes (or
        # skips) them deterministically at its own boundary
        self._cache_fut = None
        self._readahead_fut = None
        # membership: a peer-attributed failure goes through detection
        # first — a confirmed death is a world change, not a site failure
        if self.membership is not None and policy.membership:
            peer = peer_of(e)
            if peer >= 0 and self.membership.is_alive(peer):
                rung = self._membership_recover(peer, epoch)
                if rung is not None:
                    self.degradations_taken.append(rung)
                    return rung
                # the probe found the peer alive (a flap): suspicion is
                # cleared with zero membership trace, and the failure falls
                # through to the ordinary comm site accounting below
        if isinstance(e, NonFiniteLoss):
            self._rollbacks_total += 1
            if self._rollbacks_total > policy.max_rollbacks:
                raise CheckpointRollbackExhausted(
                    f"non-finite loss persisted across "
                    f"{policy.max_rollbacks} rollback+replay attempts at "
                    f"epoch {epoch} — genuine divergence") from e
            return "rollback_replay"
        n = self._site_failures.get(site, 0) + 1
        self._site_failures[site] = n
        if n >= 2 and policy.degrade:
            rung = self._degrade(site)
            if rung is not None:
                self.degradations_taken.append(rung)
            return rung
        return None

    # ------------------------------------------------------------------
    # Elastic membership (repro.membership)
    # ------------------------------------------------------------------

    def _membership_recover(self, peer: int, epoch: int) -> Optional[str]:
        """Two-phase recovery for a peer-attributed failure: suspect →
        bounded liveness probe. A peer that answers any probe was a flap —
        the suspicion is cleared and ``None`` returned (the caller replays
        in-mode, zero numerical trace). A confirmed death rebuilds the
        world per ``policy.membership_mode`` and resumes from the shared
        crash-atomic checkpoint; returns the ``membership_<mode>`` rung."""
        policy = self.resilience
        view = self.membership
        view.mark_suspect(peer, epoch=epoch)
        with obs_span("membership.detect", peer=peer, epoch=epoch):
            pr = PeerProbe(attempts=policy.probe_attempts,
                           backoff_s=policy.probe_backoff_s).confirm(peer)
        if pr.alive:
            view.clear_suspect(peer)
            return None
        view.confirm_dead(peer, epoch=epoch)
        mode = policy.membership_mode
        with obs_span("membership.rebuild", peer=peer, mode=mode,
                      epoch=epoch):
            if mode == "rejoin":
                # a replacement worker takes the dead rank: the partition
                # maps are unchanged and the rank's feature rows come back
                # from the authoritative source (the emulated backing
                # already holds them — same repair-from-source path the
                # crc layer uses), so the world is the old world under a
                # fresh generation
                engine.revive_peer(peer)
                view.rejoin(peer, epoch=epoch)
            else:
                self._membership_shrink(peer, epoch, mode)
        with obs_span("membership.resume", peer=peer, mode=mode,
                      epoch=epoch):
            self._membership_ckpt_loaded = self._resume_shared_checkpoint()
        self.membership_recoveries += 1
        obs_metrics.inc("membership.recoveries")
        return f"membership_{mode}"

    def _membership_shrink(self, dead: int, epoch: int, mode: str) -> None:
        """Elastic re-ownership at world size P-1: survivors re-own the
        dead shard's vertices (graph.partition.reassign_partition) and
        every world-shaped structure is rebuilt against the new maps.
        Numerics legitimately change (different shard batches, different
        reduction groups), so correctness is gated on loss tolerance vs a
        fresh same-world-size baseline, not bit parity."""
        if self.mesh is not None:
            raise NotImplementedError(
                "elastic shrink under a real device mesh needs a mesh "
                "rebuild; use membership_mode='rejoin' on multi-device runs")
        from repro.membership import rebuild_world
        wr = rebuild_world(self.part, dead, self.num_shards, mode=mode)
        # the dead rank leaves the world entirely; the registry entry must
        # not leak into the compacted id space
        engine.revive_peer(dead)
        self.part, self.owner = wr.part, wr.owner
        self.local_idx = wr.local_idx
        self.store = self.store.reshard(wr.part, wr.num_shards)
        self.streamed = not self.store.resident
        self.table = (jnp.asarray(self.store.as_dense())
                      if self.store.resident else None)
        self._empty_cache = None       # the (N, 0, d) table is world-shaped
        # merge controller: the base rotation assignment is world-shaped;
        # the §5.3 examination restarts against the new world
        self.controller = None
        self._resume_pattern = None
        # cache layer: rebuilt cold against the new owner map (same row
        # budget per shard)
        if self.cache_store is not None:
            from repro.cache import CacheStore, make_policy
            from repro.train.budget import next_bucket
            self.cache_store = CacheStore(
                self.num_shards, self.store.feature_dim,
                c_max=next_bucket(self.cache_rows), dtype=self.store.dtype)
            self._cache_policy = make_policy(
                self.cache_policy_name, graph=self.graph, owner=self.owner,
                num_shards=self.num_shards)
        self._cache_fut = None
        self._readahead_fut = None
        self._readahead_enabled = self.streamed and self.store.hot_rows > 0
        self._cache_prefetcher = (
            self._make_prefetcher()
            if self.cache_store is not None or self._readahead_enabled
            else None)
        if self._uploader is not None:
            # ping-pong upload buffers are plan-shaped; rebuild fresh
            from repro.train.pipeline import PlanUploader
            self._uploader = PlanUploader(budget=self.budget,
                                          view=self.membership)
        self.membership.shrink(dead, epoch=epoch)

    def _resume_shared_checkpoint(self) -> bool:
        """Reload params/opt from the shared crash-atomic checkpoint — the
        survivors' common restore point. False when no checkpoint exists
        yet; the epoch-start snapshot then serves instead (bit-identical to
        the last checkpoint whenever one exists, because checkpoints are
        written at the same epoch boundaries the snapshot is taken at)."""
        if not self.ckpt_dir or latest_step(self.ckpt_dir) is None:
            return False
        tree, step, _extra = load_checkpoint(
            self.ckpt_dir, {"params": self.params, "opt": self.opt_state})
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.global_step = step
        return True

    def _attempt_epoch(self, epoch: int, start_epoch: int, epochs: int,
                      iters: int, batch_per_model: int, cache_exec, submit):
        """One try at one epoch: inject any scheduled epoch-boundary disk
        faults (BEFORE readahead, so the crc verification sees them), run
        the boundary work and the iteration loop, then guard the synced
        losses."""
        for sp in _rfaults.take("disk_corrupt", epoch):
            _rfaults.inject_disk_corruption(self.store, sp)
        readahead_s = self._readahead_epoch_begin(
            epoch, start_epoch, epochs, iters, batch_per_model, cache_exec)
        refresh_s = self._cache_epoch_begin(
            epoch, start_epoch, epochs, iters, batch_per_model, cache_exec)
        if self.pipeline:
            from repro.train.pipeline import run_pipelined_epoch
            res = run_pipelined_epoch(
                self, epoch, iters, batch_per_model, submit,
                stack=self.pipeline_stack,
                loss_sync_iters=self.loss_sync_iters)
        else:
            res = self._epoch_sync(epoch, iters, batch_per_model, submit)
        self._check_finite(res.losses, epoch, iters - 1)
        return res, readahead_s, refresh_s

    _RECOVERABLE = (BackgroundError, StallError, CommTimeout, NonFiniteLoss,
                    InjectedFault, StaleGeneration)

    def _epoch_with_recovery(self, epoch: int, start_epoch: int,
                             epochs: int, iters: int, batch_per_model: int,
                             cache_exec, submit):
        """The epoch attempt loop: snapshot → attempt → on a recoverable
        failure restore + recover (replay or degrade) → re-attempt, up to
        ``max_epoch_attempts``. Determinism makes every replay exact: the
        same (epoch, it, seed) plans rebuild, so an absorbed fault leaves
        losses and parameters bit-identical to a fault-free run."""
        if self.resilience is None:
            res, ra, rf = self._attempt_epoch(
                epoch, start_epoch, epochs, iters, batch_per_model,
                cache_exec, submit)
            return res, ra, rf, {}
        self._comm_counters.reset()
        bg0 = self._supervisor.errors_recorded
        fp = _rfaults.active_plan()
        f0 = fp.fired_count() if fp is not None else 0
        rb0 = self._rollbacks_total
        mr0 = self.membership_recoveries
        snap = self._snapshot_state()
        attempts = 0
        rungs: list = []
        while True:
            attempts += 1
            try:
                res, ra, rf = self._attempt_epoch(
                    epoch, start_epoch, epochs, iters, batch_per_model,
                    cache_exec, submit)
                break
            except self._RECOVERABLE as e:
                if attempts >= self.resilience.max_epoch_attempts:
                    raise
                rung = self._recover(e, epoch)
                if rung is not None:
                    rungs.append(rung)
                if self._membership_ckpt_loaded:
                    # membership resumed from the shared checkpoint (== the
                    # epoch-start state at every epoch boundary); the old
                    # snapshot may alias a pre-shrink world — re-take it
                    self._membership_ckpt_loaded = False
                    snap = self._snapshot_state()
                else:
                    self._restore_state(snap)
        fp = _rfaults.active_plan()
        meta = {"epoch_attempts": attempts,
                "rollbacks": self._rollbacks_total - rb0,
                "degradations": tuple(rungs),
                "faults_injected":
                    (fp.fired_count() if fp is not None else 0) - f0,
                "comm_retries": self._comm_counters.retries,
                "comm_timeouts": self._comm_counters.timeouts,
                "bg_errors": self._supervisor.errors_recorded - bg0,
                "membership_recoveries":
                    self.membership_recoveries - mr0}
        return res, ra, rf, meta

    # ------------------------------------------------------------------
    # Epoch loop
    # ------------------------------------------------------------------

    def _epoch_sync(self, epoch: int, iters: int, batch_per_model: int,
                    submit):
        """Per-iteration blocking loop (``pipeline=False``): double-buffered
        plans, one ``float(loss)`` device sync per step. With ``fused=True``
        it dispatches the fused program (bit-identical to the pipelined
        loop, just synchronous); with ``fused=False`` it is the
        pre-pipeline grads + eager-update path, kept as the benchmarks'
        A/B baseline."""
        from repro.train.pipeline import EpochRunResult
        t_epoch = time.perf_counter()
        fut = submit(self.build_plan, epoch, 0, batch_per_model)
        iter_times: list[float] = []
        traced: list[bool] = []
        losses: list[float] = []
        remote, num_steps, cache_hits = 0, 0, 0
        t1 = t2 = up = 0
        for it in range(iters):
            with obs_span("plan.wait", epoch=epoch, it=it):
                plan = self._plan_result(fut, epoch, it)
            if it + 1 < iters:
                # double-buffer: plan i+1 builds while i executes
                fut = submit(self.build_plan, epoch, it + 1,
                             batch_per_model)
            tc0 = engine.trace_count()
            t0 = time.perf_counter()
            with obs_span("dispatch", epoch=epoch, it=it):
                loss = self._dispatch([plan], epoch, it)
            self._check_finite(loss, epoch, it)
            with obs_span("loss.sync", epoch=epoch, it=it):
                losses.append(float(loss))   # blocks until device done
            iter_times.append(time.perf_counter() - t0)
            traced.append(engine.trace_count() > tc0)
            remote += plan.remote_rows_exact
            cache_hits += plan.cache_hit_rows
            ts = getattr(plan, "tier_stats", None)
            if ts:
                t1 += ts["tier1_rows"]
                t2 += ts["tier2_rows"]
                up += ts["upload_bytes"]
            num_steps = plan.num_steps
        steady = [t for t, tr in zip(iter_times, traced) if not tr]
        return EpochRunResult(
            losses=losses, wall_s=time.perf_counter() - t_epoch,
            steady_iter_s=float(np.mean(steady)) if steady else None,
            dispatch_s=0.0, traces=int(sum(traced)), remote_rows=remote,
            cache_hit_rows=cache_hits, num_steps=num_steps,
            tier1_rows=t1, tier2_rows=t2, upload_bytes=up)

    def fit(self, epochs: int, iters_per_epoch: int,
            batch_per_model: int = 16, eval_every: int = 0,
            n_eval: int = 256, resume: bool = False,
            log: Optional[Callable[[str], None]] = None
            ) -> list[EpochStats]:
        """Run the epoch loop; returns one :class:`EpochStats` per epoch.

        ``steady_time_s`` is the compile-free steady-state estimate that
        feeds the merging controller, so the §5.3 examination measures
        kernel-switch/sync overhead instead of XLA compilation. In the
        synchronous loop it extrapolates from the iterations on which *no*
        jit trace occurred (per-iteration walls, trace-log delta); in the
        pipelined loop per-iteration walls are mere dispatch times, so it
        comes from the synced window instead — the stretch of iterations
        after the last (re)trace, closed by a ``block_until_ready`` (see
        repro.train.pipeline). If no compile-free sample exists (e.g.
        iters_per_epoch=1 right after a pattern change) the epoch is
        marked ``compile_free=False`` and is NOT recorded with the
        controller — feeding it compile-laden time would re-introduce the
        inverted signal this module exists to fix.
        """
        start_epoch = self._maybe_resume() if resume else 0
        stats: list[EpochStats] = []
        pool = (ThreadPoolExecutor(max_workers=1,
                                   thread_name_prefix="prefetch")
                if self._prefetch else None)
        if self._supervisor is None or pool is None:
            submit = pool.submit if pool is not None else self._run_inline
        else:
            def submit(fn, *args):
                # degraded rung: plans build inline on the loop thread
                # (synchronous, unsupervised — failures raise in place)
                if self._inline_planning:
                    return self._run_inline(fn, *args)
                return self._supervisor.submit(
                    pool.submit, "prefetch", fn, *args,
                    epoch=args[0] if args else -1,
                    it=args[1] if len(args) > 1 else -1)
        if self.pipeline and self._uploader is None:
            from repro.train.pipeline import PlanUploader
            self._uploader = PlanUploader(budget=self.budget,
                                          view=self.membership)
        # the cache refresh computation gets its own thread: it must not
        # block the plan double-buffer (and vice versa). The tiered store's
        # readahead forecast shares it (both are epoch-boundary jobs on the
        # same deterministic replay; the single worker serializes them).
        need_cache_thread = (self.cache_enabled and self.cache_prefetch
                             and not self._cache_policy.static)
        cache_exec = (ThreadPoolExecutor(max_workers=1,
                                         thread_name_prefix="cache")
                      if need_cache_thread or self._readahead_enabled
                      else None)
        try:
            for epoch in range(start_epoch, epochs):
                crc0 = (self.store.stats.crc_failures,
                        self.store.stats.repaired_rows)
                res, readahead_s, refresh_s, rmeta = \
                    self._epoch_with_recovery(
                        epoch, start_epoch, epochs, iters_per_epoch,
                        batch_per_model, cache_exec, submit)
                compile_free = res.steady_iter_s is not None
                steady_iter = (res.steady_iter_s if compile_free
                               else res.wall_s / iters_per_epoch)
                steady_epoch = steady_iter * iters_per_epoch
                if self.controller is not None and compile_free:
                    self.controller.record_epoch_time(steady_epoch)
                acc = (self.evaluate(n_eval=n_eval)
                       if eval_every and (epoch + 1) % eval_every == 0
                       else None)
                plan_time, plans_built = self._drain_plan_stats()
                row_bytes = self.store.row_bytes
                st = EpochStats(epoch=epoch,
                                loss=sum(res.losses) / iters_per_epoch,
                                time_s=res.wall_s,
                                steady_time_s=steady_epoch,
                                traces=res.traces,
                                num_steps=res.num_steps,
                                remote_rows=res.remote_rows,
                                acc=acc, compile_free=compile_free,
                                plan_time_s=plan_time,
                                plans_built=plans_built,
                                cache_hit_rows=res.cache_hit_rows,
                                cache_hit_rate=res.cache_hit_rows
                                / max(res.cache_hit_rows
                                      + res.remote_rows, 1),
                                cache_bytes_saved=res.cache_hit_rows
                                * row_bytes,
                                cache_refresh_s=refresh_s,
                                pipelined=self.pipeline,
                                dispatch_s=res.dispatch_s,
                                streamed=self.streamed,
                                tier1_rows=res.tier1_rows,
                                tier2_rows=res.tier2_rows,
                                tier1_bytes=res.tier1_rows * row_bytes,
                                tier2_bytes=res.tier2_rows * row_bytes,
                                upload_bytes=res.upload_bytes,
                                readahead_s=readahead_s,
                                faults_injected=rmeta.get(
                                    "faults_injected", 0),
                                comm_retries=rmeta.get("comm_retries", 0),
                                comm_timeouts=rmeta.get("comm_timeouts", 0),
                                bg_errors=rmeta.get("bg_errors", 0),
                                epoch_attempts=rmeta.get(
                                    "epoch_attempts", 1),
                                rollbacks=rmeta.get("rollbacks", 0),
                                degradations=rmeta.get("degradations", ()),
                                crc_failures=self.store.stats.crc_failures
                                - crc0[0],
                                repaired_rows=self.store.stats.repaired_rows
                                - crc0[1],
                                membership_generation=(
                                    self.membership.generation
                                    if self.membership is not None else 0),
                                membership_recoveries=rmeta.get(
                                    "membership_recoveries", 0))
                stats.append(st)
                obs_metrics.publish_epoch_stats(st)
                if log is not None:
                    log(f"epoch {epoch}: loss {st.loss:.4f} "
                        f"steps {st.num_steps} remote_rows {st.remote_rows} "
                        f"traces {st.traces} wall {st.time_s:.2f}s "
                        f"steady {st.steady_time_s:.2f}s "
                        f"plan {st.plan_time_s:.2f}s"
                        + (f" cache-hit {100 * st.cache_hit_rate:.1f}%"
                           f" refresh {st.cache_refresh_s:.2f}s"
                           if self.cache_enabled else "")
                        + (f" t1-rows {st.tier1_rows} t2-rows "
                           f"{st.tier2_rows} readahead "
                           f"{st.readahead_s:.2f}s"
                           if self.streamed else "")
                        + ("" if st.compile_free else " (all-compile)")
                        + (f" attempts {st.epoch_attempts}"
                           + (f" degraded [{','.join(st.degradations)}]"
                              if st.degradations else "")
                           if st.epoch_attempts > 1 or st.degradations
                           else "")
                        + (f" acc {100 * acc:.1f}%" if acc is not None
                           else ""))
                self._maybe_checkpoint(epoch, st)
        finally:
            if pool is not None:
                pool.shutdown(wait=False, cancel_futures=True)
            if cache_exec is not None:
                cache_exec.shutdown(wait=False, cancel_futures=True)
                self._cache_fut = None
                self._readahead_fut = None
            self._close_plan_pool()
        return stats

    @staticmethod
    def _run_inline(fn, *a):
        class _Done:
            def __init__(self, v):
                self._v = v

            def result(self, timeout=None):
                return self._v
        return _Done(fn(*a))

    # ------------------------------------------------------------------
    # Eval (features gathered back out of the sharded table)
    # ------------------------------------------------------------------

    def _features_of(self, ids: np.ndarray) -> np.ndarray:
        return self.store.take_global(ids)

    def evaluate(self, n_eval: int = 256, seed: int = 123,
                 nodes: Optional[np.ndarray] = None) -> float:
        rng = np.random.default_rng(seed)
        num_vertices = self.part.shape[0]
        if nodes is None:
            nodes = rng.choice(num_vertices, min(n_eval, num_vertices),
                               replace=False)
        blk = sample_tree_block(self.graph, nodes, self.cfg.num_layers,
                                self.cfg.fanout, seed=999)
        feats = [jnp.asarray(self._features_of(ids)) for ids in blk.hops]
        logits = gnn_forward(self.params, self.cfg, feats)
        return float((jnp.argmax(logits, -1) ==
                      jnp.asarray(self.labels[nodes])).mean())

    # ------------------------------------------------------------------
    # Checkpoint / resume
    # ------------------------------------------------------------------

    def _maybe_checkpoint(self, epoch: int, st: EpochStats) -> None:
        if not self.ckpt_dir:
            return
        extra = {"epoch": epoch, "loss": st.loss,
                 "merge_steps": (self.controller.pattern_steps
                                 if self.controller else 0),
                 "merge_frozen": (bool(self.controller.frozen)
                                  if self.controller else False),
                 "merge_last_time": (self.controller.last_epoch_time
                                     if self.controller else None),
                 # bucket state rides along so a resumed run plans straight
                 # into the original run's shapes — no probe, no first-epoch
                 # retrace (repro.train.budget persistence)
                 "budget_state": self.budget.state_dict()}
        save_checkpoint(self.ckpt_dir, self.global_step,
                        {"params": self.params, "opt": self.opt_state},
                        extra=extra, keep=self.ckpt_keep)

    def _maybe_resume(self) -> int:
        if not self.ckpt_dir or latest_step(self.ckpt_dir) is None:
            return 0
        try:
            tree, step, extra = load_checkpoint(
                self.ckpt_dir, {"params": self.params, "opt": self.opt_state})
            self.params = tree["params"]
            self.opt_state = tree["opt"]
        except ValueError:
            # pre-Trainer checkpoints stored bare params (no optimizer
            # state); restore what exists and re-init the optimizer.
            params, step, extra = load_checkpoint(self.ckpt_dir, self.params)
            self.params = params
            self.opt_state = self.optimizer.init(self.params)
        self.global_step = step
        bs = extra.get("budget_state")
        if bs:
            self.budget.load_state(bs)
        lt = extra.get("merge_last_time")
        self._resume_pattern = (int(extra.get("merge_steps", 0)),
                                bool(extra.get("merge_frozen", False)),
                                None if lt is None else float(lt))
        return int(extra.get("epoch", -1)) + 1


def merging_walk(controller: MergingController,
                 measure: Callable, max_epochs: int = 8) -> list[tuple]:
    """Drive the §5.3 examination loop against any epoch-time measure.

    ``measure(amat) -> (seconds, payload)``; returns
    ``[(num_steps, seconds, payload), ...]`` and stops when the controller
    freezes. Used by benchmarks/merging.py (modeled times) and usable with
    real measured times alike.
    """
    history = []
    for _ in range(max_epochs):
        amat = controller.assignment_for_epoch()
        t, payload = measure(amat)
        history.append((amat.num_steps, t, payload))
        controller.record_epoch_time(t)
        if controller.frozen:
            break
    return history
