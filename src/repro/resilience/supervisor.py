"""Thread supervision + the graceful-degradation policy ladder.

The Trainer runs four kinds of background work: the plan prefetcher, the
``PlanUploader`` commits riding on it, the cache-refresh thread, and the
tiered-store readahead forecast. Before this module, an exception on any of
them surfaced only when (and if) its future was ``.result()``-ed — the
cache thread's an *epoch* late, an abandoned prefetch future's never — and
a stalled thread wedged the loop forever.

:class:`ThreadSupervisor` fixes the observability half: every submission is
wrapped so the executing thread records failures *at raise time* with the
originating job's ``(site, epoch, it)`` context, and the training loop
calls :meth:`check` at each dispatch boundary, turning a silent background
death into a prompt, attributable :class:`BackgroundError`. The wrapper
also publishes the site through ``faults.current_site`` so injected
thread faults know which thread they are on.

The degradation half is a policy ladder, applied by the Trainer when a
recoverable error survives an in-mode replay (see loop.py ``_recover``):

  1. prefetch/uploader failure or stall  → pipeline → synchronous fused
     loop with inline planning (bit-identical by the PR-5 pipeline≡sync
     gate);
  2. cache-thread failure                → cache-on → cache-off
     (bit-identical by the PR-3 cache parity gate);
  3. readahead / storage failure         → streamed hot-tier → resident
     gather straight from the authoritative backing tier (bit-identical by
     the PR-6 tier-parity gate).

Every rung preserves bit-exactness, only costs throughput — which is what
lets the chaos-parity tests demand identical losses under every fault
class. Each step taken is logged into ``EpochStats.degradations``.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Optional

from repro.resilience.comm import RetryPolicy
from repro.resilience import faults as _faults

import dataclasses


class BackgroundError(RuntimeError):
    """A background thread failed; carries the originating job context."""

    def __init__(self, site: str, epoch: int, it: int,
                 cause: BaseException):
        super().__init__(
            f"background {site} job for (epoch {epoch}, it {it}) failed: "
            f"{type(cause).__name__}: {cause}")
        self.site = site
        self.epoch = epoch
        self.it = it
        self.__cause__ = cause


class StallError(RuntimeError):
    """A background job missed its deadline (stalled thread / straggler)."""

    def __init__(self, site: str, epoch: int, it: int, deadline_s: float):
        super().__init__(
            f"background {site} job for (epoch {epoch}, it {it}) exceeded "
            f"its {deadline_s}s deadline")
        self.site = site
        self.epoch = epoch
        self.it = it


class NonFiniteLoss(RuntimeError):
    """NaN/Inf detected on the loss-sync window."""

    def __init__(self, epoch: int, it: int, value: float):
        super().__init__(
            f"non-finite loss {value!r} at (epoch {epoch}, it {it})")
        self.site = "loss"
        self.epoch = epoch
        self.it = it
        self.value = value


class CheckpointRollbackExhausted(RuntimeError):
    """NaN persisted across ``max_rollbacks`` replay attempts — genuine
    divergence, not a transient; surfaced to the caller."""


@dataclasses.dataclass
class ResiliencePolicy:
    """What the Trainer is allowed to do about failures.

    The default policy is cheap enough to be always-on: one params/opt
    tree copy per epoch (the rollback snapshot), a deque peek per
    iteration (the supervisor check), and an ``isfinite`` on each synced
    loss window.
    """

    enabled: bool = True
    guard_nonfinite: bool = True     # NaN/Inf loss -> rollback + replay
    degrade: bool = True             # allow the policy ladder (else replay
    #                                  in-mode only, then escalate)
    max_rollbacks: int = 2           # NaN rollbacks per fit() before escalating
    max_epoch_attempts: int = 5      # total tries per epoch (1 clean +
    #                                  replays/degradations)
    stall_deadline_s: Optional[float] = 60.0   # plan-future wait deadline
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    # -- membership (repro.membership): what to do about a *peer* dying.
    # A peer-attributed CommTimeout triggers a bounded liveness re-probe;
    # a confirmed death recovers per membership_mode: "rejoin" (replacement
    # worker takes the dead rank — bit-identical resume), "redistribute"
    # (survivors split the lost shard's vertices — elastic shrink, new
    # numerics), or "adopt" (one survivor takes the whole shard).
    membership: bool = True
    membership_mode: str = "rejoin"
    probe_attempts: int = 3          # liveness probes before confirming death
    probe_backoff_s: float = 0.001   # sleep between probes

    @classmethod
    def resolve(cls, value) -> Optional["ResiliencePolicy"]:
        """Trainer ctor coercion: None/True -> default policy, False ->
        disabled (None), a policy instance passes through."""
        if value is None or value is True:
            return cls()
        if value is False:
            return None
        if isinstance(value, cls):
            return value if value.enabled else None
        raise TypeError(f"resilience must be a ResiliencePolicy or bool, "
                        f"got {type(value)!r}")


class ThreadSupervisor:
    """Records background failures at raise time; re-raises at boundaries.

    ``submit(submitter, site, fn, *args, epoch=, it=)`` wraps ``fn`` so the
    worker thread (a) publishes its site for fault injection, (b) records
    any exception with full context into the pending deque, and (c) still
    raises — so a consumer blocking on the future sees the same wrapped
    :class:`BackgroundError` that :meth:`check` would surface. Whichever
    boundary fires first delivers the error exactly once.
    """

    def __init__(self):
        self._pending: deque = deque()
        self._lock = threading.Lock()
        self.errors_recorded = 0

    def submit(self, submitter: Callable, site: str, fn: Callable, *args,
               epoch: int = -1, it: int = -1):
        def run():
            token = _faults.current_site.set(site)
            try:
                return fn(*args)
            except BackgroundError:
                raise                    # already wrapped + recorded upstream
            except BaseException as e:
                err = BackgroundError(site, epoch, it, e)
                self._record(err)
                raise err from e
            finally:
                _faults.current_site.reset(token)
        return submitter(run)

    def _record(self, err: BackgroundError) -> None:
        with self._lock:
            self._pending.append(err)
            self.errors_recorded += 1

    def check(self) -> None:
        """Raise the earliest pending background error (iteration-boundary
        call). No-op when healthy; each error is delivered at most once."""
        if not self._pending:            # lock-free fast path (GIL-atomic)
            return
        with self._lock:
            if not self._pending:
                return
            err = self._pending.popleft()
        raise err

    def mark_delivered(self, err: BaseException) -> None:
        """A future's ``.result()`` already delivered ``err`` to the loop —
        drop the matching pending record so check() won't double-raise."""
        with self._lock:
            try:
                self._pending.remove(err)
            except ValueError:
                pass

    def drain(self) -> list:
        """Clear and return everything pending (recovery path: abandoned
        futures' errors must not leak into the next epoch attempt)."""
        with self._lock:
            out = list(self._pending)
            self._pending.clear()
        return out
