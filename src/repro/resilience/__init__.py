"""repro.resilience — fault injection, supervised recovery, degradation.

The training stack is deterministic end to end: plans and sampling are a
pure function of ``(epoch, it, seeds, pattern, cache_version)``, every
pipeline/cache/tiering mode is bit-identical to its fallback, and shape
budgets persist across restarts. This package turns that determinism into
fault tolerance:

* :mod:`~repro.resilience.faults` — a seeded :class:`FaultPlan` harness
  that injects stragglers, dropped exchanges, background-thread deaths,
  disk-row corruption, and NaN steps at scheduled ``(epoch, it)`` points
  (plus :class:`ChaosPlan`, the transient-only background chaos the CI
  chaos-smoke job runs the whole tier-1 suite under).
* :mod:`~repro.resilience.supervisor` — :class:`ThreadSupervisor` (thread
  failures surface at the next dispatch boundary with the originating
  ``(epoch, it)`` attached), :class:`ResiliencePolicy`, and the
  degradation ladder contract (pipeline→sync, cache→off, hot-tier→
  resident; every rung bit-identical).
* :mod:`~repro.resilience.comm` — deadline + bounded-retry + jittered
  exponential backoff around the host comm boundary, with per-epoch
  counters and peer attribution on timeouts (the membership layer's
  death-suspicion signal — see repro.membership).

Recovery invariant (the headline gate, CI-enforced): under a recoverable
FaultPlan training completes with losses and parameters bit-identical to
the fault-free run, with zero steady-state retraces.
"""
from repro.core.distributed import PeerDeadError
from repro.resilience.comm import (CommCounters, CommTimeout, RetryPolicy,
                                   backoff_schedule, resilient_call)
from repro.resilience.faults import (CHAOS_KINDS, ChaosPlan, FaultPlan,
                                     FaultSpec, InjectedFault,
                                     InjectedThreadError,
                                     TransientCommError, active_plan)
from repro.resilience.supervisor import (BackgroundError,
                                         CheckpointRollbackExhausted,
                                         NonFiniteLoss, ResiliencePolicy,
                                         StallError, ThreadSupervisor)

__all__ = [
    "FaultPlan", "FaultSpec", "ChaosPlan", "CHAOS_KINDS", "active_plan",
    "InjectedFault", "InjectedThreadError", "TransientCommError",
    "PeerDeadError",
    "RetryPolicy", "CommCounters", "CommTimeout", "resilient_call",
    "backoff_schedule",
    "ThreadSupervisor", "BackgroundError", "StallError", "NonFiniteLoss",
    "ResiliencePolicy", "CheckpointRollbackExhausted",
]
