"""Comm robustness: deadline + bounded-retry + jittered-backoff guards.

In a real multi-host deployment every ``ShardComm`` all_to_all is an RPC
fan-out that can drop, stall, or time out; in this repo's single-process
harness those exchanges are staged at one host boundary — the iteration
dispatch (``repro.core.distributed.prepare_iteration_args`` /
``comm_fault_point``). :func:`resilient_call` wraps that boundary: the
wrapped callable is attempted up to ``1 + max_retries`` times under a total
deadline, transient failures (:class:`TransientCommError`, and
``PeerDeadError`` — a possibly-flapping peer) back off exponentially with
decorrelation jitter between attempts, and every retry/timeout lands in a
per-epoch :class:`CommCounters` that the Trainer drains into
``EpochStats``.

Backoff jitter: when one straggler stalls an iteration, *every* shard's
dispatch fails at the same instant; pure exponential backoff would re-issue
all P retries in lockstep and re-collide on the recovering fabric. Each
retry therefore sleeps ``base * (1 - jitter * u)`` where ``u ∈ [0, 1)`` is
a splitmix64 hash of ``(seed, epoch, it, attempt)`` — deterministic (a
replayed epoch sleeps the same schedule, and tests can assert it exactly
via :func:`backoff_schedule`) yet decorrelated across shards, which seed
their policies differently.

Peer attribution: a transient carrying a ``peer`` attribute (the engine's
``PeerDeadError``) stamps the eventual :class:`CommTimeout` with the last
peer seen — the signal ``repro.membership`` uses to turn a timeout into a
death suspicion.

Safety with buffer donation: the engine's fused train step donates
``params``/``opt_state``; retrying a dispatch after donation would reuse
dead buffers. The guard is therefore only sound because every injected (or
real, host-side) transient raise happens *before* the compiled program is
invoked — the comm fault point runs during argument staging, ahead of any
donation. A genuine failure raised by the compiled program itself is not a
``TransientCommError`` and propagates unretried.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

from repro.core.distributed import PeerDeadError
from repro.obs import metrics as _obs_metrics
from repro.obs.trace import event as _obs_event
from repro.resilience.faults import TransientCommError, guarded_attempt


class CommTimeout(RuntimeError):
    """Retries/deadline exhausted on a transient-failing exchange.

    ``peer`` is the shard id of the last peer-attributed transient (-1 when
    no attempt named one) — the membership detector's suspicion signal."""

    def __init__(self, msg: str, *, epoch: int = -1, it: int = -1,
                 attempts: int = 0, peer: int = -1):
        super().__init__(msg)
        self.site = "comm"
        self.epoch = epoch
        self.it = it
        self.attempts = attempts
        self.peer = int(peer)


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with jittered exponential backoff under a deadline."""

    max_retries: int = 3          # attempts beyond the first
    backoff_s: float = 0.005      # sleep before attempt 1's retry
    backoff_mult: float = 2.0     # backoff_s * mult**(attempt-1)
    deadline_s: float = 5.0       # total wall budget across attempts
    jitter: float = 0.5           # fraction of each backoff randomized away
    seed: int = 0                 # decorrelation hash seed (per shard/site)


def _jitter01(seed: int, epoch: int, it: int, attempt: int) -> float:
    """splitmix64-flavoured hash of (seed, epoch, it, attempt) -> [0, 1).
    Pure: the retry schedule is a function of its coordinates, never of
    wall clock or global RNG state."""
    mask = (1 << 64) - 1
    x = ((seed * 0x9E3779B97F4A7C15) & mask
         ^ ((epoch & 0xFFFF) << 40)
         ^ ((it & 0xFFFFF) << 20)
         ^ (attempt & 0xFFFFF))
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & mask
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & mask
    x = x ^ (x >> 31)
    return x / 2**64


def backoff_delay(policy: RetryPolicy, attempt: int, *, epoch: int = -1,
                  it: int = -1) -> float:
    """The exact sleep before re-issuing attempt ``attempt`` (1-based).

    ``base * (1 - jitter * u)``: full backoff at u=0, ``(1-jitter)`` of it
    at u→1 — never longer than the unjittered schedule, so deadlines tuned
    without jitter stay valid."""
    base = policy.backoff_s * policy.backoff_mult ** (attempt - 1)
    if policy.jitter <= 0.0:
        return base
    u = _jitter01(policy.seed, epoch, it, attempt)
    return base * (1.0 - policy.jitter * u)


def backoff_schedule(policy: RetryPolicy, *, epoch: int = -1, it: int = -1,
                     attempts: Optional[int] = None) -> List[float]:
    """The full sleep schedule ``resilient_call`` would take at these
    coordinates (one entry per retry). Exists so tests can pin the
    schedule without timing a live retry loop."""
    n = policy.max_retries if attempts is None else attempts
    return [backoff_delay(policy, a, epoch=epoch, it=it)
            for a in range(1, n + 1)]


@dataclasses.dataclass
class CommCounters:
    """Per-epoch exchange robustness accounting (drained into EpochStats)."""

    retries: int = 0
    timeouts: int = 0

    def reset(self) -> None:
        self.retries = 0
        self.timeouts = 0


def resilient_call(fn: Callable, *, policy: RetryPolicy,
                   counters: Optional[CommCounters] = None,
                   epoch: int = -1, it: int = -1):
    """Run ``fn()`` under the retry policy.

    The attempt number is published via the ``guarded_attempt`` context var
    so the fault injector knows a retry loop is present (comm_drop and
    flapping peer_death faults only raise under a guard, and only while
    ``attempt < drops``)."""
    t0 = time.perf_counter()
    attempt = 0
    peer = -1
    while True:
        token = guarded_attempt.set(attempt)
        try:
            return fn()
        except (TransientCommError, PeerDeadError) as e:
            peer = getattr(e, "peer", peer)
            if counters is not None:
                counters.retries += 1
            # every resilient_call site lands on the unified registry,
            # whether or not the caller passed per-epoch counters
            _obs_metrics.inc("comm.retries")
            _obs_event("comm.retry", epoch=epoch, it=it, attempt=attempt,
                       peer=peer)
            attempt += 1
            elapsed = time.perf_counter() - t0
            if attempt > policy.max_retries or elapsed > policy.deadline_s:
                if counters is not None:
                    counters.timeouts += 1
                _obs_metrics.inc("comm.timeouts")
                raise CommTimeout(
                    f"exchange failed after {attempt} attempts / "
                    f"{elapsed:.3f}s (deadline {policy.deadline_s}s): {e}",
                    epoch=epoch, it=it, attempts=attempt, peer=peer) from e
            time.sleep(backoff_delay(policy, attempt, epoch=epoch, it=it))
        finally:
            guarded_attempt.reset(token)
