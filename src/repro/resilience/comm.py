"""Comm robustness: deadline + bounded-retry + exponential-backoff guards.

In a real multi-host deployment every ``ShardComm`` all_to_all is an RPC
fan-out that can drop, stall, or time out; in this repo's single-process
harness those exchanges are staged at one host boundary — the iteration
dispatch (``repro.core.distributed.prepare_iteration_args`` /
``comm_fault_point``). :func:`resilient_call` wraps that boundary: the
wrapped callable is attempted up to ``1 + max_retries`` times under a total
deadline, transient failures (:class:`TransientCommError`) back off
exponentially between attempts, and every retry/timeout lands in a
per-epoch :class:`CommCounters` that the Trainer drains into
``EpochStats``.

Safety with buffer donation: the engine's fused train step donates
``params``/``opt_state``; retrying a dispatch after donation would reuse
dead buffers. The guard is therefore only sound because every injected (or
real, host-side) transient raise happens *before* the compiled program is
invoked — the comm fault point runs during argument staging, ahead of any
donation. A genuine failure raised by the compiled program itself is not a
``TransientCommError`` and propagates unretried.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.obs import metrics as _obs_metrics
from repro.obs.trace import event as _obs_event
from repro.resilience.faults import TransientCommError, guarded_attempt


class CommTimeout(RuntimeError):
    """Retries/deadline exhausted on a transient-failing exchange."""

    def __init__(self, msg: str, *, epoch: int = -1, it: int = -1,
                 attempts: int = 0):
        super().__init__(msg)
        self.site = "comm"
        self.epoch = epoch
        self.it = it
        self.attempts = attempts


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with exponential backoff under a total deadline."""

    max_retries: int = 3          # attempts beyond the first
    backoff_s: float = 0.005      # sleep before attempt 1's retry
    backoff_mult: float = 2.0     # backoff_s * mult**(attempt-1)
    deadline_s: float = 5.0       # total wall budget across attempts


@dataclasses.dataclass
class CommCounters:
    """Per-epoch exchange robustness accounting (drained into EpochStats)."""

    retries: int = 0
    timeouts: int = 0

    def reset(self) -> None:
        self.retries = 0
        self.timeouts = 0


def resilient_call(fn: Callable, *, policy: RetryPolicy,
                   counters: Optional[CommCounters] = None,
                   epoch: int = -1, it: int = -1):
    """Run ``fn()`` under the retry policy.

    The attempt number is published via the ``guarded_attempt`` context var
    so the fault injector knows a retry loop is present (comm_drop faults
    only raise under a guard, and only while ``attempt < drops``)."""
    t0 = time.perf_counter()
    attempt = 0
    while True:
        token = guarded_attempt.set(attempt)
        try:
            return fn()
        except TransientCommError as e:
            if counters is not None:
                counters.retries += 1
            # every resilient_call site lands on the unified registry,
            # whether or not the caller passed per-epoch counters
            _obs_metrics.inc("comm.retries")
            _obs_event("comm.retry", epoch=epoch, it=it, attempt=attempt)
            attempt += 1
            elapsed = time.perf_counter() - t0
            if attempt > policy.max_retries or elapsed > policy.deadline_s:
                if counters is not None:
                    counters.timeouts += 1
                _obs_metrics.inc("comm.timeouts")
                raise CommTimeout(
                    f"exchange failed after {attempt} attempts / "
                    f"{elapsed:.3f}s (deadline {policy.deadline_s}s): {e}",
                    epoch=epoch, it=it, attempts=attempt) from e
            time.sleep(policy.backoff_s * policy.backoff_mult
                       ** (attempt - 1))
        finally:
            guarded_attempt.reset(token)
