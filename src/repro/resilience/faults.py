"""Deterministic, seeded fault injection for the training stack.

The whole recovery story of :mod:`repro.resilience` rests on one property
the rest of the repo already enforces: plans and sampling are a pure
function of ``(epoch, it, seeds, pattern, cache_version)``. A fault that is
absorbed (retried, replayed, or degraded around) therefore leaves *zero*
numerical trace — the recovered run's losses and parameters are bit-
identical to the fault-free run. This module provides the controlled way to
prove that: a :class:`FaultPlan` schedules faults at exact ``(epoch, it)``
points, the instrumented sites consult the active plan, and every fired
fault is logged so tests and benchmarks can assert both that the fault
actually happened and that it left no trace.

Fault classes (``FaultSpec.kind``):

* ``comm_delay``   — a straggling peer: the dispatch-side comm point sleeps
  ``delay_s`` before the exchange is issued. Absorbed by the pipeline (or
  by nothing — it is pure wall-clock).
* ``comm_drop``    — a dropped index/feature exchange: the comm point
  raises :class:`TransientCommError` on the first ``drops`` attempts of the
  guarded dispatch; the retry wrapper (repro.resilience.comm) re-issues it
  with backoff. Only fires under a guard (``guarded_attempt`` context set),
  so unguarded engine callers degrade to a no-op instead of crashing.
* ``thread_stall`` — the target background thread sleeps ``delay_s``
  (models GC pauses / noisy neighbours on the planning host).
* ``thread_exc``   — the target background thread raises
  :class:`InjectedThreadError` (models a real bug/OOM on the prefetcher,
  uploader, or cache thread). Fires only when the executing thread's
  supervisor site context matches ``site`` — after the Trainer degrades to
  inline planning the same spec no longer matches, which is exactly how a
  persistent thread fault converges down the degradation ladder.
* ``disk_corrupt`` — scribbles deterministic garbage over feature rows in
  the FeatureStore's backing tier (and marks the chunk suspect, standing in
  for a scrubber / EIO signal). Detected by the store's crc32 verification,
  repaired from the authoritative source (repro.features).
* ``nan_loss``     — poisons one training step's loss *and* parameters with
  NaN (models numerical divergence / a flipped exponent bit). Detected at
  the next loss-sync window; recovered by rollback to the epoch-start
  snapshot and deterministic replay.
* ``peer_death``   — a peer shard dies (host crash / network partition of a
  feature server, the failure mode repro.membership exists for). The spec's
  ``shard`` is registered in the engine's dead-peer registry at the comm
  fault point; every subsequent dispatch raises ``PeerDeadError`` until a
  probe confirms the death and the membership layer recovers (rejoin or
  elastic re-ownership). With ``transient=True`` the peer merely *flaps*:
  the first ``drops`` attempts of a guarded dispatch raise PeerDeadError
  and the retry succeeds with no membership change (what ChaosPlan uses).

Scheduling is exact — ``(epoch, it)`` — and firing is once-only by default
(``once=True``); a replayed epoch does not re-trip its own fault, which is
what makes recovery terminate. ``once=False`` models persistent faults and
is what the degradation-ladder tests use.

One plan is active per process (``install``/``uninstall`` or the
``active()`` context manager); instrumented sites go through the module
functions :func:`fire_comm`, :func:`raise_if_thread`, :func:`sleep_point`,
:func:`take`, which are all no-ops when no plan is installed (the fast path
is one global read).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import threading
import time
from typing import List, Optional

import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs.trace import event as _obs_event


def _mark_fired(kind: str, site: str, epoch: int, it: int) -> None:
    """Telemetry for one fired fault (repro.obs): a registry counter per
    kind plus an instant mark on the current thread's timeline track, so
    a chaos run's exported trace shows exactly what was absorbed where."""
    _obs_metrics.inc("faults.fired")
    _obs_metrics.inc(f"faults.{kind}")
    _obs_event(f"fault.{kind}", site=site or "", epoch=epoch, it=it)


# Supervisor site of the current thread ("prefetch"/"uploader"/"cache"/
# "readahead"); set by ThreadSupervisor around background jobs. thread_exc
# faults fire only when this matches their site.
current_site: contextvars.ContextVar = contextvars.ContextVar(
    "repro_resilience_site", default=None)

# Attempt number of the current guarded dispatch (repro.resilience.comm's
# resilient_call); None outside a guard. comm_drop faults fire only inside
# a guard — an unguarded caller must never see an injected raise.
guarded_attempt: contextvars.ContextVar = contextvars.ContextVar(
    "repro_resilience_attempt", default=None)


class InjectedFault(Exception):
    """Mixin marking an exception as fault-injection-originated (tests and
    the supervisor can tell injected failures from genuine bugs)."""


class TransientCommError(InjectedFault, RuntimeError):
    """A dropped/timed-out exchange that a retry may recover."""


class InjectedThreadError(InjectedFault, RuntimeError):
    """Background-thread death injected by a FaultPlan."""


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault. ``it < 0`` matches any iteration of ``epoch``
    (used by epoch-boundary faults like disk corruption)."""

    kind: str                 # see module docstring
    epoch: int
    it: int = -1
    site: str = ""            # thread faults: prefetch|uploader|cache|readahead
    shard: int = 0            # disk_corrupt target
    row: int = 0
    rows: int = 1             # disk_corrupt: contiguous rows scribbled
    delay_s: float = 0.0      # comm_delay / thread_stall
    drops: int = 1            # comm_drop / flapping peer_death: failing
                              # attempts before success
    once: bool = True
    transient: bool = False   # peer_death: flap (guarded raise) instead of
                              # registering a persistent kill


class FaultPlan:
    """A seeded, ordered set of :class:`FaultSpec`\\ s plus a fired log.

    ``fired`` records ``(kind, site, epoch, it)`` tuples in firing order —
    benchmarks and chaos-parity tests assert against it (that the faults
    actually fired *and* that the run recovered bit-identically anyway).
    """

    def __init__(self, specs: List[FaultSpec], seed: int = 0,
                 name: str = "faultplan"):
        self.specs = list(specs)
        self.seed = int(seed)
        self.name = name
        self.fired: list[tuple] = []
        self._spent: set[int] = set()    # indices of exhausted once-specs
        self._lock = threading.Lock()

    # -- matching ------------------------------------------------------

    def _take(self, kind: str, epoch: int, it: int,
              site: Optional[str] = None) -> List[FaultSpec]:
        """Matching specs for a fault point, marking once-specs spent and
        logging the firing. Thread-safe (sites fire from worker threads)."""
        out = []
        with self._lock:
            for i, sp in enumerate(self.specs):
                if sp.kind != kind or i in self._spent:
                    continue
                if sp.epoch != epoch:
                    continue
                if sp.it >= 0 and it >= 0 and sp.it != it:
                    continue
                if site is not None and sp.site and sp.site != site:
                    continue
                if sp.once:
                    self._spent.add(i)
                self.fired.append((sp.kind, sp.site, epoch, it))
                out.append(sp)
        for sp in out:
            _mark_fired(sp.kind, sp.site, epoch, it)
        return out

    def fired_count(self) -> int:
        with self._lock:
            return len(self.fired)

    # -- lifecycle -----------------------------------------------------

    def install(self) -> "FaultPlan":
        _set_active(self)
        return self

    def uninstall(self) -> None:
        _set_active(None, expect=self)

    @contextlib.contextmanager
    def active(self):
        prev = active_plan()          # nest inside e.g. a session ChaosPlan
        self.install()
        try:
            yield self
        finally:
            _set_active(prev, expect=self)

    # -- canonical plans ----------------------------------------------

    @classmethod
    def recoverable(cls, seed: int = 0, *, kill_epoch: int = 1,
                    nan_epoch: int = 2) -> "FaultPlan":
        """The headline-gate plan: one background-thread kill, one
        transient comm delay, one dropped exchange (retried), one corrupted
        disk chunk, and one NaN step — every one recoverable, so training
        must finish bit-identical to the fault-free run."""
        return cls([
            FaultSpec("thread_exc", epoch=kill_epoch, it=1, site="prefetch"),
            FaultSpec("comm_delay", epoch=kill_epoch, it=3, delay_s=0.003),
            FaultSpec("comm_drop", epoch=kill_epoch, it=5, drops=1),
            FaultSpec("disk_corrupt", epoch=kill_epoch, shard=0, row=0,
                      rows=2),
            FaultSpec("nan_loss", epoch=nan_epoch, it=1),
        ], seed=seed, name="recoverable")


# The chaos whitelist: every kind ChaosPlan can draw. The CI chaos job
# asserts each of these actually fired at least once over the suite (see
# tests/conftest.py) — a kind that stops firing means the chaos coverage
# silently regressed, not that the code got more robust.
CHAOS_KINDS = ("comm_delay", "comm_drop", "thread_stall", "peer_death")


class ChaosPlan(FaultPlan):
    """Low-rate, transient-only background chaos for running whole test
    suites under fault pressure (the CI chaos-smoke job).

    Faults are drawn deterministically from a hash of
    ``(seed, kind, epoch, it)`` — the same run sees the same faults — and
    are restricted to classes that every code path absorbs without
    semantic effect: short comm delays, single-drop exchanges (guarded
    callers retry; unguarded callers never see drops), short planner
    stalls, and flapping peers (``peer_death`` with ``transient=True``: a
    guarded dispatch sees PeerDeadError once, the retry finds the peer
    back — the membership detector's false-positive path). No corruption,
    no thread kills, no NaNs, no *persistent* deaths: tier-1 assertions
    (bit-parity, trace counts) must hold unchanged under this plan.

    ``offered`` counts how many times each kind was *consulted* (fired or
    not), so the coverage assertion can require fired > 0 only for kinds
    the suite actually exposed enough draws to.
    """

    def __init__(self, seed: int = 0, rate: float = 0.05,
                 max_delay_s: float = 0.002):
        super().__init__([], seed=seed, name=f"chaos-smoke-{seed}")
        self.rate = float(rate)
        self.max_delay_s = float(max_delay_s)
        self.offered: dict[str, int] = {k: 0 for k in CHAOS_KINDS}

    def fired_by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {k: 0 for k in CHAOS_KINDS}
        with self._lock:
            for kind, _site, _e, _i in self.fired:
                out[kind] = out.get(kind, 0) + 1
        return out

    def _hash01(self, kind: str, epoch: int, it: int) -> float:
        # splitmix64-flavoured integer hash -> [0, 1); Python ints with an
        # explicit 64-bit mask (multiplication is *meant* to wrap). The
        # kind is mixed in via crc32, NOT hash(): str hash is randomized
        # per process, which would make the chaos schedule differ between
        # runs of the same seed — the coverage assertion (tests/conftest)
        # and "same run sees the same faults" both need it stable.
        import zlib
        mask = (1 << 64) - 1
        x = ((self.seed * 0x9E3779B97F4A7C15) & mask
             ^ (zlib.crc32(kind.encode()) & 0xFFFFFFFF)
             ^ ((epoch & 0xFFFF) << 32)
             ^ (it & 0xFFFFFFFF))
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & mask
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & mask
        x = x ^ (x >> 31)
        return x / 2**64

    def _take(self, kind: str, epoch: int, it: int,
              site: Optional[str] = None) -> List[FaultSpec]:
        if kind not in CHAOS_KINDS:
            return []
        with self._lock:
            self.offered[kind] = self.offered.get(kind, 0) + 1
        u = self._hash01(kind, epoch, it)
        # drops and flaps raise (absorbed only by guarded retries) — keep
        # them rarer than the pure-wall-clock delays/stalls
        thresh = self.rate * (0.5 if kind in ("comm_drop", "peer_death")
                              else 1.0)
        if u >= thresh:
            return []
        sp = FaultSpec(kind, epoch=epoch, it=it, site=site or "",
                       delay_s=(u / max(thresh, 1e-12)) * self.max_delay_s,
                       drops=1, once=False,
                       transient=(kind == "peer_death"))
        with self._lock:
            self.fired.append((kind, site or "", epoch, it))
        _mark_fired(kind, site or "", epoch, it)
        return [sp]


# ---------------------------------------------------------------------------
# Active-plan registry + instrumented fault points
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None
_ACTIVE_LOCK = threading.Lock()


def _set_active(plan: Optional[FaultPlan],
                expect: Optional[FaultPlan] = None) -> None:
    global _ACTIVE
    with _ACTIVE_LOCK:
        if expect is not None and _ACTIVE is not expect:
            return            # someone else's plan is active; leave it
        _ACTIVE = plan
    # keep the engine's host-boundary comm hook in sync (lazy import keeps
    # repro.core free of any resilience dependency)
    from repro.core import distributed as engine
    engine.set_comm_fault_hook(None if plan is None else _engine_comm_hook)


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def _epoch_it_of(plan_obj) -> tuple[int, int]:
    ei = getattr(plan_obj, "epoch_it", None)
    return (int(ei[0]), int(ei[1])) if ei is not None else (-1, -1)


def _engine_comm_hook(plan_obj) -> None:
    """Installed into repro.core.distributed's host comm boundary: every
    iteration dispatch staging exchange arguments passes through here."""
    epoch, it = _epoch_it_of(plan_obj)
    fire_comm(epoch, it)


def fire_comm(epoch: int, it: int) -> None:
    """Comm fabric fault point (dispatch-side, before any buffer donation).

    Delays sleep in place; drops raise :class:`TransientCommError` but only
    inside a guarded dispatch (``guarded_attempt`` set) and only while the
    attempt number is below the spec's ``drops`` — a retry always succeeds
    eventually, and unguarded callers only ever see the sleep."""
    fp = _ACTIVE
    if fp is None:
        return
    for sp in fp._take("comm_delay", epoch, it):
        time.sleep(sp.delay_s)
    attempt = guarded_attempt.get()
    for sp in fp._take("comm_drop", epoch, it):
        if attempt is not None and attempt < sp.drops:
            raise TransientCommError(
                f"injected drop of exchange at (epoch {epoch}, it {it}), "
                f"attempt {attempt}")
    for sp in fp._take("peer_death", epoch, it):
        from repro.core import distributed as engine
        if sp.transient:
            # flapping peer: unreachable for the first ``drops`` guarded
            # attempts, back before the probe would confirm anything.
            # Unguarded callers never see the raise (same contract as
            # comm_drop) — the membership layer must treat a recovered
            # flap as a non-event.
            if attempt is not None and attempt < sp.drops:
                raise engine.PeerDeadError(
                    f"injected peer flap: shard {sp.shard} unreachable at "
                    f"(epoch {epoch}, it {it}), attempt {attempt}",
                    peer=sp.shard)
        else:
            # persistent death: register the kill; the enclosing
            # comm_fault_point consults the registry right after the hook
            # returns, so this same dispatch fails with the peer attributed
            engine.kill_peer(sp.shard)


def sleep_point(kind_site: str, epoch: int, it: int) -> None:
    """Stall fault point (prefetcher/planner): sleeps if a thread_stall is
    scheduled here. Safe from any thread, inline or pooled."""
    fp = _ACTIVE
    if fp is None:
        return
    for sp in fp._take("thread_stall", epoch, it, site=kind_site):
        time.sleep(sp.delay_s)


def raise_if_thread(site: str, epoch: int, it: int) -> None:
    """Thread-death fault point: raises InjectedThreadError when a
    thread_exc is scheduled for this site AND the executing thread is
    actually supervised under that site (inline fallbacks don't re-trip)."""
    fp = _ACTIVE
    if fp is None or current_site.get() != site:
        return
    if fp._take("thread_exc", epoch, it, site=site):
        raise InjectedThreadError(
            f"injected {site}-thread death at (epoch {epoch}, it {it})")


def take(kind: str, epoch: int, it: int = -1) -> List[FaultSpec]:
    """Generic take for Trainer-managed fault classes (nan_loss at dispatch,
    disk_corrupt at epoch boundaries)."""
    fp = _ACTIVE
    if fp is None:
        return []
    return fp._take(kind, epoch, it)


def inject_disk_corruption(store, spec: FaultSpec) -> int:
    """Scribble deterministic garbage over ``spec.rows`` backing rows of
    ``spec.shard`` starting at ``spec.row`` and mark the chunk suspect
    (see repro.features.FeatureStore.corrupt_rows). Returns rows hit."""
    rows = np.arange(spec.row, spec.row + max(1, spec.rows), dtype=np.int64)
    rows = rows[rows < store.local_rows]
    if rows.size:
        store.corrupt_rows(spec.shard, rows, seed=active_seed())
    return int(rows.size)


def active_seed() -> int:
    return _ACTIVE.seed if _ACTIVE is not None else 0
