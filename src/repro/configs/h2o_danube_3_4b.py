"""h2o-danube-3-4b — dense llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    swa_window=4096, mlp="swiglu", rope_theta=10_000.0,
    source="arXiv:2401.16818",
)
