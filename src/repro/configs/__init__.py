"""Architecture + input-shape registry.

``get_config(arch_id)`` returns the exact published configuration;
``smoke_variant(cfg)`` returns the reduced same-family variant used by the
CPU smoke tests (≤2 layers, d_model ≤ 512, ≤4 experts — per the brief);
``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input of a given input shape (no allocation —
the dry-run pattern).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig

_MODULES = {
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "pixtral-12b": "pixtral_12b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen2.5-3b": "qwen2_5_3b",
    "whisper-base": "whisper_base",
    "qwen2-1.5b": "qwen2_1_5b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-moe-16b": "deepseek_moe_16b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason). long_500k requires sub-quadratic decode state."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full attention: a 524288-token KV cache is O(S) "
                       "per token with O(S) HBM — skipped per DESIGN.md §4")
    return True, ""


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant: ≤2 layers (one pattern period for
    hybrids), d_model ≤ 512, ≤4 experts, small vocab."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2),
        d_model=min(cfg.d_model, 256),
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        vocab_pad_to=128,
        dtype="float32",
    )
    if cfg.num_heads:
        heads = min(cfg.num_heads, 4)
        kw["num_heads"] = heads
        kw["num_kv_heads"] = max(1, min(cfg.num_kv_heads,
                                        heads if cfg.num_kv_heads >= cfg.num_heads
                                        else max(1, heads // 2)))
        kw["head_dim"] = kw["d_model"] // heads
    if cfg.swa_window:
        kw["swa_window"] = 64
    if cfg.moe_num_experts:
        kw["moe_num_experts"] = 4
        kw["moe_top_k"] = min(cfg.moe_top_k, 2)
        kw["moe_num_shared"] = min(cfg.moe_num_shared, 1)
        kw["moe_expert_d_ff"] = 128
    if cfg.family == "hybrid":
        kw["num_layers"] = len(tuple(cfg.block_pattern))   # one full period
        kw["rglru_width"] = kw["d_model"]
        kw["local_attn_window"] = 32
    if cfg.family == "ssm":
        kw["rwkv_head_dim"] = 32
    if cfg.family == "audio":
        kw["encoder_layers"] = 2
        kw["encoder_seq"] = 64
        kw["encoder_d_model"] = kw["d_model"]
    if cfg.family == "vlm":
        kw["num_patches"] = 16
        kw["patch_dim"] = 64
    return dataclasses.replace(cfg, **kw)


def input_specs(cfg: ArchConfig, shape_name: str,
                seq: Optional[int] = None,
                batch: Optional[int] = None) -> dict:
    """ShapeDtypeStruct stand-ins for the *data* inputs of a step.

    train/prefill → the forward batch dict; decode → {"token": (B,)}
    (the decode *state* specs come from ``jax.eval_shape`` over
    ``init_decode_state`` in the dry-run driver).
    """
    sh = SHAPES[shape_name]
    S = seq if seq is not None else sh.seq_len
    B = batch if batch is not None else sh.global_batch
    i32 = jnp.int32
    act = cfg.activation_dtype
    if sh.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((B,), i32)}
    specs: dict = {}
    if cfg.family == "vlm":
        P = min(cfg.num_patches, max(S // 4, 1))
        specs["patches"] = jax.ShapeDtypeStruct((B, P, cfg.patch_dim), act)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
    elif cfg.family == "audio":
        De = cfg.encoder_d_model or cfg.d_model
        specs["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, De), act)
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs
