"""pixtral-12b — Pixtral-ViT (stubbed) + mistral-nemo decoder backbone.
[hf:mistralai/Pixtral-12B-2409]"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072,
    mlp="swiglu", rope_theta=1_000_000.0,
    num_patches=1024, patch_dim=1024,     # stub ViT output (P, 1024)
    source="hf:mistralai/Pixtral-12B-2409",
)
