"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936,
    qkv_bias=True, mlp="swiglu",
    moe_num_experts=60, moe_top_k=4, moe_num_shared=4,
    moe_expert_d_ff=1408, moe_dispatch="auto",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
