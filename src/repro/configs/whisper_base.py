"""whisper-base — encoder-decoder audio backbone; conv/mel frontend is a
STUB (input_specs supplies frame embeddings). [arXiv:2212.04356]"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    mlp="gelu",
    encoder_layers=6, encoder_seq=1500, encoder_d_model=512,
    source="arXiv:2212.04356",
)
