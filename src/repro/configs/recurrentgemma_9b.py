"""recurrentgemma-9b — RG-LRU recurrent + local attention hybrid, pattern
(rec, rec, attn). [arXiv:2402.19427]"""
from repro.models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000,
    block_pattern=("rec", "rec", "attn"),
    rglru_width=4096, local_attn_window=2048, conv_width=4,
    mlp="swiglu",
    source="arXiv:2402.19427",
)
