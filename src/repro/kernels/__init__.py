"""Pallas TPU kernels for the perf-critical compute layers.

* ``gather_agg``  — feature-row gather / fused gather+aggregate (the GNN
  SpMM hot-spot re-expressed on the fixed-fanout tree layout).
* ``linattn``     — chunked RWKV6-style gated linear attention (the rwkv6-7b
  assigned-arch hot-spot).
* ``ops``         — platform-dispatching jit wrappers (call these).
* ``ref``         — pure-jnp oracles defining each kernel's semantics.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
