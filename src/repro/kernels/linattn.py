"""Pallas TPU kernel: chunked RWKV6-style gated linear attention.

The RWKV6 recurrence (ref.py `linattn_ref`) is a token-serial scan — O(T)
sequential steps, hostile to the MXU. The chunked re-formulation (GLA/FLA
family) processes C tokens per step with dense matmuls and carries only the
(dk, dv) state between chunks:

  with e_t = Π_{r≤t} w_r (inclusive cumprod inside the chunk, e_0 = 1):
    o_t   = (q_t ⊙ e_{t-1}) · S_in
          + Σ_{s<t} ((q_t ⊙ e_{t-1}/e_s) · k_s) v_s        (intra, masked)
          + ((q_t ⊙ u) · k_t) v_t                           (bonus diag)
    S_out = diag(e_C) S_in + (K ⊙ e_C/e)ᵀ V

Everything inside a chunk is (C×dk)·(dk×dv) / (C×C)·(C×dv) matmuls —
MXU-shaped with C = dk = dv = multiples of 8/128. The state lives in a VMEM
scratch that persists across the (sequential) chunk axis of the grid; the
batch·head axis is parallel.

Numerical note: e_{t-1}/e_s can overflow for long chunks of small w; with
C = 128 and w ∈ [0.5, 1) (RWKV6's exp(-exp(·)) decays near 1 in practice)
the ratio stays ≤ 2^128 in f32 only if w ≥ 0.5 — the wrapper asserts the
documented domain w ∈ (2⁻¹, 1]. Production RWKV6 keeps log-decays small, so
this domain is the realistic one; the ref oracle has no such restriction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed upstream: older jax ships TPUCompilerParams, newer CompilerParams.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))
if _COMPILER_PARAMS is None:
    def _COMPILER_PARAMS(**kwargs):
        raise AttributeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams — unsupported jax version for linattn")


def _linattn_kernel(q_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_out_ref,
                    s_ref, *, chunk: int, nchunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[0].astype(jnp.float32)          # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (C, dv)
    w = w_ref[0].astype(jnp.float32)          # (C, dk)
    u = u_ref[...].astype(jnp.float32)        # (1, dk)

    e = jnp.cumprod(w, axis=0)                # e_t, inclusive
    e_prev = e / w                            # e_{t-1} = e_t / w_t (w > 0)
    S = s_ref[...]                            # (dk, dv) carried state

    q_dec = q * e_prev                        # (C, dk)
    # intra-chunk attention matrix, strictly causal
    att = q_dec @ (k / e).T                   # (C, C)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(s_idx < t_idx, att, 0.0)
    bonus = jnp.sum((q * u) * k, axis=1)      # (C,)
    o = q_dec @ S + att @ v + bonus[:, None] * v
    o_ref[0] = o.astype(o_ref.dtype)

    e_last = e[-1]                            # (dk,)
    s_ref[...] = e_last[:, None] * S + ((k * (e_last / e)).T @ v)

    @pl.when(c == nchunks - 1)
    def _emit_state():
        s_out_ref[0] = s_ref[...]


def linattn_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    w: jnp.ndarray, u: jnp.ndarray, chunk: int = 64,
                    interpret: bool = False
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """q,k,w: (BH, T, dk); v: (BH, T, dv); u: (dk,) or (BH, dk) per-head
    bonus. T % chunk == 0.
    Returns (o: (BH, T, dv) in q.dtype, final state (BH, dk, dv) f32)."""
    BH, T, dk = q.shape
    dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    nchunks = T // chunk
    u2 = jnp.broadcast_to(u, (BH, dk))
    kern = functools.partial(_linattn_kernel, chunk=chunk, nchunks=nchunks)
    o, s_out = pl.pallas_call(
        kern,
        grid=(BH, nchunks),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dv), q.dtype),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, w, u2)
    return o, s_out
