"""Dispatching wrappers around the Pallas kernels.

Call sites never touch `pallas_call` directly: each op picks the best
implementation for the runtime platform —

  * TPU      → the Pallas kernel (compiled),
  * CPU/test → the pure-jnp oracle (ref.py), or the kernel in interpret
               mode when ``force_kernel=True`` (how tests exercise it).

The jnp paths are differentiable; training uses them (the chunked
formulation is matmul-parallel in jnp too). The Pallas kernels are the
serving/TPU fast path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import gather_agg as _ga
from repro.kernels import linattn as _la
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gather_rows(table: jnp.ndarray, idx: jnp.ndarray,
                force_kernel: bool = False) -> jnp.ndarray:
    """out[i] = table[idx[i]]."""
    if _on_tpu():
        return _ga.gather_rows(table, idx)
    if force_kernel:
        return _ga.gather_rows(table, idx, interpret=True)
    return _ref.gather_rows_ref(table, idx)


def gather_agg(table: jnp.ndarray, idx: jnp.ndarray, reduce: str = "sum",
               force_kernel: bool = False) -> jnp.ndarray:
    """out[i] = reduce_j table[idx[i, j]] (fused gather + segment reduce)."""
    if _on_tpu():
        return _ga.gather_agg(table, idx, reduce=reduce)
    if force_kernel:
        return _ga.gather_agg(table, idx, reduce=reduce, interpret=True)
    return _ref.gather_agg_ref(table, idx, reduce=reduce)


# ---------------------------------------------------------------------------
# Gated linear attention (RWKV6)
# ---------------------------------------------------------------------------

def linattn_chunked_jnp(q, k, v, w, u, state=None, chunk: int = 64):
    """Differentiable chunked formulation in pure jnp (same math as the
    Pallas kernel; lax.scan over chunks carries the state). Used by the
    RWKV6 *training* path; the Pallas kernel serves prefill on TPU."""
    BH, T, dk = q.shape
    dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    C = chunk
    if state is None:
        state = jnp.zeros((BH, dk, dv), jnp.float32)

    qc = q.reshape(BH, T // C, C, dk).astype(jnp.float32)
    kc = k.reshape(BH, T // C, C, dk).astype(jnp.float32)
    vc = v.reshape(BH, T // C, C, dv).astype(jnp.float32)
    wc = w.reshape(BH, T // C, C, dk).astype(jnp.float32)
    uf = jnp.broadcast_to(u, (BH, dk)).astype(jnp.float32)

    t_idx = jnp.arange(C)[:, None]
    s_idx = jnp.arange(C)[None, :]
    causal = (s_idx < t_idx)

    def chunk_step(S, xs):
        qb, kb, vb, wb = xs                   # (BH, C, *)
        e = jnp.cumprod(wb, axis=1)
        e_prev = e / wb
        q_dec = qb * e_prev
        att = jnp.einsum("btd,bsd->bts", q_dec, kb / e)
        att = jnp.where(causal[None], att, 0.0)
        bonus = jnp.einsum("btd,btd->bt", qb * uf[:, None, :], kb)
        o = (jnp.einsum("btd,bdv->btv", q_dec, S)
             + jnp.einsum("bts,bsv->btv", att, vb)
             + bonus[..., None] * vb)
        e_last = e[:, -1]                     # (BH, dk)
        S = (e_last[..., None] * S
             + jnp.einsum("btd,btv->bdv", kb * (e_last[:, None, :] / e), vb))
        return S, o

    S, o = jax.lax.scan(chunk_step, state,
                        (qc.transpose(1, 0, 2, 3), kc.transpose(1, 0, 2, 3),
                         vc.transpose(1, 0, 2, 3), wc.transpose(1, 0, 2, 3)))
    o = o.transpose(1, 0, 2, 3).reshape(BH, T, dv)
    return o.astype(q.dtype), S


def linattn(q, k, v, w, u, state=None, chunk: int = 64,
            force_kernel: bool = False):
    """RWKV6 gated linear attention over a sequence. Returns (o, S_out)."""
    if state is None and (_on_tpu() or force_kernel):
        return _la.linattn_chunked(q, k, v, w, u, chunk=chunk,
                                   interpret=not _on_tpu())
    return linattn_chunked_jnp(q, k, v, w, u, state=state, chunk=chunk)


def linattn_step(q, k, v, w, u, state):
    """Single-token decode update.

    q,k,w: (BH, dk); v: (BH, dv); u: (dk,) or (BH, dk);
    state: (BH, dk, dv) f32. Returns (o: (BH, dv), new_state)."""
    qf, kf, vf, wf = (x.astype(jnp.float32) for x in (q, k, v, w))
    uf = jnp.broadcast_to(u, q.shape).astype(jnp.float32)
    bonus = jnp.sum(qf * uf * kf, axis=-1, keepdims=True)      # (BH, 1)
    o = jnp.einsum("bd,bdv->bv", qf, state) + bonus * vf
    new_state = wf[..., None] * state + kf[..., None] * vf[:, None, :]
    return o.astype(q.dtype), new_state
