"""Pallas TPU kernels: feature-row gather and fused gather+aggregate.

These are the compute hot-spots of LeapGNN's data path (DESIGN.md §2):

* ``gather_rows``  — workspace row gather ``out[i] = table[idx[i]]``; the
  inner op of pre-gathering (§5.2) and of every tree-block feature load.
* ``gather_agg``   — fused neighbor gather + segment reduction over the
  fixed-fanout axis, replacing DGL's SpMM. On GPU this is a scatter-based
  sparse kernel; the TPU-native re-expression uses the *regular* (n, f)
  neighbor-index matrix: each grid step DMAs one feature row (sublane-
  aligned) from the table and accumulates into the output block resident in
  VMEM — no atomics (TPU has none), no scatter, MXU-friendly d-tiles.

Both use ``PrefetchScalarGridSpec``: the index matrix is scalar-prefetched
into SMEM so the BlockSpec ``index_map`` can steer each grid step's DMA to
the dynamically-selected table row — the canonical Pallas-TPU gather
pattern. Feature dim is tiled at 128 lanes (MXU/VPU width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128  # TPU lane width; feature tiles are multiples of this


def _dblk(d: int) -> int:
    """Feature-dim block for a dim that needs no split: one lane tile when
    the dim divides evenly, whole dim when it fits inside one tile."""
    return LANE if d % LANE == 0 else d


def _dim_splits(d: int) -> list[tuple[int, int, int]]:
    """Partition the feature dim into lane-tileable column ranges.

    Returns ``[(offset, width, block)]``. A dim that divides by LANE (or
    fits in one tile) is a single range; d > LANE with a remainder tiles
    the first ``d // LANE * LANE`` lanes at LANE and the tail as one
    sub-lane block — instead of the old whole-dim fallback, which put the
    entire (possibly multi-thousand-column) row in one VMEM block and lost
    lane alignment on all of it.
    """
    if d % LANE == 0 or d < LANE:
        return [(0, d, _dblk(d))]
    main = d // LANE * LANE
    return [(0, main, LANE), (main, d - main, d - main)]


# ---------------------------------------------------------------------------
# gather_rows: out[i] = table[idx[i]]
# ---------------------------------------------------------------------------

def _gather_rows_kernel(idx_ref, table_ref, out_ref):
    # table_ref block = (1, dblk) row slice steered by index_map; copy out.
    out_ref[...] = table_ref[...]


def _gather_rows_call(table: jnp.ndarray, idx: jnp.ndarray, dblk: int,
                      interpret: bool) -> jnp.ndarray:
    n = idx.shape[0]
    d = table.shape[1]
    return pl.pallas_call(
        _gather_rows_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n, d // dblk),
            in_specs=[
                pl.BlockSpec((1, dblk), lambda i, j, idx_ref: (idx_ref[i], j)),
            ],
            out_specs=pl.BlockSpec((1, dblk), lambda i, j, idx_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx, table)


def gather_rows(table: jnp.ndarray, idx: jnp.ndarray,
                interpret: bool = False) -> jnp.ndarray:
    """table: (R, d), idx: (n,) int32 -> (n, d)."""
    d = table.shape[1]
    parts = [_gather_rows_call(table[:, off:off + w], idx, blk, interpret)
             for off, w, blk in _dim_splits(d)]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


# ---------------------------------------------------------------------------
# gather_agg: out[i] = reduce_j table[idx[i, j]]
# ---------------------------------------------------------------------------

def _gather_agg_kernel(idx_ref, table_ref, out_ref, *, fanout: int,
                       reduce: str):
    j = pl.program_id(1)  # fanout position (innermost revisits out block)
    row = table_ref[...].astype(jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = row

    @pl.when(j > 0)
    def _acc():
        if reduce == "max":
            out_ref[...] = jnp.maximum(out_ref[...], row)
        else:
            out_ref[...] = out_ref[...] + row

    if reduce == "mean":
        @pl.when(j == fanout - 1)
        def _norm():
            out_ref[...] = out_ref[...] / fanout


def gather_agg(table: jnp.ndarray, idx: jnp.ndarray, reduce: str = "sum",
               interpret: bool = False) -> jnp.ndarray:
    """table: (R, d), idx: (n, f) int32 -> (n, d) reduced over f.

    Grid is (n, f, d_tiles); the output block (i, :) stays resident in VMEM
    across the f accumulation steps (TPU grids execute sequentially, so
    revisiting an output block is the supported accumulate idiom).
    """
    n, f = idx.shape
    d = table.shape[1]
    kern = functools.partial(_gather_agg_kernel, fanout=f, reduce=reduce)

    def call(table_part: jnp.ndarray, dblk: int) -> jnp.ndarray:
        dd = table_part.shape[1]
        return pl.pallas_call(
            kern,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(n, f, dd // dblk),
                in_specs=[
                    pl.BlockSpec((1, dblk),
                                 lambda i, j, t, idx_ref: (idx_ref[i, j], t)),
                ],
                out_specs=pl.BlockSpec((1, dblk),
                                       lambda i, j, t, idx_ref: (i, t)),
            ),
            out_shape=jax.ShapeDtypeStruct((n, dd), jnp.float32),
            interpret=interpret,
        )(idx, table_part)

    parts = [call(table[:, off:off + w], blk)
             for off, w, blk in _dim_splits(d)]
    out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return out.astype(table.dtype)
