"""Pure-jnp oracles for every Pallas kernel in this package.

Each function defines the *semantics* of the matching kernel; tests sweep
shapes/dtypes and assert the kernel (interpret=True on CPU) matches these
references to float tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_rows_ref(table: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """out[i] = table[idx[i]].  table: (R, d), idx: (n,) int32 -> (n, d)."""
    return jnp.take(table, idx, axis=0)


def gather_agg_ref(table: jnp.ndarray, idx: jnp.ndarray,
                   reduce: str = "sum") -> jnp.ndarray:
    """Fused neighbor gather + aggregate (DGL's SpMM on the fixed-fanout
    tree layout). table: (R, d), idx: (n, f) -> (n, d)."""
    rows = jnp.take(table, idx.reshape(-1), axis=0)
    rows = rows.reshape(idx.shape[0], idx.shape[1], table.shape[1])
    if reduce == "sum":
        return rows.sum(axis=1)
    if reduce == "mean":
        return rows.mean(axis=1)
    if reduce == "max":
        return rows.max(axis=1)
    raise ValueError(reduce)


def linattn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                w: jnp.ndarray, u: jnp.ndarray,
                state: jnp.ndarray | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """RWKV6-style gated linear attention, token-by-token scan.

    Per (batch·head): with S ∈ (dk, dv), for t = 1..T
        o_t = q_t · S  +  (q_t ⊙ u) · k_t) v_t          (bonus current token)
        S   = diag(w_t) S + k_t ⊗ v_t                   (data-dependent decay)

    Shapes: q,k,w: (BH, T, dk); v: (BH, T, dv); u: (dk,) or (BH, dk)
    (per-head bonus); state: (BH, dk, dv) or None (zeros).
    Returns (o: (BH, T, dv), S_out).
    """
    BH, T, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((BH, dk, dv), jnp.float32)
    u2 = jnp.broadcast_to(u, (BH, dk)).astype(jnp.float32)

    def step(S, qkvw, ub):
        qt, kt, vt, wt = qkvw
        o = qt @ S + ((qt * ub) * kt).sum() * vt
        S = wt[:, None] * S + kt[:, None] * vt[None, :]
        return S, o

    def per_bh(S0, q1, k1, v1, w1, ub):
        S, o = jax.lax.scan(
            lambda S, x: step(S, x, ub), S0,
            (q1.astype(jnp.float32), k1.astype(jnp.float32),
             v1.astype(jnp.float32), w1.astype(jnp.float32)))
        return o, S

    o, S = jax.vmap(per_bh)(state, q, k, v, w, u2)
    return o.astype(q.dtype), S
