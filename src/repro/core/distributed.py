"""Device engine: execute an IterationPlan under SPMD.

The per-iteration computation is written once against an abstract ``Comm``
interface with two implementations:

* ``ShardComm``   — real collectives (``lax.all_to_all`` / ``psum``) inside
  ``shard_map`` over the mesh's ``"data"`` axis. Used by the launcher, the
  multi-device integration tests, and the dry-run.
* ``EmulatedComm``— the same exchange as pure gathers over globally-stacked
  arrays on a single device. Bit-identical numerics, used by unit tests and
  the CPU benchmark harness (1-core container).

The feature exchange is LeapGNN's pre-gathering (§5.2; the paper titles the
system "HopGNN" but names it LeapGNN in the text) mapped to TPU: one
all_to_all carries the (deduplicated) request indices, a second carries the
feature rows back — the SPMD analogue of the paper's batched gRPC fetch.
Training then scans the iteration's time steps (§5.1), accumulating
gradients, and ends with a single data-parallel gradient reduction.

Remote-feature cache (repro.cache): every iteration body takes a
``(N, c_max, d)`` cache table next to the feature table; the per-shard
workspace is assembled as ``[local | cached | fetched]`` rows, matching the
planner's slot layout. ``c_max = 0`` (the default when no cache is passed)
degenerates to the original two-region workspace.

Per-step collectives: the T index requests ship in ONE batched all_to_all
hoisted ahead of the time-step scan (PR 2). When ``T·r_max`` fits
:data:`FOLD_RETURNS_MAX_TR`, the T feature *returns* are folded into one
batched collective too (``serve_features_batched``): per-step mode then
runs exactly 2 all_to_alls per iteration — the same count as pregather
mode — at the cost of a ``(T, P, r_max, d)`` staging buffer, which is what
the budget flag gates.

Compile-once contract: jitted callables are built once per
``(cfg, pregather, fold_returns, mesh, axis)`` by
:func:`get_compiled_iteration` and reused by every ``run_iteration`` call;
the true global batch size is a *traced* scalar (``denom``), so varying
true batch sizes never retrace. Each (re)trace is appended to a
module-level trace log, which the repro.train Trainer and the regression
tests use to assert the compile-once invariant.

Fused train step (async pipeline, repro.train.pipeline): next to the
grads-returning iteration there is a fused program
``fn(params, opt_state, table, cache, dev, denom) ->
(params', opt_state', loss)`` (:func:`get_compiled_train_step`) that folds
the optimizer update into the same XLA program with buffer donation for
``params``/``opt_state`` — one dispatch per iteration instead of a grads
round-trip plus tens of eager optimizer ops. **Donation contract:** the
caller's ``params``/``opt_state`` buffers are consumed by the call; thread
the returned trees forward and never reuse the inputs. A ``stacked=True``
variant scans the fused step over K same-bucket iterations stacked on a
leading axis, amortizing dispatch when per-iteration device time is tiny.

Argument fast path: :func:`prepare_iteration_args` uploads only host-side
leaves — device-resident tables/caches pass through untouched, and a plan
whose device args were pre-committed by the pipeline uploader
(``plan.committed``) skips the per-leaf conversion walk entirely.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.gnn.models import GNNConfig, gnn_forward, gnn_loss
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace


def _shard_map(body, mesh, in_specs, out_specs):
    """Version-compatible shard_map: jax.shard_map(check_vma=...) moved from
    jax.experimental.shard_map.shard_map(check_rep=...)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_zeros_like(t):
    return jax.tree.map(jnp.zeros_like, t)


# ---------------------------------------------------------------------------
# Comm backends
# ---------------------------------------------------------------------------

class ShardComm:
    """Real collectives; valid only inside shard_map over ``axis``."""

    def __init__(self, axis: str = "data"):
        self.axis = axis

    def exchange_indices(self, req: jnp.ndarray) -> jnp.ndarray:
        """req: (P, r_max) peer-local indices I want. Returns (P, r_max):
        row p = indices peer p wants from me."""
        return jax.lax.all_to_all(req, self.axis, split_axis=0,
                                  concat_axis=0, tiled=True)

    def exchange_indices_batched(self, step_req: jnp.ndarray) -> jnp.ndarray:
        """step_req: (T, P, r_max) — all T per-step index requests in ONE
        all_to_all (split/concat over the peer axis). Returns (T, P, r_max):
        ``out[t, p]`` = indices peer p wants from me at step t. Hoisting
        this ahead of the time-step scan halves the per-step collective
        count: the scan body only ships features back (T+1 all_to_alls per
        iteration instead of 2T)."""
        return jax.lax.all_to_all(step_req, self.axis, split_axis=1,
                                  concat_axis=1, tiled=True)

    def serve_features(self, table: jnp.ndarray,
                       incoming: jnp.ndarray) -> jnp.ndarray:
        """table: (local_rows, d); incoming: (P, r_max) indices each peer
        wants from me. Serves them from the local shard and ships features
        back; returns (P, r_max, d): row p = rows fetched from peer p."""
        served = jnp.take(table, incoming.reshape(-1), axis=0)
        served = served.reshape(incoming.shape[0], incoming.shape[1], -1)
        return jax.lax.all_to_all(served, self.axis, split_axis=0,
                                  concat_axis=0, tiled=True)

    def serve_features_batched(self, table: jnp.ndarray,
                               incoming: jnp.ndarray) -> jnp.ndarray:
        """Fold all T feature returns into ONE all_to_all.

        incoming: (T, P, r_max) server-view indices (the output of
        :meth:`exchange_indices_batched`). Returns (T, P, r_max, d):
        ``out[t, p]`` = rows fetched from peer p for step t — each
        ``out[t]`` bit-identical to the per-step :meth:`serve_features`
        slice (same gather, same exchange, only batched). With the batched
        index exchange this brings per-step mode to exactly 2 all_to_alls
        per iteration, paying a (T, P, r_max, d) staging buffer."""
        T, P, r = incoming.shape
        served = jnp.take(table, incoming.reshape(-1), axis=0)
        served = served.reshape(T, P, r, -1)
        return jax.lax.all_to_all(served, self.axis, split_axis=1,
                                  concat_axis=1, tiled=True)

    def exchange(self, table: jnp.ndarray, req: jnp.ndarray) -> jnp.ndarray:
        """table: (local_rows, d); req: (P, r_max) peer-local indices.
        Returns (P, r_max, d): row p = rows fetched from peer p."""
        return self.serve_features(table, self.exchange_indices(req))

    def grad_mean(self, grads, denom: float):
        return jax.tree.map(lambda g: jax.lax.psum(g, self.axis) / denom, grads)

    def mean_scalar(self, x):
        return jax.lax.pmean(x, self.axis)

    # -- membership hooks (repro.membership). On a real deployment the RPC
    # layer reports per-peer liveness; here a peer's death is registered
    # process-wide so every comm boundary sees the same world view.
    @staticmethod
    def kill(shard: int) -> None:
        kill_peer(shard)

    @staticmethod
    def revive(shard: int) -> None:
        revive_peer(shard)


class EmulatedComm:
    """Single-device emulation over globally-stacked arrays (leading N axis).

    ``exchange``/``grad_mean`` consume the stacked views; numerics match
    ShardComm exactly (pure data movement, no arithmetic reordering except
    the gradient sum, which is reduced in the same order)."""

    def exchange_global(self, table_g: jnp.ndarray, req_g: jnp.ndarray
                        ) -> jnp.ndarray:
        """table_g: (N, local_rows, d); req_g: (N, P, r_max).
        Returns (N, P, r_max, d): out[s, p] = table_g[p][req_g[s, p]]."""
        def per_peer(table_p, req_sp):   # (rows,d), (N,r_max)
            return jnp.take(table_p, req_sp, axis=0)          # (N, r_max, d)
        out = jax.vmap(per_peer, in_axes=(0, 1), out_axes=1)(table_g, req_g)
        return out

    def exchange_indices_batched_global(self, step_req_g: jnp.ndarray
                                        ) -> jnp.ndarray:
        """Emulated analogue of ShardComm.exchange_indices_batched.
        step_req_g: (N, T, P, r_max). Returns (N, T, P, r_max) in the
        *server* view: out[m, t, p] = step_req_g[p, t, m] — the indices
        peer p wants from shard m at step t. A pure transpose: on one
        device the index exchange is data movement only."""
        return jnp.transpose(step_req_g, (2, 1, 0, 3))

    def serve_step_global(self, table_g: jnp.ndarray, incoming_g: jnp.ndarray,
                          t, shard: int) -> jnp.ndarray:
        """Feature return for requesting ``shard`` at step ``t``.
        incoming_g: (N, T, P, r_max) server-view indices (see above).
        Returns (P, r_max, d): row p = table_g[p][incoming_g[p, t, shard]]
        — bit-identical to the per-step exchange_global slice."""
        idx = incoming_g[:, t, shard]                         # (P, r_max)
        def per_peer(table_p, idx_p):                         # (rows,d), (r,)
            return jnp.take(table_p, idx_p, axis=0)
        return jax.vmap(per_peer)(table_g, idx)               # (P, r_max, d)

    def serve_features_batched_global(self, table_g: jnp.ndarray,
                                      incoming_g: jnp.ndarray) -> jnp.ndarray:
        """Emulated analogue of ShardComm.serve_features_batched: all T
        feature returns for all shards at once. incoming_g: (N, T, P, r_max)
        server-view. Returns (N, T, P, r_max, d):
        ``out[s, t, p] = table_g[p][incoming_g[p, t, s]]`` — each [s, t]
        slice bit-identical to :meth:`serve_step_global`."""
        def per_peer(table_p, idx_p):      # (rows, d), (T, S, r)
            return jnp.take(table_p, idx_p, axis=0)           # (T, S, r, d)
        out = jax.vmap(per_peer)(table_g, incoming_g)         # (P, T, S, r, d)
        return jnp.transpose(out, (2, 1, 0, 3, 4))            # (S, T, P, r, d)

    def grad_mean_global(self, grads_g, denom: float):
        return jax.tree.map(lambda g: jnp.sum(g, axis=0) / denom, grads_g)

    # -- membership hooks: identical semantics to ShardComm's (the single
    # process stands in for the whole fabric, so both backends share the
    # module-level dead-peer registry).
    @staticmethod
    def kill(shard: int) -> None:
        kill_peer(shard)

    @staticmethod
    def revive(shard: int) -> None:
        revive_peer(shard)


# ---------------------------------------------------------------------------
# Per-shard iteration body (comm-free inner compute)
# ---------------------------------------------------------------------------

def _shard_grads(params, cfg: GNNConfig, workspace_fn: Callable,
                 hop_idx, labels, weights):
    """Scan the time steps of one shard, accumulating grads and loss.

    workspace_fn(t) -> (rows, d) feature workspace for step t (constant
    across steps in pregather mode). The per-hop feature gather is the
    Pallas ``gather_rows`` kernel on TPU (kernels/gather_agg.py) and
    ``jnp.take`` on CPU — dispatched by kernels.ops."""
    from repro.kernels import ops
    T = labels.shape[0]

    def loss_fn(p, ws, idxs, lab, w):
        feats = [ops.gather_rows(ws, i) for i in idxs]
        loss, logits = gnn_loss(p, cfg, feats, lab, weight=w)
        return loss, logits

    def step(carry, t):
        gacc, lacc = carry
        ws = workspace_fn(t)
        idxs = [h[t] for h in hop_idx]
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, ws, idxs, labels[t], weights[t])
        return (tree_add(gacc, g), lacc + loss), None

    init = (tree_zeros_like(params), jnp.zeros(()))
    (grads, loss_sum), _ = jax.lax.scan(step, init, jnp.arange(T))
    return grads, loss_sum


def _iteration_shard(params, table, cache, dev, cfg: GNNConfig,
                     pregather: bool, fold_returns: bool, denom,
                     comm: ShardComm):
    """Body run on every shard inside shard_map. ``dev`` = plan.device_args()
    with the leading shard axis already stripped. ``cache`` is the shard's
    (c_max, d) resident remote-feature rows (c_max = 0 when caching is off);
    the workspace is assembled as [local | cached | fetched], matching the
    planner's slot layout. ``denom`` is the true global batch size as a
    traced scalar (not static — see module doc)."""
    base = jnp.concatenate([table, cache], 0)     # [local | cached]
    d = table.shape[1]
    if pregather:
        recv = comm.exchange(table, dev["req"])            # (P, r_max, d)
        ws = jnp.concatenate([base, recv.reshape(-1, d)], 0)
        workspace_fn = lambda t: ws
    else:
        # All T index requests ship in one batched all_to_all before the
        # time-step scan; the scan body then only pays the feature-return
        # collective — T+1 all_to_alls per iteration instead of 2T. With
        # fold_returns the T returns also collapse into one pre-scan
        # collective: exactly 2 all_to_alls per iteration.
        incoming = comm.exchange_indices_batched(dev["step_req"])
        if fold_returns:
            recv_all = comm.serve_features_batched(table, incoming)
            def workspace_fn(t):
                return jnp.concatenate(
                    [base, recv_all[t].reshape(-1, d)], 0)
        else:
            def workspace_fn(t):
                recv = comm.serve_features(table, incoming[t])
                return jnp.concatenate([base, recv.reshape(-1, d)], 0)
    grads, loss_sum = _shard_grads(params, cfg, workspace_fn,
                                   dev["hop_idx"], dev["labels"], dev["weights"])
    grads = comm.grad_mean(grads, denom)
    loss = jax.lax.psum(loss_sum, comm.axis) / denom
    return grads, loss


# ---------------------------------------------------------------------------
# Compiled-fn cache + trace log (compile-once contract)
# ---------------------------------------------------------------------------

# (cfg, pregather, fold_returns, mesh, axis) -> jitted callable. jit's own
# cache then keys on argument shapes/dtypes, so one entry serves every shape
# bucket; a new bucket retraces exactly once and is recorded in the trace log.
_COMPILE_CACHE: dict = {}

# Fold the T per-step feature returns into one batched all_to_all when
# T·r_max is at most this many rows per peer (the staging buffer is
# (T, P, r_max, d) — the flag bounds its footprint). run_iteration's
# fold_returns=None consults this; pass an explicit bool to override.
FOLD_RETURNS_MAX_TR = 1 << 15

# Every jit (re)trace of an iteration body appends one record here. The
# append runs at *trace* time only, so executions of an already-compiled
# shape are invisible — exactly the signal the compile-once tests need.
_TRACE_LOG: list = []


def trace_count() -> int:
    """Number of iteration-body jit traces since process start / last reset."""
    return len(_TRACE_LOG)


def trace_log() -> tuple:
    """Immutable view of the trace records: (kind, model, pregather, shapes)."""
    return tuple(_TRACE_LOG)


def reset_trace_log() -> None:
    _TRACE_LOG.clear()


def clear_compile_cache() -> None:
    """Drop cached jitted callables (forces fresh traces — test isolation)."""
    _COMPILE_CACHE.clear()


def _shape_sig(tree) -> tuple:
    return tuple((tuple(x.shape), str(x.dtype)) for x in jax.tree.leaves(tree))


def _note_trace(kind: str, cfg: GNNConfig, pregather: bool, table, cache,
                dev):
    _TRACE_LOG.append((kind, cfg.model, bool(pregather),
                       tuple(table.shape), tuple(cache.shape),
                       _shape_sig(dev)))
    # telemetry (repro.obs): retraces after epoch 0 are defects the CI
    # gates watch for — surface them on the unified registry + timeline
    _obs_metrics.inc("engine.traces")
    _obs_trace.event("engine.retrace", kind=kind, model=cfg.model)


def get_compiled_iteration(cfg: GNNConfig, pregather: bool,
                           mesh: Optional[Mesh] = None, axis: str = "data",
                           fold_returns: bool = False,
                           streamed: bool = False):
    """Return the cached jitted iteration fn for this engine configuration.

    The callable's signature is ``fn(params, table, cache, dev, denom)``
    where ``cache`` is the (N, c_max, d) resident remote-feature table
    (c_max = 0 disables caching) and ``denom`` is the true global batch
    size as a float32 scalar. Building the callable is cheap; *tracing*
    happens lazily per argument-shape bucket inside jit and is what the
    trace log records. ``fold_returns`` only affects per-step mode.

    ``streamed`` (repro.features): the plan carries its own feature blocks
    (``feat_local``/``feat_fetch`` in ``dev``) gathered host-side through a
    tiered FeatureStore; ``table`` is the shared zero-width placeholder and
    NO feature collectives run — only the gradient reduction remains.
    """
    key = (cfg, bool(pregather), bool(fold_returns), mesh,
           axis if mesh is not None else None, bool(streamed))
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        fn = (_build_emulated(cfg, pregather, fold_returns, streamed)
              if mesh is None
              else _build_sharded(cfg, pregather, fold_returns, mesh, axis,
                                  streamed))
        _COMPILE_CACHE[key] = fn
    return fn


def get_compiled_inference(cfg: GNNConfig):
    """Cached jitted serving forward (repro.serve's device program).

    Signature ``fn(params, cache_tab, fetched, *hop_idx) -> logits`` where
    ``cache_tab`` is the serve cache's resident ``(c_max, d)`` hot rows
    (height 0 disables it), ``fetched`` the micro-batch's host-gathered
    ``(u_max, d)`` unique rows, and ``hop_idx[h]`` the
    ``(batch_pad · fanout^h,)`` int32 tree positions into the concatenated
    ``[cached | fetched]`` workspace. Lives in the same compile cache and
    trace log as the training programs (kind ``"infer"``), so the serving
    zero-retraces-after-warmup gate reads the exact signal the training
    compile-once tests do.
    """
    key = ("infer", cfg)
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        from repro.kernels import ops

        def infer(params, cache_tab, fetched, *hop_idx):
            _note_trace("infer", cfg, True, fetched, cache_tab,
                        list(hop_idx))
            ws = jnp.concatenate([cache_tab, fetched], 0)
            feats = [ops.gather_rows(ws, i) for i in hop_idx]
            return gnn_forward(params, cfg, feats)

        fn = jax.jit(infer)
        _COMPILE_CACHE[key] = fn
    return fn


def infer_trace_count() -> int:
    """Traces of the serving forward alone (kind ``"infer"`` records)."""
    return sum(1 for r in _TRACE_LOG if r[0] == "infer")


def optimizer_cache_key(optimizer) -> tuple:
    """Stable compile-cache identity for an optimizer: its declared value
    ``key`` when it has one (two ``adam(5e-3)`` instances then share one
    compiled program), else the instance id — safe because the cached
    callable closes over the optimizer and keeps it alive, so the id can
    never be recycled while the entry exists. Flip side: an id-keyed entry
    (schedule lr without an explicit ``key=``) pins its compiled program
    for the process lifetime — long-running sweeps over many schedule
    optimizers should pass ``key=`` (see repro.optim.adamw)."""
    key = getattr(optimizer, "key", None)
    return key if key is not None else ("optimizer-id", id(optimizer))


def get_compiled_train_step(cfg: GNNConfig, pregather: bool, optimizer,
                            mesh: Optional[Mesh] = None, axis: str = "data",
                            fold_returns: bool = False,
                            stacked: bool = False,
                            streamed: bool = False):
    """Cached *fused* train step: iteration + optimizer update, one program.

    Signature ``fn(params, opt_state, table, cache, dev, denom) ->
    (params', opt_state', loss)`` with ``params``/``opt_state`` **donated**
    (the input buffers are consumed — thread the outputs forward, never
    reuse the inputs). With ``stacked=True`` the signature takes a K-stacked
    device-arg tree and a ``(K,)`` denom vector and ``lax.scan``s the fused
    step over the K iterations, returning ``(K,)`` losses — one dispatch
    for K iterations. jit's shape cache keys on K, so different stack
    widths coexist without rebuilding."""
    key = ("fused", cfg, bool(pregather), bool(fold_returns), mesh,
           axis if mesh is not None else None, optimizer_cache_key(optimizer),
           bool(stacked), bool(streamed))
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        fn = _build_fused(cfg, pregather, fold_returns, mesh, axis,
                          optimizer, stacked, streamed)
        _COMPILE_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def resolve_fold_returns(plan, fold_returns: Optional[bool] = None) -> bool:
    """Auto-fold policy: fold the per-step feature returns when the staging
    buffer is small enough (T·r_max ≤ FOLD_RETURNS_MAX_TR). Explicit bools
    pass through; pregather mode never folds (nothing to fold)."""
    if plan.pregather:
        return False
    if fold_returns is not None:
        return bool(fold_returns)
    return plan.num_steps * plan.r_max <= FOLD_RETURNS_MAX_TR


def _as_device(x):
    """Upload only host-side leaves: device-resident arrays pass through
    untouched (no per-leaf re-wrap on the hot path)."""
    return x if isinstance(x, jax.Array) else jnp.asarray(x)


# Host comm boundary hook (repro.resilience). In a multi-host deployment
# each all_to_all is an RPC fan-out that can stall or drop; in this harness
# the host-side point where an iteration's exchanges are initiated is the
# dispatch that stages their arguments. A fault/robustness layer installs a
# callable here; it runs BEFORE any compiled program is invoked (and thus
# before any params/opt_state buffer donation), so a raise from the hook is
# always safe to retry. None (the default) costs one global read.
_COMM_FAULT_HOOK: Optional[Callable] = None


def set_comm_fault_hook(hook: Optional[Callable]) -> None:
    """Install/remove the host comm-boundary hook (``hook(plan)``)."""
    global _COMM_FAULT_HOOK
    _COMM_FAULT_HOOK = hook


# Dead-peer registry (repro.membership). On a real multi-host deployment
# liveness comes from the RPC layer (a peer's channel errors out); in this
# single-process harness a death is registered here — by the `peer_death`
# fault kind, a membership test, or a comm backend's .kill() hook — and
# every subsequent dispatch that would contact the fabric raises
# PeerDeadError from the host staging boundary. The raise is pre-donation
# (safe to retry) and persistent (the peer stays dead until revive_peer),
# so a guarded caller's retries exhaust into the detector's CommTimeout
# with the peer attributed — exactly the signal repro.membership consumes.
_DEAD_PEERS: set = set()


class PeerDeadError(RuntimeError):
    """An exchange addressed a peer registered as dead.

    Typed transient for the retry guard (repro.resilience.comm retries it
    alongside TransientCommError): the *probe* decides permanence, not the
    raise — a flapping peer that comes back mid-retry is absorbed with no
    membership change."""

    def __init__(self, msg: str, *, peer: int = -1):
        super().__init__(msg)
        self.site = "comm"
        self.peer = int(peer)


def kill_peer(shard: int) -> None:
    """Register ``shard`` as dead; every later dispatch fails until
    :func:`revive_peer`."""
    _DEAD_PEERS.add(int(shard))


def revive_peer(shard: int) -> None:
    _DEAD_PEERS.discard(int(shard))


def peer_is_dead(shard: int) -> bool:
    return int(shard) in _DEAD_PEERS


def dead_peers() -> frozenset:
    return frozenset(_DEAD_PEERS)


def comm_fault_point(plan) -> None:
    """Run the comm-boundary hook for one iteration dispatch (pre-donation).
    Called by :func:`prepare_iteration_args` and the stacked dispatch.

    The hook runs first (a scheduled ``peer_death`` fault registers the
    kill here), then the dead-peer registry is consulted: a dispatch stages
    exchanges with *every* peer, so any registered death fails the staging
    with the peer attributed."""
    hook = _COMM_FAULT_HOOK
    if hook is not None:
        hook(plan)
    if _DEAD_PEERS:
        peer = min(_DEAD_PEERS)
        ei = getattr(plan, "epoch_it", (-1, -1))
        raise PeerDeadError(
            f"peer shard {peer} is dead at (epoch {ei[0]}, it {ei[1]}); "
            "exchange fan-out cannot be staged", peer=peer)


# (num_shards, feature_dim, dtype) -> (N, 0, d) device zeros. Cache-off
# iterations all share one zero-width cache table instead of allocating a
# fresh one per call (part of the per-iteration host overhead PR 5 removes).
_EMPTY_CACHE: dict = {}


def empty_cache_table(num_shards: int, feature_dim: int, dtype=np.float32):
    key = (int(num_shards), int(feature_dim), np.dtype(dtype).str)
    tab = _EMPTY_CACHE.get(key)
    if tab is None:
        tab = jnp.zeros((key[0], 0, key[1]), key[2])
        _EMPTY_CACHE[key] = tab
    return tab


def prepare_iteration_args(table_global, plan, cache=None):
    """Shared argument prep for :func:`run_iteration` /
    :func:`run_train_step`: validates the cache against the plan and
    returns device-ready ``(table, cache, dev, denom)``.

    Fast paths: device-resident inputs are passed through untouched; a plan
    whose device args were pre-committed by the pipeline uploader
    (``plan.committed``, see repro.train.pipeline) skips the conversion
    walk entirely — the upload already happened off the critical path.

    Streamed plans (repro.features): no resident table exists —
    ``table_global=None`` is replaced by the shared zero-width placeholder
    (the plan's feature blocks ride in ``dev``)."""
    comm_fault_point(plan)
    if table_global is None:
        if not getattr(plan, "streamed", False):
            raise ValueError("table_global=None is only valid for streamed "
                             "plans (tiered FeatureStore)")
        fl = plan.feat_local
        table_global = empty_cache_table(plan.num_shards, fl.shape[-1],
                                         fl.dtype)
    table_global = _as_device(table_global)
    if cache is None:
        if plan.c_max:
            raise ValueError(
                f"plan was built against a cache (c_max={plan.c_max}) "
                "but no cache table was passed")
        cache = empty_cache_table(table_global.shape[0],
                                  table_global.shape[-1], table_global.dtype)
    else:
        cache = _as_device(cache)
        if int(cache.shape[1]) != int(plan.c_max):
            raise ValueError(
                f"cache table height {cache.shape[1]} != plan c_max "
                f"{plan.c_max} (stale cache?)")
    committed = getattr(plan, "committed", None)
    if committed is not None:
        dev, denom = committed["dev"], committed["denom"]
    else:
        dev = jax.tree.map(_as_device, plan.device_args())
        denom = jnp.asarray(float(plan.global_batch), jnp.float32)
    return table_global, cache, dev, denom


def run_iteration(params, table_global, plan, cfg: GNNConfig,
                  mesh: Optional[Mesh] = None, cache=None,
                  fold_returns: Optional[bool] = None):
    """Execute one planned iteration.

    With a ``mesh`` (data axis length == plan.num_shards): shard_map with
    real collectives. Without: single-device emulation (same numerics).
    ``cache`` is the (N, c_max, d) device-resident remote-feature table a
    cache-aware plan was built against (required iff plan.c_max > 0; its
    height must match the plan's). ``fold_returns=None`` applies the
    :data:`FOLD_RETURNS_MAX_TR` auto policy in per-step mode.
    Returns (grads, mean_loss) — optimizer application is the caller's
    (training loop / train_step fusion decide placement; see
    :func:`run_train_step` for the fused variant).

    The jitted callable comes from the module-level compile cache: repeated
    calls with plans of the same device shapes reuse one compiled program.
    """
    table_global, cache, dev, denom = prepare_iteration_args(
        table_global, plan, cache)
    fn = get_compiled_iteration(cfg, plan.pregather, mesh=mesh,
                                fold_returns=resolve_fold_returns(
                                    plan, fold_returns),
                                streamed=bool(getattr(plan, "streamed",
                                                      False)))
    return fn(params, table_global, cache, dev, denom)


def run_train_step(params, opt_state, table_global, plan, cfg: GNNConfig,
                   optimizer, mesh: Optional[Mesh] = None, cache=None,
                   fold_returns: Optional[bool] = None):
    """Execute one planned iteration *and* the optimizer update as a single
    fused dispatch. Returns ``(params', opt_state', loss)``.

    Donation contract: ``params`` and ``opt_state`` buffers are donated to
    the program — the inputs are invalid after the call; always continue
    from the returned trees. The loss stays on device (no host sync); call
    ``float(loss)`` only when you actually need the value.
    """
    table_global, cache, dev, denom = prepare_iteration_args(
        table_global, plan, cache)
    fn = get_compiled_train_step(cfg, plan.pregather, optimizer, mesh=mesh,
                                 fold_returns=resolve_fold_returns(
                                     plan, fold_returns),
                                 streamed=bool(getattr(plan, "streamed",
                                                       False)))
    return fn(params, opt_state, table_global, cache, dev, denom)


def make_sharded_iteration(cfg: GNNConfig, pregather: bool, mesh: Mesh,
                           axis: str = "data", fold_returns: bool = False):
    """jit-compiled shard_map iteration ``fn(params, table, cache, dev,
    denom)`` for repeated use by the train loop (cached per config)."""
    return get_compiled_iteration(cfg, pregather, mesh=mesh, axis=axis,
                                  fold_returns=fold_returns)


def _grads_callable(cfg: GNNConfig, pregather: bool, fold_returns: bool,
                    mesh: Optional[Mesh], axis: str, kind: str,
                    streamed: bool = False):
    """Unjitted ``(params, table, cache, dev, denom) -> (grads, loss)``
    callable — the shared core the plain-iteration, fused, and stacked
    builders all wrap. ``kind`` labels the trace-log records."""
    if mesh is None:
        def fn(params, table_g, cache_g, dev, denom):
            _note_trace(kind, cfg, pregather, table_g, cache_g, dev)
            if streamed:
                return _emulated_streamed_iteration(params, cache_g, dev,
                                                    denom, cfg)
            return _emulated_iteration(params, table_g, cache_g, dev, denom,
                                       cfg, pregather, fold_returns)
        return fn

    comm = ShardComm(axis)

    def body(params, table, cache, dev, denom):
        _note_trace(kind, cfg, pregather, table, cache, dev)
        # shard_map passes per-shard views with the shard axis kept (size 1)
        table = table[0]
        cache = cache[0]
        dev = jax.tree.map(lambda x: x[0], dev)
        if streamed:
            grads, loss = _streamed_shard(params, cache, dev, cfg, denom,
                                          comm)
        else:
            grads, loss = _iteration_shard(params, table, cache, dev, cfg,
                                           pregather, fold_returns, denom,
                                           comm)
        return grads, loss

    return _shard_map(body, mesh, (P(), P(axis), P(axis), P(axis), P()),
                      (P(), P()))


def _streamed_shard(params, cache, dev, cfg: GNNConfig, denom,
                    comm: ShardComm):
    """Streamed-mode shard body: the workspace is assembled entirely from
    plan-carried feature blocks — ``[local_compact | cached | fetched]`` —
    so no feature collective runs; only the gradient psum remains."""
    d = dev["feat_local"].shape[-1]
    ws = jnp.concatenate([dev["feat_local"], cache,
                          dev["feat_fetch"].reshape(-1, d)], 0)
    grads, loss_sum = _shard_grads(params, cfg, lambda t: ws,
                                   dev["hop_idx"], dev["labels"],
                                   dev["weights"])
    grads = comm.grad_mean(grads, denom)
    loss = jax.lax.psum(loss_sum, comm.axis) / denom
    return grads, loss


def _build_sharded(cfg: GNNConfig, pregather: bool, fold_returns: bool,
                   mesh: Mesh, axis: str, streamed: bool = False):
    return jax.jit(_grads_callable(cfg, pregather, fold_returns, mesh, axis,
                                   "sharded", streamed))


def _build_fused(cfg: GNNConfig, pregather: bool, fold_returns: bool,
                 mesh: Optional[Mesh], axis: str, optimizer, stacked: bool,
                 streamed: bool = False):
    """Fused iteration + optimizer update (optionally scanned over a
    K-stack of same-shape iterations), with params/opt_state donation."""
    kind = (("emulated" if mesh is None else "sharded") + "-fused"
            + ("-stacked" if stacked else ""))
    grads_fn = _grads_callable(cfg, pregather, fold_returns, mesh, axis, kind,
                               streamed)

    if not stacked:
        def step(params, opt_state, table, cache, dev, denom):
            grads, loss = grads_fn(params, table, cache, dev, denom)
            new_params, new_state = optimizer.update(grads, opt_state, params)
            return new_params, new_state, loss

        return jax.jit(step, donate_argnums=(0, 1))

    def steps(params, opt_state, table, cache, dev_stack, denoms):
        def body(carry, x):
            p, s = carry
            dev, denom = x
            grads, loss = grads_fn(p, table, cache, dev, denom)
            p2, s2 = optimizer.update(grads, s, p)
            return (p2, s2), loss

        (p, s), losses = jax.lax.scan(body, (params, opt_state),
                                      (dev_stack, denoms))
        return p, s, losses

    return jax.jit(steps, donate_argnums=(0, 1))


def collective_counts(fn, *args) -> dict:
    """Count collective *executions* in one call of ``fn(*args)``.

    Traces ``fn`` to a jaxpr and walks it recursively, multiplying any
    collective found inside a ``scan`` body by the scan trip count — so an
    all_to_all inside the time-step loop counts T times, one hoisted ahead
    of it counts once. This is the acceptance metric for the batched
    per-step exchange: unfolded per-step mode must run exactly T+1
    all_to_alls per iteration (T feature returns + 1 batched index
    exchange), folded per-step mode and pregather mode exactly 2.
    """
    closed = jax.make_jaxpr(fn)(*args)
    counts: dict = {}
    _count_collectives(closed.jaxpr, 1, counts)
    return counts


_COLLECTIVE_PRIMS = ("all_to_all", "psum", "pmean", "all_gather",
                     "reduce_scatter", "ppermute")


def _count_collectives(jaxpr, mult: int, counts: dict) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _COLLECTIVE_PRIMS:
            counts[name] = counts.get(name, 0) + mult
        sub_mult = mult * int(eqn.params["length"]) if name == "scan" else mult
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                _count_collectives(sub, sub_mult, counts)


def _subjaxprs(v):
    from jax.extend import core as jex_core  # jax.core aliases, 0.4-compat
    ClosedJaxpr = getattr(jex_core, "ClosedJaxpr", None) or jax.core.ClosedJaxpr
    Jaxpr = getattr(jex_core, "Jaxpr", None) or jax.core.Jaxpr
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for w in v:
            yield from _subjaxprs(w)


def _build_emulated(cfg: GNNConfig, pregather: bool, fold_returns: bool,
                    streamed: bool = False):
    return jax.jit(_grads_callable(cfg, pregather, fold_returns, None,
                                   "data", "emulated", streamed))


def _emulated_streamed_iteration(params, cache_g, dev, denom,
                                 cfg: GNNConfig):
    """Single-device streamed emulation: per-shard workspaces come straight
    from the plan's feature blocks (no table, no exchange). Feature values
    per tree position equal the resident path's exactly — only the slot
    numbering differs — so grads/losses are bit-identical to it."""
    ecomm = EmulatedComm()
    n = dev["labels"].shape[0]
    d = dev["feat_local"].shape[-1]
    per_shard = []
    for s in range(n):
        ws = jnp.concatenate([dev["feat_local"][s], cache_g[s],
                              dev["feat_fetch"][s].reshape(-1, d)], 0)
        hop_idx = [h[s] for h in dev["hop_idx"]]
        g, l = _shard_grads(params, cfg, lambda t, ws=ws: ws, hop_idx,
                            dev["labels"][s], dev["weights"][s])
        per_shard.append((g, l))
    grads_g = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *[g for g, _ in per_shard])
    grads = ecomm.grad_mean_global(grads_g, denom)
    loss = sum(l for _, l in per_shard) / denom
    return grads, loss


def _emulated_iteration(params, table_g, cache_g, dev, denom, cfg: GNNConfig,
                        pregather: bool, fold_returns: bool):
    """Single-device emulation: python-loop over shards, explicit exchange."""
    ecomm = EmulatedComm()
    n = table_g.shape[0]
    d = table_g.shape[-1]
    if pregather:
        recv_g = ecomm.exchange_global(table_g, dev["req"])   # (N,P,r,d)
    else:
        # index exchange hoisted ahead of the scan, mirroring ShardComm's
        # batched collective (here a pure transpose — same data movement)
        incoming_g = ecomm.exchange_indices_batched_global(dev["step_req"])
        if fold_returns:
            recv_all_g = ecomm.serve_features_batched_global(table_g,
                                                             incoming_g)
    per_shard = []
    for s in range(n):
        base = jnp.concatenate([table_g[s], cache_g[s]], 0)  # [local|cached]
        if pregather:
            ws = jnp.concatenate([base, recv_g[s].reshape(-1, d)], 0)
            workspace_fn = lambda t, ws=ws: ws
        elif fold_returns:
            def workspace_fn(t, s=s, base=base):
                return jnp.concatenate(
                    [base, recv_all_g[s, t].reshape(-1, d)], 0)
        else:
            def workspace_fn(t, s=s, base=base):
                recv = ecomm.serve_step_global(table_g, incoming_g, t, s)
                return jnp.concatenate([base, recv.reshape(-1, d)], 0)
        hop_idx = [h[s] for h in dev["hop_idx"]]
        g, l = _shard_grads(params, cfg, workspace_fn, hop_idx,
                            dev["labels"][s], dev["weights"][s])
        per_shard.append((g, l))
    grads_g = jax.tree.map(lambda *xs: jnp.stack(xs), *[g for g, _ in per_shard])
    grads = ecomm.grad_mean_global(grads_g, denom)
    loss = sum(l for _, l in per_shard) / denom
    return grads, loss
