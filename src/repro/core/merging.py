"""Micrograph merging (paper §5.3): adaptive time-step reduction.

Merging trades remote-fetch volume against per-step overhead (kernel
launches, synchronization). The controller reproduces the paper's algorithm:

* *Which*: rank time steps by total root count (the paper's proxy for
  Num_vertex, decided before sampling); pick ts_min.
* *How*:  redistribute each model's ts_min roots evenly over that model's
  remaining steps (Fig. 10), keeping per-model batch composition intact —
  the accuracy-fidelity invariant.
* *How many*: an examination period starting at epoch 2 — keep merging while
  the measured epoch time improves; then freeze the pattern.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Optional

import numpy as np

from repro.core.micrograph import AssignmentMatrix


def merge_min_step(amat: AssignmentMatrix,
                   ts_min: Optional[int] = None) -> AssignmentMatrix:
    """Fold the lightest time step into the remaining ones (one §5.3 round).

    Each model's groups at ts_min are split evenly across the model's other
    steps; the merged roots execute on the *hosting* server of the target
    step (locality loss is the cost the examination period measures).
    """
    if amat.num_steps <= 1:
        return amat
    counts = amat.root_counts().sum(axis=1)      # (T,)
    t_min = int(np.argmin(counts)) if ts_min is None else ts_min
    T = amat.num_steps

    # model -> its (server, roots) at t_min, and its target (server, step)s
    new_groups: dict = {}
    per_model_targets: dict[int, list[tuple[int, int]]] = {}
    for (s, t), gs in amat.groups.items():
        if t == t_min:
            continue
        nt = t if t < t_min else t - 1
        new_groups.setdefault((s, nt), []).extend(
            (d, r.copy()) for d, r in gs)
        for d, _ in gs:
            # Dedupe: a model with several groups at one (server, step) slot
            # (common after a previous merge round) must count that slot
            # once, or array_split over-weights it and skews the even
            # redistribution Fig. 10 requires.
            tgt = per_model_targets.setdefault(d, [])
            if (s, nt) not in tgt:
                tgt.append((s, nt))

    for (s, t), gs in amat.groups.items():
        if t != t_min:
            continue
        for d, roots in gs:
            targets = per_model_targets.get(d)
            if not targets:
                # model d only trained at t_min: keep it at step 0 on the
                # same server (degenerate but load-consistent).
                new_groups.setdefault((s, 0), []).append((d, roots.copy()))
                continue
            chunks = np.array_split(roots, len(targets))
            for (ts_s, ts_t), chunk in zip(targets, chunks):
                if chunk.size:
                    new_groups.setdefault((ts_s, ts_t), []).append((d, chunk))

    return AssignmentMatrix(num_shards=amat.num_shards, num_steps=T - 1,
                            groups=new_groups)


def merge_random_step(amat: AssignmentMatrix, rng: np.random.Generator
                      ) -> AssignmentMatrix:
    """RD baseline of §7.4: merge a uniformly random step (load-oblivious)."""
    t = int(rng.integers(0, amat.num_steps))
    return merge_min_step(amat, ts_min=t)


def fold_assignment(base: AssignmentMatrix, num_steps: int,
                    selector: str = "min",
                    rng: Optional[np.random.Generator] = None
                    ) -> AssignmentMatrix:
    """Fold ``base`` down to ``num_steps`` time steps by repeated merging.

    This is how a frozen merge *pattern* (a step count, decided once by the
    examination period) is applied to each epoch's fresh mini-batch
    assignment: the controller owns the depth, the per-iteration roots stay
    the model's own (accuracy fidelity)."""
    amat = base
    while amat.num_steps > max(1, num_steps):
        amat = (merge_min_step(amat) if selector == "min"
                else merge_random_step(amat, rng or np.random.default_rng(0)))
    return amat


@dataclasses.dataclass
class MergingController:
    """Epoch-level examination loop (§5.3 'How many').

    Call ``assignment_for_epoch()`` before each epoch and
    ``record_epoch_time(seconds)`` after it. From epoch 2 on, the controller
    proposes one more merge per epoch while measured time improves, then
    freezes.

    Timing signal: pass *steady-state* epoch time — device execution only,
    excluding host planning and (critically) XLA compilation. A merge
    changes the iteration's device shapes, so the first iteration after a
    pattern change retraces; feeding that wall time back in would measure
    the compiler, not the kernel-switch/sync overhead §5.3 trades against,
    and invert the signal. The repro.train Trainer computes the compile-free
    time via the distributed-engine trace log."""

    base: AssignmentMatrix
    selector: str = "min"          # "min" (paper) | "random" (RD baseline)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._current = self.base
        self._previous: Optional[AssignmentMatrix] = None
        self._times: list[float] = []
        self._frozen = False
        self.history: list[int] = [self.base.num_steps]

    @property
    def frozen(self) -> bool:
        return self._frozen

    @property
    def last_epoch_time(self) -> Optional[float]:
        """Most recent recorded epoch time (the examination baseline)."""
        return self._times[-1] if self._times else None

    @property
    def pattern_steps(self) -> int:
        """The merge pattern: how many time steps the controller currently
        folds the base rotation down to."""
        return self._current.num_steps

    def assignment_for_epoch(self) -> AssignmentMatrix:
        return self._current

    def apply_to(self, base: AssignmentMatrix) -> AssignmentMatrix:
        """Apply the current merge pattern to a *fresh* per-iteration
        assignment (new mini-batch, same fold depth)."""
        return fold_assignment(base, self.pattern_steps, self.selector,
                               self._rng)

    def restore(self, num_steps: int, frozen: bool,
                last_time: Optional[float] = None) -> None:
        """Resume from a checkpointed pattern.

        ``last_time`` re-seeds the examination baseline so the first
        post-resume epoch is compared against the pre-resume measurement
        (otherwise the controller would merge unconditionally). The revert
        target is reconstructed as the one-step-shallower fold, so a
        regression after resume can still undo the last merge."""
        self._current = fold_assignment(self.base, num_steps, self.selector,
                                        self._rng)
        self._previous = (fold_assignment(self.base, num_steps + 1,
                                          self.selector, self._rng)
                          if num_steps < self.base.num_steps else None)
        self._frozen = bool(frozen)
        self._times = [] if last_time is None else [float(last_time)]
        self.history.append(self._current.num_steps)

    def record_epoch_time(self, seconds: float) -> None:
        self._times.append(seconds)
        if self._frozen:
            return
        if len(self._times) >= 2 and self._times[-1] >= self._times[-2]:
            # regression: revert to the previous pattern and freeze (§5.3)
            if self._previous is not None:
                self._current = self._previous
            self._frozen = True
            self.history.append(self._current.num_steps)
            return
        if self._current.num_steps > 1:
            self._previous = self._current
            self._current = (merge_min_step(self._current)
                             if self.selector == "min"
                             else merge_random_step(self._current, self._rng))
            self.history.append(self._current.num_steps)
        else:
            self._frozen = True
