"""Micrograph merging (paper §5.3): adaptive time-step reduction.

Merging trades remote-fetch volume against per-step overhead (kernel
launches, synchronization). The controller reproduces the paper's algorithm:

* *Which*: rank time steps by total root count (the paper's proxy for
  Num_vertex, decided before sampling); pick ts_min.
* *How*:  redistribute each model's ts_min roots evenly over that model's
  remaining steps (Fig. 10), keeping per-model batch composition intact —
  the accuracy-fidelity invariant.
* *How many*: an examination period starting at epoch 2 — keep merging while
  the measured epoch time improves; then freeze the pattern.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Optional

import numpy as np

from repro.core.micrograph import AssignmentMatrix


def merge_min_step(amat: AssignmentMatrix,
                   ts_min: Optional[int] = None) -> AssignmentMatrix:
    """Fold the lightest time step into the remaining ones (one §5.3 round).

    Each model's groups at ts_min are split evenly across the model's other
    steps; the merged roots execute on the *hosting* server of the target
    step (locality loss is the cost the examination period measures).
    """
    if amat.num_steps <= 1:
        return amat
    counts = amat.root_counts().sum(axis=1)      # (T,)
    t_min = int(np.argmin(counts)) if ts_min is None else ts_min
    T = amat.num_steps

    # model -> its (server, roots) at t_min, and its target (server, step)s
    new_groups: dict = {}
    per_model_targets: dict[int, list[tuple[int, int]]] = {}
    for (s, t), gs in amat.groups.items():
        if t == t_min:
            continue
        nt = t if t < t_min else t - 1
        new_groups.setdefault((s, nt), []).extend(
            (d, r.copy()) for d, r in gs)
        for d, _ in gs:
            per_model_targets.setdefault(d, []).append((s, nt))

    for (s, t), gs in amat.groups.items():
        if t != t_min:
            continue
        for d, roots in gs:
            targets = per_model_targets.get(d)
            if not targets:
                # model d only trained at t_min: keep it at step 0 on the
                # same server (degenerate but load-consistent).
                new_groups.setdefault((s, 0), []).append((d, roots.copy()))
                continue
            chunks = np.array_split(roots, len(targets))
            for (ts_s, ts_t), chunk in zip(targets, chunks):
                if chunk.size:
                    new_groups.setdefault((ts_s, ts_t), []).append((d, chunk))

    return AssignmentMatrix(num_shards=amat.num_shards, num_steps=T - 1,
                            groups=new_groups)


def merge_random_step(amat: AssignmentMatrix, rng: np.random.Generator
                      ) -> AssignmentMatrix:
    """RD baseline of §7.4: merge a uniformly random step (load-oblivious)."""
    t = int(rng.integers(0, amat.num_steps))
    return merge_min_step(amat, ts_min=t)


@dataclasses.dataclass
class MergingController:
    """Epoch-level examination loop (§5.3 'How many').

    Call ``assignment_for_epoch()`` before each epoch and
    ``record_epoch_time(seconds)`` after it. From epoch 2 on, the controller
    proposes one more merge per epoch while measured time improves, then
    freezes."""

    base: AssignmentMatrix
    selector: str = "min"          # "min" (paper) | "random" (RD baseline)
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._current = self.base
        self._previous: Optional[AssignmentMatrix] = None
        self._times: list[float] = []
        self._frozen = False
        self.history: list[int] = [self.base.num_steps]

    @property
    def frozen(self) -> bool:
        return self._frozen

    def assignment_for_epoch(self) -> AssignmentMatrix:
        return self._current

    def record_epoch_time(self, seconds: float) -> None:
        self._times.append(seconds)
        if self._frozen:
            return
        if len(self._times) >= 2 and self._times[-1] >= self._times[-2]:
            # regression: revert to the previous pattern and freeze (§5.3)
            if self._previous is not None:
                self._current = self._previous
            self._frozen = True
            self.history.append(self._current.num_steps)
            return
        if self._current.num_steps > 1:
            self._previous = self._current
            self._current = (merge_min_step(self._current)
                             if self.selector == "min"
                             else merge_random_step(self._current, self._rng))
            self.history.append(self._current.num_steps)
        else:
            self._frozen = True
