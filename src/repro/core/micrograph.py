"""Micrograph construction and root-vertex redistribution (paper §4, §5.1).

An *assignment* maps every (server s, time step t) to the list of
(model d, roots) groups trained there. LeapGNN's rotation schedule places
model d on server (d + t) mod N at step t; merging (§5.3) later edits this
matrix. The planner consumes the assignment and emits device-ready index
arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (server, time_step) -> list of (model_id, roots int64 array)
Assignment = dict[tuple[int, int], list[tuple[int, np.ndarray]]]


@dataclasses.dataclass
class AssignmentMatrix:
    """Assignment plus its shape metadata."""

    num_shards: int
    num_steps: int
    groups: Assignment

    def roots_at(self, s: int, t: int) -> np.ndarray:
        gs = self.groups.get((s, t), [])
        return (np.concatenate([r for _, r in gs])
                if gs else np.zeros((0,), np.int64))

    def root_counts(self) -> np.ndarray:
        """(num_steps, num_shards) true root counts — the Num_vertex proxy
        the merging heuristic ranks time steps by (§5.3)."""
        c = np.zeros((self.num_steps, self.num_shards), np.int64)
        for (s, t), gs in self.groups.items():
            c[t, s] = sum(r.size for _, r in gs)
        return c

    def model_step_counts(self) -> np.ndarray:
        """(num_steps, num_models) root counts per model per step (Fig. 10b)."""
        n_models = self.num_shards
        c = np.zeros((self.num_steps, n_models), np.int64)
        for (_, t), gs in self.groups.items():
            for d, r in gs:
                c[t, d] += r.size
        return c


def model_centric_assignment(roots_per_model: list[np.ndarray]
                             ) -> AssignmentMatrix:
    """DGL-style: one step; model s trains its own mini-batch on server s."""
    n = len(roots_per_model)
    groups: Assignment = {(s, 0): [(s, np.asarray(roots_per_model[s], np.int64))]
                          for s in range(n)}
    return AssignmentMatrix(num_shards=n, num_steps=1, groups=groups)


def hopgnn_assignment(roots_per_model: list[np.ndarray], part: np.ndarray
                      ) -> AssignmentMatrix:
    """§5.1 steps 1–2: group each model's roots by home server; model d's
    group homed at server h is trained at time step t = (h - d) mod N
    (when model d, rotating as (d + t) mod N, visits h)."""
    n = len(roots_per_model)
    groups: Assignment = {}
    for d, roots in enumerate(roots_per_model):
        roots = np.asarray(roots, np.int64)
        home = part[roots]
        for h in range(n):
            sel = roots[home == h]
            if sel.size == 0:
                continue
            t = (h - d) % n
            groups.setdefault((h, t), []).append((d, sel))
    return AssignmentMatrix(num_shards=n, num_steps=n, groups=groups)


def lo_assignment(roots_per_model: list[np.ndarray], part: np.ndarray
                  ) -> AssignmentMatrix:
    """Locality-optimized baseline (§5.1 'Limitations', §7.9): every root is
    trained at its home server by that server's resident model, one step.
    Fast, but batch composition becomes locality-correlated → biased."""
    n = len(roots_per_model)
    all_roots = np.concatenate([np.asarray(r, np.int64) for r in roots_per_model])
    home = part[all_roots]
    groups: Assignment = {}
    for s in range(n):
        sel = all_roots[home == s]
        if sel.size:
            groups[(s, 0)] = [(s, sel)]
    return AssignmentMatrix(num_shards=n, num_steps=1, groups=groups)


def micrograph_locality_stats(blocks_hops: list[list[np.ndarray]],
                              part: np.ndarray) -> tuple[float, float]:
    """(R_micro-style local fraction, remote fraction) over tree blocks.

    Each root's subtree is scored against *that root's own* home server.
    The fixed-fanout layout makes the per-root slice rectangular: root i of
    a B-root block owns ``hops[h][i * f**h : (i+1) * f**h]``, so a
    multi-root block with mixed homes is no longer lumped under the first
    root's partition."""
    local = total = 0
    for hops in blocks_hops:
        roots = np.asarray(hops[0])
        b = roots.shape[0]
        if b == 0:
            continue
        homes = part[roots]
        for h in hops[1:]:
            per_root = h.size // b          # f**h vertices per root subtree
            local += int((part[h] == np.repeat(homes, per_root)).sum())
            total += h.size
    return (local / max(total, 1), 1.0 - local / max(total, 1))
