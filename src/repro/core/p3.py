"""Executable P³ baseline [Gandhi & Iyer, OSDI'21] — feature-dimension
model parallelism for the input layer, data parallelism above.

P³ hash-partitions the *feature dimension*: server p stores
``features[:, p·d/N:(p+1)·d/N]`` for every vertex, so raw features never
cross the network. The input layer runs model-parallel — each server
computes a partial first-layer output with its slice and the matching
*rows* of W₁, partials are summed across servers (one activation
all-reduce) — and the remaining layers run data-parallel on the (small)
hidden activations.

This module executes that schedule. Because a dim-sliced matmul summed over
slices equals the full matmul, P³'s gradients match model-centric training
to float tolerance — verified in tests (the same kind of placement-only
equivalence LeapGNN has). Supported models: gcn, sage, gat (input layer is
matmul-fronted; deepgcn/film normalize *pre-matmul* over the full feature
vector, which P³'s slicing cannot express without an extra all-gather —
the paper's own "P³ suits particular architectures" caveat, surfaced as
``P3Unsupported``).

Comm accounting mirrors core.comm_model.p3_bytes: hidden activations of
hops 0..k-1 cross the fabric (pull + gradient push), raw features never do.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.sampler import TreeBlock, sample_tree_block
from repro.graph.structs import CSRGraph
from repro.models.gnn.layers import LAYER_REGISTRY
from repro.models.gnn.models import GNNConfig

SUPPORTED = ("gcn", "sage", "gat")


class P3Unsupported(ValueError):
    pass


@dataclasses.dataclass
class P3Plan:
    """Per-model tree blocks with *global* vertex ids (P³ needs no
    owner/local mapping — every server holds every vertex's slice)."""

    blocks: list[TreeBlock]
    labels: list[np.ndarray]
    num_shards: int
    num_layers: int
    fanout: int
    hidden_dim: int

    def activation_bytes(self) -> int:
        """Hidden activations exchanged: hops 0..k-1 unique vertices per
        model, pull + push (×2), (N-1)/N remote share."""
        n = self.num_shards
        total = 0
        for blk in self.blocks:
            for h in range(self.num_layers):
                total += int(np.unique(blk.hops[h]).size)
        return int(2 * total * self.hidden_dim * 4 * (n - 1) / n)


def plan_p3(graph: CSRGraph, labels: np.ndarray,
            roots_per_model: Sequence[np.ndarray], num_layers: int,
            fanout: int, hidden_dim: int,
            sample_seed: int = 0) -> P3Plan:
    blocks, labs = [], []
    for roots in roots_per_model:
        roots = np.asarray(roots, np.int64)
        blocks.append(sample_tree_block(graph, roots, num_layers, fanout,
                                        seed=sample_seed))
        labs.append(labels[roots].astype(np.int32))
    return P3Plan(blocks=blocks, labels=labs,
                  num_shards=len(blocks), num_layers=num_layers,
                  fanout=fanout, hidden_dim=hidden_dim)


# ---------------------------------------------------------------------------
# dim-sliced first layer (the model-parallel piece)
# ---------------------------------------------------------------------------

def _first_layer_partial(model: str, p, parent_x, child_x, d_slice):
    """Partial pre-activation of layer 1 using feature dims ``d_slice``
    and the matching rows of W₁. Summing partials over slices == the full
    computation, so a psum finishes the layer."""
    px = parent_x[:, d_slice]
    cx = child_x[:, :, d_slice]
    if model == "gcn":
        f = cx.shape[1]
        agg = (px + jnp.sum(cx, axis=1)) / (f + 1.0)
        return agg @ p["w"][d_slice]                      # (n, d_out)
    if model == "sage":
        return (px @ p["w_self"][d_slice]
                + jnp.mean(cx, axis=1) @ p["w_nbr"][d_slice])
    if model == "gat":
        n, f, _ = cx.shape
        hp = px @ p["w"][d_slice]                         # (n, h*dh)
        hc = (cx.reshape(n * f, -1) @ p["w"][d_slice]).reshape(n, f, -1)
        return jnp.concatenate([hp[:, None], hc], axis=1)  # (n, 1+f, h*dh)
    raise P3Unsupported(model)


def _first_layer_finish(model: str, p, partial_sum, fanout):
    """Post-psum completion of layer 1 (bias, nonlinearity, attention)."""
    if model == "gcn":
        return jax.nn.relu(partial_sum + p["b"])
    if model == "sage":
        return jax.nn.relu(partial_sum + p["b"])
    if model == "gat":
        heads = p["a_src"].shape[0]
        n, f1, hd = partial_sum.shape
        dh = hd // heads
        hall = partial_sum.reshape(n, f1, heads, dh)
        hp, hc = hall[:, 0], hall[:, 1:]
        e_src = jnp.einsum("nhd,hd->nh", hp, p["a_src"])
        e_all = jnp.einsum("nfhd,hd->nfh", hall, p["a_dst"])
        logits = jax.nn.leaky_relu(e_src[:, None, :] + e_all, 0.2)
        alpha = jax.nn.softmax(logits, axis=1)
        out = jnp.einsum("nfh,nfhd->nhd", alpha, hall)
        return jax.nn.elu(out.reshape(n, heads * dh))
    raise P3Unsupported(model)


def _upper_layers(params, cfg: GNNConfig, h1_feats):
    """Layers 2..k data-parallel on hidden features (standard tree pass)."""
    _, apply_fn = LAYER_REGISTRY[cfg.model]
    f = cfg.fanout
    hs = list(h1_feats)
    for layer in range(1, cfg.num_layers):
        p = params["layers"][layer]
        new_hs = []
        for h in range(len(hs) - 1):
            parent = hs[h]
            d = hs[h + 1].shape[-1]
            child = hs[h + 1].reshape(parent.shape[0], f, d)
            new_hs.append(apply_fn(p, parent, child))
        hs = new_hs
    root = hs[0]
    return root @ params["head"]["w"] + params["head"]["b"]


def run_p3_iteration(params, features: jnp.ndarray, plan: P3Plan,
                     cfg: GNNConfig):
    """Execute one P³ iteration (emulated comm: the per-slice partial sums
    make the model-parallel schedule explicit; a shard_map realization
    replaces the python sum with lax.psum over the dim axis).

    Returns (grads, mean_loss) — gradient-parity-compatible with the
    model-centric engine."""
    if cfg.model not in SUPPORTED:
        raise P3Unsupported(
            f"{cfg.model}: P³'s input-layer slicing needs a matmul-fronted "
            f"layer (paper §1: P³ targets particular GNN shapes)")
    n = plan.num_shards
    d = cfg.feature_dim
    slices = [jnp.asarray(ix) for ix in
              np.array_split(np.arange(d), n)]
    total_roots = sum(len(l) for l in plan.labels)

    def loss_fn(prm):
        loss_sum = 0.0
        for s in range(n):
            blk = plan.blocks[s]
            k = cfg.num_layers
            # layer 1, model-parallel: partial per dim slice, then "psum"
            h1 = []
            for h in range(k):
                parent_x = jnp.asarray(features[blk.hops[h]])
                child_x = jnp.asarray(features[blk.hops[h + 1]]).reshape(
                    blk.hops[h].shape[0], cfg.fanout, d)
                partial = sum(
                    _first_layer_partial(cfg.model, prm["layers"][0],
                                         parent_x, child_x, sl)
                    for sl in slices)
                h1.append(_first_layer_finish(cfg.model, prm["layers"][0],
                                              partial, cfg.fanout))
            logits = _upper_layers(prm, cfg, h1)
            labs = jnp.asarray(plan.labels[s])
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(
                logp, labs[:, None].astype(jnp.int32), axis=-1)[:, 0]
            loss_sum = loss_sum + nll.sum()
        return loss_sum / total_roots

    loss, grads = jax.value_and_grad(lambda p: loss_fn(p))(params)
    return grads, loss
