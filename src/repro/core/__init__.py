"""LeapGNN core — the paper's contribution. (The paper's *title* says
"HopGNN" but the text names the system LeapGNN; this repo keeps "hopgnn"
as the strategy key for continuity and uses LeapGNN when naming the
system.)

Feature-centric distributed GNN training: instead of fetching remote vertex
features to stationary data-parallel model replicas (model-centric, DGL
style), LeapGNN redistributes each mini-batch's root vertices to the servers
that own their features ("home" servers), trains per-root *micrographs*
there over N rotating time steps (model migration — free under SPMD
replication, see DESIGN.md §2), pre-gathers the deduplicated remote feature
set once per iteration, and adaptively merges time steps.

Public API:
  - plan_iteration(...)        host-side planner → IterationPlan
  - run_iteration(...)         device engine (shard_map or emulated comm)
  - PlanOverflow               structured shape-budget overflow signal
  - MergingController          §5.3 adaptive time-step merging
  - comm_model.*               byte accounting for every strategy

The compile-once training loop over these primitives lives in
:mod:`repro.train` (shape budgets, compiled-fn reuse, plan prefetching).
"""
from repro.core.strategies import (plan_iteration, plan_inference,
                                   InferencePlan, IterationPlan, Strategy)
from repro.core.distributed import (
    run_iteration, make_sharded_iteration, get_compiled_iteration,
    get_compiled_inference, infer_trace_count,
    EmulatedComm, ShardComm,
)
from repro.core.merging import MergingController, fold_assignment
from repro.core.pregather import PlanOverflow
from repro.core.p3 import P3Plan, P3Unsupported, plan_p3, run_p3_iteration
from repro.core import comm_model

__all__ = [
    "plan_iteration", "plan_inference", "InferencePlan", "IterationPlan",
    "Strategy", "run_iteration",
    "make_sharded_iteration", "get_compiled_iteration",
    "get_compiled_inference", "infer_trace_count",
    "EmulatedComm", "ShardComm",
    "MergingController", "fold_assignment", "PlanOverflow", "comm_model",
    "P3Plan", "P3Unsupported", "plan_p3", "run_p3_iteration",
]
