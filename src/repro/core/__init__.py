"""HopGNN core — the paper's contribution.

Feature-centric distributed GNN training: instead of fetching remote vertex
features to stationary data-parallel model replicas (model-centric, DGL
style), HopGNN redistributes each mini-batch's root vertices to the servers
that own their features ("home" servers), trains per-root *micrographs*
there over N rotating time steps (model migration — free under SPMD
replication, see DESIGN.md §2), pre-gathers the deduplicated remote feature
set once per iteration, and adaptively merges time steps.

Public API:
  - plan_iteration(...)        host-side planner → IterationPlan
  - run_iteration(...)         device engine (shard_map or emulated comm)
  - MergingController          §5.3 adaptive time-step merging
  - comm_model.*               byte accounting for every strategy
"""
from repro.core.strategies import plan_iteration, IterationPlan, Strategy
from repro.core.distributed import (
    run_iteration, make_sharded_iteration, EmulatedComm, ShardComm,
)
from repro.core.merging import MergingController
from repro.core.p3 import P3Plan, P3Unsupported, plan_p3, run_p3_iteration
from repro.core import comm_model

__all__ = [
    "plan_iteration", "IterationPlan", "Strategy", "run_iteration",
    "make_sharded_iteration", "EmulatedComm", "ShardComm",
    "MergingController", "comm_model",
    "P3Plan", "P3Unsupported", "plan_p3", "run_p3_iteration",
]
