"""Vertex feature pre-gathering (paper §5.2).

Given the tree blocks a server will train across *all* time steps of one
iteration, the full remote-vertex set is known before the first step.
Pre-gathering deduplicates it (a vertex used at steps t0 and t1 is fetched
once) and batches the per-peer fetches into a single exchange.

The exchange plan is expressed as TPU-friendly rectangular arrays:
``req[s, p] : (R_max,)`` peer-local row indices shard s wants from shard p,
padded with 0; true counts ride along for exact byte accounting. The device
engine turns this into two ``all_to_all`` collectives (indices out,
features back) — the SPMD analogue of LeapGNN's batched gRPC fetch.
"""
from __future__ import annotations

import dataclasses

import numpy as np


class PlanOverflow(ValueError):
    """A rectangular plan array would not fit the requested shape budget.

    Carries which budgeted dimension overflowed (``"batch_pad"`` or
    ``"r_max"``) and the size actually needed, so callers (repro.train's
    ShapeBudget) can re-bucket precisely instead of parsing messages.
    """

    def __init__(self, field: str, needed: int, limit: int):
        super().__init__(f"{field} overflow: need {needed} > {field}={limit}")
        self.field = field
        self.needed = int(needed)
        self.limit = int(limit)


@dataclasses.dataclass
class GatherPlan:
    """One exchange: requests + the workspace index of every remote vertex."""

    req: np.ndarray          # (N, P, R_max) int32 — peer-local indices
    req_count: np.ndarray    # (N, P) int64 — true counts (accounting)
    r_max: int
    # global-vertex-id -> workspace slot, per requesting shard:
    #   slot(v) = local_rows + p * R_max + position (v owned by p)
    slot_of: list[dict[int, int]]

    def remote_rows_exact(self) -> int:
        return int(self.req_count.sum())

    def remote_rows_padded(self) -> int:
        n, p = self.req_count.shape
        return n * (p - 1) * self.r_max  # self-column carries no traffic


def build_gather_plan(needed_ids_per_shard: list[np.ndarray],
                      owner: np.ndarray, local_idx: np.ndarray,
                      num_shards: int, local_rows: int,
                      r_max: int | None = None) -> GatherPlan:
    """Build the deduplicated exchange plan.

    needed_ids_per_shard[s]: every global vertex id shard s touches this
    iteration (may include duplicates; we dedup here — that *is* §5.2).
    """
    n = num_shards
    uniq = [np.unique(ids[owner[ids] != s]) if ids.size else np.zeros(0, np.int64)
            for s, ids in enumerate(needed_ids_per_shard)]
    per_peer: list[list[np.ndarray]] = []
    counts = np.zeros((n, n), np.int64)
    for s in range(n):
        row = []
        for p in range(n):
            ids = uniq[s][owner[uniq[s]] == p] if p != s else np.zeros(0, np.int64)
            row.append(ids)
            counts[s, p] = ids.size
        per_peer.append(row)
    if r_max is None:
        r_max = max(1, int(counts.max()))
    if counts.max() > r_max:
        raise PlanOverflow("r_max", int(counts.max()), int(r_max))

    req = np.zeros((n, n, r_max), np.int32)
    slot_of: list[dict[int, int]] = []
    for s in range(n):
        m: dict[int, int] = {}
        for p in range(n):
            ids = per_peer[s][p]
            req[s, p, :ids.size] = local_idx[ids]
            base = local_rows + p * r_max
            for j, v in enumerate(ids):
                m[int(v)] = base + j
        slot_of.append(m)
    return GatherPlan(req=req, req_count=counts, r_max=r_max, slot_of=slot_of)


def workspace_indices(hops: list[np.ndarray], shard: int,
                      owner: np.ndarray, local_idx: np.ndarray,
                      plan: GatherPlan) -> list[np.ndarray]:
    """Map global vertex ids of a tree block to workspace slots on ``shard``:
    locally-owned rows index the local table; remote rows index the
    pre-gathered region."""
    out = []
    slots = plan.slot_of[shard]
    for ids in hops:
        is_local = owner[ids] == shard
        w = np.where(is_local, local_idx[ids], 0).astype(np.int64)
        if not np.all(is_local):
            rem_pos = np.nonzero(~is_local)[0]
            w[rem_pos] = np.array([slots[int(v)] for v in ids[rem_pos]], np.int64)
        out.append(w.astype(np.int32))
    return out
