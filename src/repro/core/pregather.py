"""Vertex feature pre-gathering (paper §5.2).

Given the tree blocks a server will train across *all* time steps of one
iteration, the full remote-vertex set is known before the first step.
Pre-gathering deduplicates it (a vertex used at steps t0 and t1 is fetched
once) and batches the per-peer fetches into a single exchange.

The exchange plan is expressed as TPU-friendly rectangular arrays:
``req[s, p] : (R_max,)`` peer-local row indices shard s wants from shard p,
padded with 0; true counts ride along for exact byte accounting. The device
engine turns this into two ``all_to_all`` collectives (indices out,
features back) — the SPMD analogue of LeapGNN's batched gRPC fetch.

Cache-aware path (repro.cache): when a resident :class:`CacheIndex` is
passed, each deduped remote id is first probed against the requesting
shard's cached set. Hits are translated to slots in the cached workspace
region (``[local_rows, local_rows + c_max)``) and never enter the
exchange; only misses are grouped into ``req``. Features are static during
training, so cached rows are exact and the split is numerics-neutral.

Planner hot path: plan construction is fully vectorized numpy — one
``np.unique`` over a flat ``(shard, id)`` key dedups every shard at once,
``bincount``/``lexsort`` produce the per-(shard, peer) layout, and the
global-id → workspace-slot translation is a :class:`SlotMap`
(``searchsorted`` over per-shard sorted id segments) instead of the
original per-vertex Python dicts. The reference implementation is kept as
:func:`_reference_build_gather_plan` / :func:`_reference_workspace_indices`
and the parity tests assert the two agree exactly.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import numpy as np

if TYPE_CHECKING:                      # duck-typed at runtime (no core→cache
    from repro.cache.store import CacheIndex   # import cycle risk)


class PlanOverflow(ValueError):
    """A rectangular plan array would not fit the requested shape budget.

    Carries which budgeted dimension overflowed (``"batch_pad"`` or
    ``"r_max"``) and the size actually needed, so callers (repro.train's
    ShapeBudget) can re-bucket precisely instead of parsing messages.
    """

    def __init__(self, field: str, needed: int, limit: int):
        super().__init__(f"{field} overflow: need {needed} > {field}={limit}")
        self.field = field
        self.needed = int(needed)
        self.limit = int(limit)


# Above this many vertices the per-shard dense translation cache is not
# built (memory guard: one int64 row of ``num_vertices`` per shard) and
# lookups stay on the searchsorted path.
_DENSE_LUT_MAX_VERTICES = 64_000_000

# The planner dedups via an (N, V) presence bitmap — O(ids + N·V) — when it
# fits this many cells (bool bytes) AND the id volume justifies the O(N·V)
# bitmap scan (see _use_bitmap_dedup); otherwise it falls back to the
# sort-based O(ids log ids) path. Tree-block id streams repeat heavily
# (fanout trees share neighbors), so the bitmap wins on dense workloads.
_DENSE_DEDUP_MAX_CELLS = 1 << 28


def _use_bitmap_dedup(n: int, V: int, total_ids: int) -> bool:
    """Bitmap dedup only when its O(n·V) scan is cheap in absolute terms
    or amortized by the id volume — a per-step plan with a few thousand
    ids on a 30M-vertex graph must not pay a 240MB bitmap per call."""
    cells = n * V
    if not 0 < cells <= _DENSE_DEDUP_MAX_CELLS:
        return False
    return cells <= (1 << 22) or cells <= total_ids * 16


@dataclasses.dataclass
class SlotMap:
    """Vectorized global-vertex-id → workspace-slot translation.

    Layout: the remote ids of all requesting shards live in one flat array,
    segmented per shard by ``starts`` (CSR-style offsets, length N+1).
    Within a shard's segment ``ids[starts[s]:starts[s+1]]`` is sorted
    ascending, so a lookup is ``searchsorted`` into the segment — O(log n)
    per query, fully vectorized over query batches, zero per-element Python.
    ``slots`` is aligned with ``ids`` and holds each id's workspace row.

    Hot-path cache: ``workspace_indices`` queries the same shard T × hops
    times per plan, so :meth:`translation_row` materializes one dense
    int32 ``num_vertices``-sized row per shard (local row index or remote
    slot at index v, -1 elsewhere) and every hop translation is a single
    gather. The cache is skipped above ``_DENSE_LUT_MAX_VERTICES`` (memory
    guard) or when ``num_vertices`` is unknown; the searchsorted
    :meth:`lookup` path is always valid.
    """

    starts: np.ndarray   # (N+1,) int64 — per-shard segment offsets
    ids: np.ndarray      # (M,) int64 — remote global ids, sorted per segment
    slots: np.ndarray    # (M,) int64 — workspace slot of ids[k]
    num_vertices: int = 0   # global id space size (0 = unknown, no cache)

    def __post_init__(self):
        self._trans: dict[int, np.ndarray] = {}

    @property
    def num_shards(self) -> int:
        return self.starts.size - 1

    def shard_ids(self, shard: int) -> np.ndarray:
        """Sorted remote global ids shard ``shard`` fetches."""
        return self.ids[self.starts[shard]:self.starts[shard + 1]]

    def shard_slots(self, shard: int) -> np.ndarray:
        """Workspace slots aligned with :meth:`shard_ids`."""
        return self.slots[self.starts[shard]:self.starts[shard + 1]]

    def cached_translation_row(self, shard: int) -> np.ndarray | None:
        """The shard's dense translation row if already built, else None —
        lets callers reuse a paid-for row even when the current query
        volume alone wouldn't justify building one."""
        return self._trans.get(shard)

    def translation_row(self, shard: int, owner: np.ndarray,
                        local_idx: np.ndarray) -> np.ndarray | None:
        """Full per-shard translation row: ``row[v]`` = workspace slot of
        global id v on ``shard`` — ``local_idx[v]`` for locally-owned v,
        the pre-gathered slot for fetched remote v, -1 for ids outside the
        plan. Turns a whole hop translation into ONE gather (no owner
        mask, no where, no searchsorted). Cached per shard; callers pass
        the same (owner, local_idx) the plan was built with. None above
        the memory guard — callers fall back to :meth:`lookup`."""
        if not (0 < self.num_vertices <= _DENSE_LUT_MAX_VERTICES):
            return None
        row = self._trans.get(shard)
        if row is None:
            # int32 on purpose: workspace rows fit comfortably, and the
            # hop translation gather moves half the bytes.
            row = np.where(np.asarray(owner) == shard,
                           np.asarray(local_idx, np.int32),
                           np.int32(-1))
            row[self.shard_ids(shard)] = self.shard_slots(shard)
            self._trans[shard] = row
        return row

    def lookup(self, shard: int, query: np.ndarray) -> np.ndarray:
        """Workspace slots for global ids ``query`` on ``shard``.

        Every queried id must be in the shard's remote set (callers filter
        local ids first); unknown ids raise rather than alias silently.
        """
        query = np.asarray(query, np.int64)
        lo, hi = int(self.starts[shard]), int(self.starts[shard + 1])
        seg = self.ids[lo:hi]
        if query.size and seg.size == 0:
            raise KeyError(
                f"ids not in shard {shard}'s remote set: {query[:8]}")
        pos = np.searchsorted(seg, query)
        if query.size:
            bad = (pos >= seg.size) \
                | (seg[np.minimum(pos, seg.size - 1)] != query)
            if np.any(bad):
                raise KeyError(f"ids not in shard {shard}'s remote set: "
                               f"{query[bad][:8]}")
        return self.slots[lo + pos]


@dataclasses.dataclass
class GatherPlan:
    """One exchange: requests + the workspace index of every remote vertex.

    With a cache (repro.cache), the workspace on shard s is
    ``[local_rows local | c_max cached | P*r_max fetched]``: remote ids
    resident in the shard's cache table are *hits* (their slot points into
    the cached region; they never enter ``req``), the rest are *misses*
    shipped through the exchange as before. ``req``/``req_count``/``r_max``
    therefore describe miss traffic only.
    """

    req: np.ndarray          # (N, P, R_max) int32 — peer-local indices
    req_count: np.ndarray    # (N, P) int64 — true miss counts (accounting)
    r_max: int
    # global-vertex-id -> workspace slot, per requesting shard:
    #   hit:  slot(v) = local_rows + cache_slot(v)
    #   miss: slot(v) = local_rows + c_max + p * R_max + position
    slot_map: SlotMap
    c_max: int = 0                        # cached-region height (0 = no cache)
    cache_hits: Optional[np.ndarray] = None   # (N,) int64 hit rows per shard

    def remote_rows_exact(self) -> int:
        """Deduped remote rows actually shipped (misses only)."""
        return int(self.req_count.sum())

    def cache_hit_rows(self) -> int:
        """Deduped remote rows served from the resident cache."""
        return 0 if self.cache_hits is None else int(self.cache_hits.sum())

    def remote_rows_padded(self) -> int:
        n, p = self.req_count.shape
        return n * (p - 1) * self.r_max  # self-column carries no traffic


def build_gather_plan(needed_ids_per_shard: list[np.ndarray],
                      owner: np.ndarray, local_idx: np.ndarray,
                      num_shards: int, local_rows: int,
                      r_max: int | None = None,
                      cache: "Optional[CacheIndex]" = None) -> GatherPlan:
    """Build the deduplicated exchange plan (vectorized).

    needed_ids_per_shard[s]: every global vertex id shard s touches this
    iteration (may include duplicates; we dedup here — that *is* §5.2).

    All bookkeeping is flat numpy: ids are tagged with their requesting
    shard via a combined ``shard * V + id`` key, deduped in one
    ``np.unique``, split against the optional resident ``cache``
    (repro.cache.CacheIndex — hits point into the cached workspace region
    and leave the exchange entirely), and the misses are grouped by owning
    peer and scattered into the rectangular ``req`` with one fancy-index
    store.
    """
    n = num_shards
    owner = np.asarray(owner)
    local_idx = np.asarray(local_idx)
    V = owner.size

    total_ids = sum(np.asarray(ids).size for ids in needed_ids_per_shard)
    if _use_bitmap_dedup(n, V, total_ids):
        # Bitmap dedup: mark[s, v] = shard s touches id v, then clear each
        # id's home cell (local ids need no fetch). np.nonzero walks the
        # bitmap row-major, handing back the dedup set already sorted by
        # (shard, id) — SlotMap's exact layout — in O(ids + n·V), with no
        # sort (and no concatenated copy) of the heavily duplicated raw
        # id stream.
        mark = np.zeros((n, V), bool)
        for s, ids in enumerate(needed_ids_per_shard):
            ids = np.asarray(ids)
            if ids.size:
                mark[s, ids.ravel()] = True
        mark[owner, np.arange(V)] = False
        u_shard, u_id = np.nonzero(mark)       # dedup set, (shard, id) order
    else:
        # Sort dedup: one combined (shard, id) key — a single np.unique
        # dedups per requesting shard and leaves the output in the
        # (shard, id) order SlotMap wants.
        sizes = [np.asarray(ids).size for ids in needed_ids_per_shard]
        if sum(sizes) == 0:
            flat = np.zeros(0, np.int64)
            shard = np.zeros(0, np.int64)
        else:
            flat = np.concatenate([np.asarray(ids, np.int64).ravel()
                                   for ids in needed_ids_per_shard])
            shard = np.repeat(np.arange(n, dtype=np.int64), sizes)
        own = owner[flat].astype(np.int64) if flat.size else flat
        remote = own != shard
        flat, shard = flat[remote], shard[remote]
        ukey = np.unique(shard * V + flat)
        u_shard, u_id = np.divmod(ukey, V)
    u_own = owner[u_id].astype(np.int64)

    # ---- cache split: hits leave the exchange ----
    c_max = int(cache.c_max) if cache is not None else 0
    hit = np.zeros(u_id.size, bool)
    slots_by_id = np.empty(u_id.size, np.int64)
    starts = np.concatenate(
        ([0], np.cumsum(np.bincount(u_shard, minlength=n))))
    if cache is not None and u_id.size:
        for s in range(n):
            lo, hi = int(starts[s]), int(starts[s + 1])
            if hi == lo:
                continue
            h, cslot = cache.hit_split(s, u_id[lo:hi])
            hit[lo:hi] = h
            idx = np.nonzero(h)[0] + lo
            slots_by_id[idx] = local_rows + cslot[h]
    cache_hits = np.bincount(u_shard[hit], minlength=n).astype(np.int64)

    # ---- misses: group by (shard, peer, id) and build the exchange ----
    miss_pos = np.nonzero(~hit)[0]
    s_m, p_m, v_m = u_shard[miss_pos], u_own[miss_pos], u_id[miss_pos]
    # a stable argsort over the small-range (shard, peer) key keeps ids
    # ascending within each (s, p) group
    order = np.argsort(s_m * n + p_m, kind="stable")
    s_o, p_o, v_o = s_m[order], p_m[order], v_m[order]

    counts = np.bincount(s_o * n + p_o,
                         minlength=n * n).reshape(n, n).astype(np.int64)
    if r_max is None:
        r_max = max(1, int(counts.max()))
    if counts.max() > r_max:
        raise PlanOverflow("r_max", int(counts.max()), int(r_max))

    # j-th id of a (s, p) group lands in req[s, p, j] and workspace slot
    # local_rows + c_max + p*r_max + j.
    group_start = np.concatenate(
        ([0], np.cumsum(counts.reshape(-1))))[:-1]
    j = np.arange(s_o.size, dtype=np.int64) - group_start[s_o * n + p_o]

    req = np.zeros((n, n, r_max), np.int32)
    req[s_o, p_o, j] = local_idx[v_o]

    # miss slots aligned back to the (shard, id)-sorted SlotMap layout
    slots_by_id[miss_pos[order]] = local_rows + c_max + p_o * r_max + j

    return GatherPlan(req=req, req_count=counts, r_max=r_max,
                      slot_map=SlotMap(starts=starts, ids=u_id,
                                       slots=slots_by_id, num_vertices=V),
                      c_max=c_max,
                      cache_hits=cache_hits if cache is not None else None)


def workspace_indices(hops: list[np.ndarray], shard: int,
                      owner: np.ndarray, local_idx: np.ndarray,
                      plan: GatherPlan) -> list[np.ndarray]:
    """Map global vertex ids of a tree block to workspace slots on ``shard``:
    locally-owned rows index the local table; remote rows index the
    pre-gathered region. Hot path is one gather per hop through the
    SlotMap's cached full translation row; above the row's memory guard it
    falls back to owner-mask + searchsorted (still zero per-element
    Python)."""
    out = []
    sm = plan.slot_map
    row = sm.cached_translation_row(shard)
    if row is None:
        # Building the dense row costs O(V); only pay it when this call's
        # id volume amortizes it (mirrors _use_bitmap_dedup's guard — a
        # few thousand ids on a 30M-vertex graph stay on searchsorted).
        total = sum(np.asarray(ids).size for ids in hops)
        V = sm.num_vertices
        if 0 < V and (V <= (1 << 22) or V <= total * 16):
            row = sm.translation_row(shard, owner, local_idx)
    for ids in hops:
        ids = np.asarray(ids)
        if row is not None:
            w = row[ids]                     # already int32
            if w.size and int(w.min()) < 0:
                raise KeyError(f"ids not in shard {shard}'s remote set: "
                               f"{ids[w < 0][:8]}")
            out.append(w)
            continue
        is_local = owner[ids] == shard
        w = np.where(is_local, local_idx[ids], 0).astype(np.int64)
        rem_pos = np.nonzero(~is_local)[0]
        if rem_pos.size:
            w[rem_pos] = plan.slot_map.lookup(shard,
                                              np.asarray(ids,
                                                         np.int64)[rem_pos])
        out.append(w.astype(np.int32))
    return out


# ---------------------------------------------------------------------------
# Streamed mode (repro.features): compacted local region
# ---------------------------------------------------------------------------

def split_local_touched(needed_ids_per_shard: list[np.ndarray],
                        owner: np.ndarray,
                        l_max: int | None = None
                        ) -> tuple[list[np.ndarray], int]:
    """Per-shard sorted unique *locally-owned* ids an iteration touches.

    Streamed plans (a tiered FeatureStore instead of a device-resident
    table) cannot index the full local shard — only the iteration's
    touched local rows are uploaded, compacted into the first ``l_max``
    workspace rows. ``l_max`` is a budgeted dimension exactly like
    ``r_max``: ``None`` sizes it to this iteration's need; a too-small
    budget raises :class:`PlanOverflow("l_max")` for explicit re-bucketing.

    Returns (local_ids_per_shard, l_max): ``local_ids_per_shard[s]`` is
    sorted ascending, so global id ``local_ids_per_shard[s][k]`` lives in
    workspace row ``k`` on shard s.
    """
    owner = np.asarray(owner)
    loc: list[np.ndarray] = []
    for s, ids in enumerate(needed_ids_per_shard):
        ids = np.asarray(ids, np.int64).ravel()
        u = np.unique(ids) if ids.size else np.zeros(0, np.int64)
        loc.append(u[owner[u] == s] if u.size else u)
    need = max(1, max((u.size for u in loc), default=1))
    if l_max is None:
        l_max = need
    elif need > l_max:
        raise PlanOverflow("l_max", need, int(l_max))
    return loc, int(l_max)


def stream_workspace_indices(hops: list[np.ndarray], shard: int,
                             owner: np.ndarray,
                             local_ids: np.ndarray,
                             plan: GatherPlan) -> list[np.ndarray]:
    """Streamed-mode analogue of :func:`workspace_indices`: locally-owned
    ids map to their position in the shard's *compacted* touched-local
    region (``local_ids``, sorted — position = searchsorted rank) instead
    of a full-shard local row; remote ids resolve through the plan's
    SlotMap as usual (the plan was built with ``local_rows = l_max``, so
    remote slots already sit above the compacted region)."""
    out = []
    local_ids = np.asarray(local_ids, np.int64)
    owner = np.asarray(owner)
    sm = plan.slot_map
    # dense fast path: one row translating BOTH local compaction and remote
    # slots, amortized like workspace_indices' guard
    row = None
    V = sm.num_vertices
    total = sum(np.asarray(ids).size for ids in hops)
    if 0 < V <= _DENSE_LUT_MAX_VERTICES \
            and (V <= (1 << 22) or V <= total * 16):
        row = np.full(V, -1, np.int32)
        row[local_ids] = np.arange(local_ids.size, dtype=np.int32)
        row[sm.shard_ids(shard)] = sm.shard_slots(shard).astype(np.int32)
    for ids in hops:
        ids = np.asarray(ids, np.int64)
        if row is not None:
            w = row[ids]
            if w.size and int(w.min()) < 0:
                raise KeyError(f"ids not in shard {shard}'s touched set: "
                               f"{ids[w < 0][:8]}")
            out.append(w)
            continue
        is_local = owner[ids] == shard
        w = np.zeros(ids.size, np.int64)
        lpos = np.nonzero(is_local)[0]
        if lpos.size:
            p = np.searchsorted(local_ids, ids[lpos])
            bad = (p >= local_ids.size) \
                | (local_ids[np.minimum(p, local_ids.size - 1)]
                   != ids[lpos])
            if np.any(bad):
                raise KeyError(f"ids not in shard {shard}'s touched set: "
                               f"{ids[lpos][bad][:8]}")
            w[lpos] = p
        rpos = np.nonzero(~is_local)[0]
        if rpos.size:
            w[rpos] = sm.lookup(shard, ids[rpos])
        out.append(w.astype(np.int32))
    return out


# ---------------------------------------------------------------------------
# Reference implementation (pure-Python, per-vertex) — parity oracle only.
# ---------------------------------------------------------------------------

def _reference_build_gather_plan(needed_ids_per_shard: list[np.ndarray],
                                 owner: np.ndarray, local_idx: np.ndarray,
                                 num_shards: int, local_rows: int,
                                 r_max: int | None = None,
                                 cache: "Optional[CacheIndex]" = None
                                 ) -> GatherPlan:
    """The original dict-based planner, kept as the parity oracle (and as
    the 'legacy' side of benchmarks/planning.py), extended with the same
    per-vertex cache hit/miss split the vectorized planner performs.
    Returns the same GatherPlan structure; its dict-built slot map is
    converted to a SlotMap at the end so downstream code sees one type."""
    n = num_shards
    c_max = int(cache.c_max) if cache is not None else 0
    cache_dicts = ([{int(v): int(c) for v, c in zip(cache.ids[s],
                                                    cache.slots[s])}
                    for s in range(n)] if cache is not None
                   else [{} for _ in range(n)])
    uniq = [np.unique(ids[owner[ids] != s]) if np.asarray(ids).size
            else np.zeros(0, np.int64)
            for s, ids in enumerate(needed_ids_per_shard)]
    hits: list[list[int]] = [[] for _ in range(n)]
    misses: list[np.ndarray] = []
    for s in range(n):
        keep = []
        for v in uniq[s]:
            if int(v) in cache_dicts[s]:
                hits[s].append(int(v))
            else:
                keep.append(int(v))
        misses.append(np.asarray(keep, np.int64))
    per_peer: list[list[np.ndarray]] = []
    counts = np.zeros((n, n), np.int64)
    for s in range(n):
        row = []
        for p in range(n):
            ids = misses[s][owner[misses[s]] == p] if p != s \
                else np.zeros(0, np.int64)
            row.append(ids)
            counts[s, p] = ids.size
        per_peer.append(row)
    if r_max is None:
        r_max = max(1, int(counts.max()))
    if counts.max() > r_max:
        raise PlanOverflow("r_max", int(counts.max()), int(r_max))

    req = np.zeros((n, n, r_max), np.int32)
    slot_of: list[dict[int, int]] = []
    for s in range(n):
        m: dict[int, int] = {}
        for v in hits[s]:
            m[v] = local_rows + cache_dicts[s][v]
        for p in range(n):
            ids = per_peer[s][p]
            req[s, p, :ids.size] = local_idx[ids]
            base = local_rows + c_max + p * r_max
            for jj, v in enumerate(ids):
                m[int(v)] = base + jj
        slot_of.append(m)
    plan = GatherPlan(req=req, req_count=counts, r_max=r_max,
                      slot_map=_slot_map_from_dicts(slot_of),
                      c_max=c_max,
                      cache_hits=(np.asarray([len(h) for h in hits],
                                             np.int64)
                                  if cache is not None else None))
    plan._slot_dicts = slot_of   # legacy translation path (benchmarks)
    return plan


def _slot_map_from_dicts(slot_of: list[dict[int, int]]) -> SlotMap:
    ids_seg, slots_seg, starts = [], [], [0]
    for m in slot_of:
        ids = np.fromiter(m.keys(), np.int64, len(m))
        order = np.argsort(ids, kind="stable")
        ids_seg.append(ids[order])
        slots_seg.append(
            np.fromiter(m.values(), np.int64, len(m))[order])
        starts.append(starts[-1] + len(m))
    cat = (lambda xs: np.concatenate(xs) if xs else np.zeros(0, np.int64))
    return SlotMap(starts=np.asarray(starts, np.int64),
                   ids=cat(ids_seg), slots=cat(slots_seg))


def _reference_workspace_indices(hops: list[np.ndarray], shard: int,
                                 owner: np.ndarray, local_idx: np.ndarray,
                                 plan: GatherPlan) -> list[np.ndarray]:
    """Original per-element translation — the oracle for workspace_indices
    parity and the 'legacy' side of benchmarks/planning.py. Uses the
    reference plan's dicts when present (as the seed code did), else
    rebuilds one from the SlotMap."""
    out = []
    dicts = getattr(plan, "_slot_dicts", None)
    if dicts is not None:
        slots = dicts[shard]
    else:
        sm = plan.slot_map
        slots = {int(v): int(s) for v, s in zip(sm.shard_ids(shard),
                                                sm.shard_slots(shard))}
    for ids in hops:
        is_local = owner[ids] == shard
        w = np.where(is_local, local_idx[ids], 0).astype(np.int64)
        if not np.all(is_local):
            rem_pos = np.nonzero(~is_local)[0]
            w[rem_pos] = np.array([slots[int(v)] for v in ids[rem_pos]],
                                  np.int64)
        out.append(w.astype(np.int32))
    return out
