"""Communication-volume accounting for every training strategy (Fig. 5, 7).

The paper's figures compare *bytes moved per iteration* across strategies;
byte counts are hardware-independent, so this module is pure host-side
arithmetic over sampled tree blocks. A ``Fabric`` turns bytes into modeled
seconds for a named interconnect (the paper's 10 Gb/s Ethernet, or TPU ICI)
so EXPERIMENTS.md can report both.

Strategies accounted:

* ``model_centric``  — DGL: each shard fetches the deduplicated remote
  feature rows of its whole subgraph; gradients all-reduce once.
* ``naive_fc``       — §3.2: the model migrates layer-by-layer to wherever
  the current layer's features live, carrying parameters + partial
  activations + the subgraph topology on every hop. Reproduces the paper's
  "up to 2.59× worse than model-centric" finding (Fig. 7).
* ``hopgnn``         — §5: remote rows after micrograph redistribution and
  pre-gather dedup, plus one model+gradient migration per time step
  (``replicated_params=True`` zeroes the migration term — the SPMD
  realization where parameters are already everywhere; see DESIGN.md §2).
* ``p3``             — P³ [OSDI'21]: feature dimension is model-parallel for
  the input layer; hidden activations (and their gradients) of the
  second-innermost hop are exchanged instead of raw features. Cheap for
  small hidden dims, poor for large ones — the sensitivity the paper
  exploits in §7.2 observation 4.
* ``lo``             — locality-optimized: zero remote feature bytes (and
  biased batches; accuracy cost measured in benchmarks/accuracy.py).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.sampler import TreeBlock

F32 = 4  # feature/activation/parameter byte width used throughout the paper


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Point-to-point fabric model: seconds = bytes / bandwidth (+latency/msg)."""

    name: str
    bandwidth_Bps: float
    latency_s: float = 0.0

    def seconds(self, total_bytes: float, messages: int = 0) -> float:
        return total_bytes / self.bandwidth_Bps + messages * self.latency_s


FABRICS = {
    # the paper's cluster interconnect
    "ethernet_10g": Fabric("ethernet_10g", 10e9 / 8, latency_s=50e-6),
    # TPU v5e ICI per link (roofline constant from the brief)
    "tpu_ici": Fabric("tpu_ici", 50e9, latency_s=1e-6),
}


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """What the comm model needs to know about the GNN being trained."""

    feature_dim: int
    hidden_dim: int
    num_layers: int
    param_bytes: int

    def layer_width(self, hop: int) -> int:
        """Embedding width at hop h *after* (num_layers - h) layers ran."""
        return self.feature_dim if hop == self.num_layers else self.hidden_dim


# ---------------------------------------------------------------------------
# Per-strategy byte accounting
# ---------------------------------------------------------------------------

def _remote_unique_rows(blocks: Sequence[TreeBlock], owner: np.ndarray,
                        shard_of_block: Sequence[int],
                        dedup_across_blocks: bool) -> int:
    """Unique remote feature rows fetched, grouped by the fetching shard."""
    per_shard: dict[int, list[np.ndarray]] = {}
    for blk, s in zip(blocks, shard_of_block):
        per_shard.setdefault(s, []).append(blk.all_ids())
    total = 0
    for s, ids_list in per_shard.items():
        if dedup_across_blocks:
            ids = np.unique(np.concatenate(ids_list))
            total += int((owner[ids] != s).sum())
        else:
            for ids in ids_list:
                u = np.unique(ids)
                total += int((owner[u] != s).sum())
    return total


def model_centric_bytes(blocks: Sequence[TreeBlock], owner: np.ndarray,
                        shard_of_block: Sequence[int], spec: ModelSpec,
                        num_shards: int) -> dict:
    """DGL: remote features in, one gradient all-reduce out."""
    rows = _remote_unique_rows(blocks, owner, shard_of_block,
                               dedup_across_blocks=True)
    feat = rows * spec.feature_dim * F32
    # ring all-reduce moves 2·(N-1)/N · param_bytes per shard
    grad = int(2 * (num_shards - 1) / num_shards * spec.param_bytes) * num_shards
    return {"feature_bytes": feat, "grad_bytes": grad, "model_bytes": 0,
            "intermediate_bytes": 0, "total": feat + grad,
            "remote_rows": rows}


def topology_bytes(blk: TreeBlock) -> int:
    return int(sum(h.size for h in blk.hops)) * F32


def naive_fc_bytes(blocks: Sequence[TreeBlock], owner: np.ndarray,
                   spec: ModelSpec, num_shards: int) -> dict:
    """§3.2: per subgraph, walk layers innermost-out; for each layer visit
    every shard owning any of that layer's features, carrying model +
    partial state + topology on every migration.

    Partial state at layer ℓ (hops 0..ℓ-1 still incomplete) = the
    aggregation workspace for those hops: Σ_{h<ℓ} |hop_h| · width(h) · 4B.
    """
    k = spec.num_layers
    model = intermediate = 0
    migrations = 0
    for blk in blocks:
        topo = topology_bytes(blk)
        here = int(owner[blk.hops[0][0]])  # model starts at root's home
        for layer in range(k, 0, -1):      # consume hop `layer` features
            owners = np.unique(owner[blk.hops[layer]])
            carried = sum(blk.hops[h].size * spec.layer_width(h) * F32
                          for h in range(layer))
            for dst in owners:
                if int(dst) == here:
                    continue
                migrations += 1
                model += spec.param_bytes + topo
                intermediate += carried
                here = int(dst)
        # return home for the final root update + sync
        if here != int(owner[blk.hops[0][0]]):
            migrations += 1
            model += spec.param_bytes + topo
            intermediate += blk.hops[0].size * spec.layer_width(0) * F32
    grad = int(2 * (num_shards - 1) / num_shards * spec.param_bytes) * num_shards
    total = model + intermediate + grad
    return {"feature_bytes": 0, "grad_bytes": grad, "model_bytes": model,
            "intermediate_bytes": intermediate, "total": total,
            "migrations": migrations}


def hopgnn_bytes(remote_rows_pregathered: int, num_steps: int,
                 spec: ModelSpec, num_shards: int,
                 replicated_params: bool = False) -> dict:
    """§5: deduped remote rows (from the IterationPlan's exact accounting) +
    per-step model migration. With ``replicated_params`` (the SPMD
    realization) migration bytes are zero; paper-faithful mode charges
    parameters + accumulated gradients per hop of the rotation."""
    feat = remote_rows_pregathered * spec.feature_dim * F32
    if replicated_params:
        model = 0
    else:
        # every model makes (num_steps - 1) hops carrying params + grads
        model = num_shards * (num_steps - 1) * 2 * spec.param_bytes
    grad = int(2 * (num_shards - 1) / num_shards * spec.param_bytes) * num_shards
    return {"feature_bytes": feat, "grad_bytes": grad, "model_bytes": model,
            "intermediate_bytes": 0, "total": feat + model + grad,
            "remote_rows": remote_rows_pregathered}


def hopgnn_bytes_cached(miss_rows: int, hit_rows: int, num_steps: int,
                        spec: ModelSpec, num_shards: int,
                        replicated_params: bool = False,
                        refresh_rows: int = 0,
                        iters_per_refresh: int = 1) -> dict:
    """Cache-adjusted §5 accounting (repro.cache).

    ``miss_rows``/``hit_rows`` come straight from the cache-aware
    IterationPlan (``remote_rows_exact`` / ``cache_hit_rows``): hits move
    zero bytes at iteration time. The cache's own refill traffic —
    ``refresh_rows`` feature rows per refresh, amortized over the
    ``iters_per_refresh`` iterations a refresh serves (one epoch for the
    Trainer's epoch prefetcher) — is charged back to ``feature_bytes`` so
    the model can't pretend cached rows were free to install. The reported
    ``cache_saved_bytes`` is the *net* per-iteration win the benchmark's
    measured bytes must match."""
    base = hopgnn_bytes(miss_rows, num_steps, spec, num_shards,
                        replicated_params=replicated_params)
    refresh = refresh_rows * spec.feature_dim * F32 \
        / max(int(iters_per_refresh), 1)
    base["feature_bytes"] = int(base["feature_bytes"] + refresh)
    base["total"] = int(base["total"] + refresh)
    base["cache_hit_rows"] = int(hit_rows)
    base["cache_refresh_bytes_amortized"] = int(refresh)
    base["cache_saved_bytes"] = int(hit_rows * spec.feature_dim * F32
                                    - refresh)
    return base


def p3_bytes(blocks: Sequence[TreeBlock], owner: np.ndarray,
             shard_of_block: Sequence[int], spec: ModelSpec,
             num_shards: int) -> dict:
    """P³: input-layer model parallelism over the feature dimension.

    Raw features never move (each shard holds a 1/N slice of *every*
    vertex). The innermost layer computes partial activations everywhere;
    the (N-1)/N remote share of hop-(k-1) hidden activations is exchanged
    (pull), and the matching gradients flow back (push) — 2× hidden bytes.
    Remaining layers run data-parallel on hop<k-1 vertices whose *hidden*
    embeddings are fetched like features (hidden_dim wide, not feature_dim).
    """
    k = spec.num_layers
    frac_remote = (num_shards - 1) / num_shards
    act = 0
    for blk, s in zip(blocks, shard_of_block):
        hk1 = np.unique(blk.hops[k - 1]) if k >= 1 else np.array([], np.int64)
        act += int(2 * hk1.size * spec.hidden_dim * F32 * frac_remote)
        # hops 0..k-2 hidden embeddings fetched when remote
        for h in range(0, k - 1):
            u = np.unique(blk.hops[h])
            act += int((owner[u] != s).sum()) * spec.hidden_dim * F32
    grad = int(2 * (num_shards - 1) / num_shards * spec.param_bytes) * num_shards
    return {"feature_bytes": 0, "grad_bytes": grad, "model_bytes": 0,
            "intermediate_bytes": act, "total": act + grad}


def lo_bytes(spec: ModelSpec, num_shards: int) -> dict:
    grad = int(2 * (num_shards - 1) / num_shards * spec.param_bytes) * num_shards
    return {"feature_bytes": 0, "grad_bytes": grad, "model_bytes": 0,
            "intermediate_bytes": 0, "total": grad}


def tiered_feature_bytes(tier1_rows: int, tier2_rows: int,
                         readahead_rows: int, upload_bytes: int,
                         feature_dim: int, iters: int,
                         host_gbps: float = 100.0,
                         disk_gbps: float = 12.0,
                         upload_gbps: float = 100.0) -> dict:
    """Per-tier byte/seconds accounting for the tiered FeatureStore
    (repro.features), amortized per iteration.

    ``tier1_rows``/``tier2_rows`` are the epoch's gather-path reads (host
    hot tier vs mmap disk), ``readahead_rows`` the tier-2 → tier-1
    promotion traffic at the epoch boundary, ``upload_bytes`` the
    plan-carried feature blocks shipped to the device — all straight from
    EpochStats. Bandwidths model DRAM reads, NVMe-class sequential mmap
    reads, and the host→device link; on the CPU container the modeled
    seconds are a decomposition aid (like :class:`Fabric`), not a wall
    prediction. The headline is ``disk_fraction``: with an exact covering
    readahead it approaches 0 and steady iteration time stays flat — the
    out-of-core flatness gate benchmarks/features.py enforces."""
    row = feature_dim * F32
    t1, t2, ra = tier1_rows * row, tier2_rows * row, readahead_rows * row
    it = max(int(iters), 1)
    sec = (t1 / (host_gbps * 1e9 / 8) + (t2 + ra) / (disk_gbps * 1e9 / 8)
           + upload_bytes / (upload_gbps * 1e9 / 8))
    gathered = t1 + t2
    return {"tier1_bytes": int(t1), "tier2_bytes": int(t2),
            "readahead_bytes": int(ra), "upload_bytes": int(upload_bytes),
            "tier1_bytes_per_iter": int(t1 / it),
            "tier2_bytes_per_iter": int(t2 / it),
            "upload_bytes_per_iter": int(upload_bytes / it),
            "disk_fraction": (t2 + ra) / max(gathered + ra, 1),
            "modeled_seconds_per_iter": sec / it}


# ---------------------------------------------------------------------------
# The α ratio (Fig. 5)
# ---------------------------------------------------------------------------

def alpha_ratio(remote_rows_per_iter: int, feature_dim: int,
                param_bytes: int) -> float:
    """α = remote-fetched feature bytes per iteration / model parameter bytes.
    α ≫ 1 is the regime where feature-centric training wins (Fig. 5:
    13.4 … 2368.1)."""
    return remote_rows_per_iter * feature_dim * F32 / max(param_bytes, 1)


def alpha_ratio_cached(miss_rows_per_iter: int, feature_dim: int,
                       param_bytes: int, refresh_rows: int = 0,
                       iters_per_refresh: int = 1) -> float:
    """Cache-adjusted α: only miss bytes (plus amortized cache refresh
    traffic) still cross the fabric per iteration. The gap between
    :func:`alpha_ratio` and this value is the regime shift the cache buys —
    with a covering budget, effective α approaches the refresh term alone."""
    eff_rows = miss_rows_per_iter + refresh_rows / max(int(iters_per_refresh),
                                                       1)
    return eff_rows * feature_dim * F32 / max(param_bytes, 1)
