"""Iteration planner: strategy -> device-ready IterationPlan.

The planner is the host-side half of LeapGNN — the paper's system name; its
title says "HopGNN" and this repo keeps ``hopgnn`` as the strategy key.
Sampling and bookkeeping run on CPU in DGL too. It consumes a training-strategy name plus the mini-batch
and emits rectangular numpy arrays the device engine executes without any
dynamic shapes:

  * ``model_centric`` — DGL baseline: one step, no redistribution; every
    shard fetches the (deduplicated) remote features of its whole subgraph.
  * ``hopgnn``        — §5.1 micrograph training: redistribution by home
    server, N rotating time steps, gradient accumulation. Pre-gathering
    (§5.2) and merging (§5.3) are orthogonal switches.
  * ``lo``            — locality-optimized baseline (§7.9): home-grouped,
    one step, no migration — fast but biased batches.

The *naive feature-centric* strategy of §3.2 is reproduced in
:mod:`repro.core.comm_model` as byte accounting only: its numerics equal
model-centric training (it computes the same subgraphs, just elsewhere), and
its defining cost — shipping model + activations every hop — has no SPMD
realization worth building (parameters are already replicated; see
DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import Executor
from typing import Literal, Optional, Sequence

import numpy as np

from repro.graph.sampler import TreeBlock, sample_tree_block
from repro.graph.structs import CSRGraph
from repro.obs import trace as _obs_trace
from repro.core.micrograph import (
    AssignmentMatrix, hopgnn_assignment, lo_assignment,
    model_centric_assignment,
)
from repro.core.pregather import (GatherPlan, PlanOverflow, build_gather_plan,
                                  split_local_touched,
                                  stream_workspace_indices, workspace_indices)

Strategy = Literal["model_centric", "hopgnn", "lo"]


def _pmap(executor: Optional[Executor], fn, items: list,
          label: Optional[str] = None) -> list:
    """Map ``fn`` over ``items``, fanning out on ``executor`` when given.

    The planner's per-(shard, step) work is numpy-heavy (sampling, dedup,
    searchsorted translation) and releases the GIL, so a small thread pool
    gives real multi-core planning without pickling graph structures.
    With ``label`` and tracing enabled, each item is recorded as a span on
    whichever thread runs it — the planner-pool fan-out shows up as its
    own Perfetto lanes."""
    if label is not None and _obs_trace.is_enabled():
        inner = fn

        def fn(item, _inner=inner, _label=label):  # noqa: F811
            with _obs_trace.span(_label):
                return _inner(item)
    if executor is None or len(items) <= 1:
        return [fn(x) for x in items]
    return list(executor.map(fn, items))


@dataclasses.dataclass
class IterationPlan:
    """Device-ready arrays (all stacked over the shard axis 0) + accounting.

    Workspace layout on shard s: rows [0, local_rows) are the local feature
    shard; rows [local_rows + p*r_max + j] hold the j-th pre-gathered row
    from peer p. In per-step mode the remote region is rebuilt each step
    from ``step_req``.
    """

    # --- static config ---
    num_shards: int
    num_steps: int
    fanout: int
    num_layers: int
    pregather: bool
    local_rows: int
    r_max: int
    batch_pad: int           # padded roots per (shard, step)
    global_batch: int        # true total roots (loss normalization)

    # --- device arrays ---
    req: np.ndarray                      # (N, P, r_max) int32 (pregather) or
    step_req: Optional[np.ndarray]       # (N, T, P, r_max) int32 (per-step)
    hop_idx: list                        # [h]: (N, T, batch_pad * f**h) int32
    labels: np.ndarray                   # (N, T, batch_pad) int32
    weights: np.ndarray                  # (N, T, batch_pad) f32

    # --- host accounting (exact, unpadded) ---
    remote_rows_exact: int               # deduped remote feature rows fetched
    remote_rows_nodedup: int             # without §5.2 dedup (per-step uniq)
    total_rows: int                      # all feature rows touched (tree, dup)
    unique_rows: int                     # deduped rows touched
    step_unique_rows: int                # Σ per-(shard,step) unique rows
    true_counts: np.ndarray              # (T, N) roots per (step, shard)
    assignment: AssignmentMatrix

    # --- remote-feature cache (repro.cache; defaults = cache off) ---
    c_max: int = 0                       # cached workspace region height
    cache_version: int = -1              # CacheStore version planned against
    cache_hit_rows: int = 0              # deduped remote rows served locally
    remote_ids: Optional[list] = None    # per-shard deduped remote ids the
    #                                      iteration requested (hits+misses)
    #                                      — what a trailing LFU observes

    # --- async pipeline (repro.train.pipeline; None = not committed) ---
    committed: Optional[dict] = None     # {"dev": device-resident
    #                                      device_args tree, "denom": f32
    #                                      scalar} uploaded ahead of time by
    #                                      the plan double-buffer thread;
    #                                      the engine's prepare fast path
    #                                      uses it verbatim

    # --- streamed feature path (repro.features; tiered FeatureStore) ---
    streamed: bool = False               # features ride in the plan, not in
    #                                      a device-resident table
    l_max: int = 0                       # compacted touched-local region
    #                                      height (budgeted like r_max)
    feat_local: Optional[np.ndarray] = None   # (N, l_max, d) touched local
    feat_fetch: Optional[np.ndarray] = None   # (N, P, r_max, d) miss rows,
    #                                      gathered host-side through the
    #                                      store's tier chain
    tier_stats: Optional[dict] = None    # per-tier rows/bytes this plan's
    #                                      host gathers resolved through

    # --- provenance (repro.resilience; None outside the Trainer) ---
    epoch_it: Optional[tuple] = None     # (epoch, it) this plan was built
    #                                      for — attached by build_plan so
    #                                      background failures and comm
    #                                      faults carry their origin

    def miss_rate(self) -> float:
        """Remote fraction of unique feature rows (paper Fig. 14)."""
        return self.remote_rows_exact / max(self.unique_rows, 1)

    def cache_hit_rate(self) -> float:
        """Of the deduped remote rows this iteration needs, the fraction
        served from the resident cache instead of the fabric."""
        denom = self.cache_hit_rows + self.remote_rows_exact
        return self.cache_hit_rows / max(denom, 1)

    def miss_rate_per_request(self) -> float:
        """Fig. 14's cache view: of all feature *requests* (one per unique
        vertex per (shard, step)), the fraction served remotely, without
        §5.2's cross-step dedup."""
        return self.remote_rows_nodedup / max(self.step_unique_rows, 1)

    def device_args(self):
        """The pytree handed to the device engine."""
        if self.streamed:
            # features travel WITH the plan; no req (nothing to exchange —
            # the host gather through the tier chain already happened)
            return dict(feat_local=self.feat_local,
                        feat_fetch=self.feat_fetch,
                        hop_idx=list(self.hop_idx), labels=self.labels,
                        weights=self.weights)
        return dict(req=self.req, step_req=self.step_req,
                    hop_idx=list(self.hop_idx), labels=self.labels,
                    weights=self.weights)


def _pad_tree_block(blk: TreeBlock, batch_pad: int,
                    pad_vertex: int) -> TreeBlock:
    """Pad a sampled block to ``batch_pad`` roots with a constant local
    vertex at every position of every padded subtree (weight-0 rows; see
    plan_iteration). True-root hops are shared, not copied."""
    k = blk.batch_size
    if k == batch_pad:
        return blk
    f = blk.fanout
    hops = [np.concatenate(
        [ids, np.full((batch_pad - k) * f ** h, pad_vertex, ids.dtype
                      if ids.size else np.int64)])
        for h, ids in enumerate(blk.hops)]
    return TreeBlock(hops=hops, fanout=f)


def _assignment_for(strategy: Strategy, roots_per_model, part,
                    override: Optional[AssignmentMatrix]) -> AssignmentMatrix:
    if override is not None:
        return override
    if strategy == "model_centric":
        return model_centric_assignment(roots_per_model)
    if strategy == "hopgnn":
        return hopgnn_assignment(roots_per_model, part)
    if strategy == "lo":
        return lo_assignment(roots_per_model, part)
    raise ValueError(f"unknown strategy {strategy!r}")


def plan_iteration(graph: CSRGraph,
                   labels: np.ndarray,
                   part: np.ndarray,
                   owner: np.ndarray,
                   local_idx: np.ndarray,
                   local_rows: int,
                   roots_per_model: Sequence[np.ndarray],
                   num_layers: int,
                   fanout: int,
                   strategy: Strategy = "hopgnn",
                   pregather: bool = True,
                   assignment: Optional[AssignmentMatrix] = None,
                   rng: Optional[np.random.Generator] = None,
                   sample_seed: Optional[int] = None,
                   batch_pad: Optional[int] = None,
                   r_max: Optional[int] = None,
                   c_max: Optional[int] = None,
                   cache_index=None,
                   executor: Optional[Executor] = None,
                   feature_store=None,
                   l_max: Optional[int] = None) -> IterationPlan:
    """Compile one training iteration into an IterationPlan.

    ``sample_seed`` switches to stateless per-root-deterministic sampling:
    the tree below each root depends only on (root, seed), so two plans with
    the same roots and seed — regardless of strategy — train *identical*
    micrographs. This is the gradient-parity (accuracy fidelity) invariant.

    ``executor``: optional thread pool the per-(shard, step) sampling and
    per-shard index translation fan out on (the Trainer passes its planning
    pool). Requires ``sample_seed`` for the sampling fan-out — a shared
    stateful ``rng`` is not thread-safe, so with ``rng`` sampling stays
    serial and only the translation parallelizes. Results are independent
    of the executor (same blocks, same arrays, deterministic order).

    ``cache_index``: resident remote-feature cache (repro.cache.CacheIndex);
    needed remote ids split into cache hits (read from the device-resident
    cached region) and misses (shipped via all_to_all). ``c_max`` is the
    shape *budget* for the cached region — the plan's actual cached height
    always equals the index's own padded ``c_max``; a budget smaller than
    that raises :class:`PlanOverflow` so repro.train's ShapeBudget can
    re-bucket explicitly (the compile-once contract extended to cache
    growth).

    ``feature_store``: a repro.features.FeatureStore. A *resident* store is
    equivalent to the classic dense table and planning is unchanged. A
    *tiered* store switches the plan to **streamed** mode: no device table
    exists, so the iteration's needed feature rows are host-gathered here
    through the store's tier chain (hot tier → mmap disk) into per-plan
    blocks — a compacted ``(N, l_max, d)`` touched-local region plus the
    ``(N, P, r_max, d)`` miss rows — and the workspace indices target
    ``[local_compact | cached | fetched]``. ``l_max`` budgets the compacted
    region exactly like ``r_max`` budgets fetches (PlanOverflow on
    overflow). Streamed mode requires ``pregather=True`` (per-step
    exchanges presume a device-resident table to serve from).
    """
    if cache_index is not None and c_max is not None \
            and cache_index.c_max > c_max:
        raise PlanOverflow("c_max", int(cache_index.c_max), int(c_max))
    streamed = feature_store is not None and not feature_store.resident
    if streamed and not pregather:
        raise ValueError("streamed feature plans (tiered FeatureStore) "
                         "require pregather=True — the per-step exchange "
                         "serves from a device-resident table")
    if sample_seed is None:
        rng = rng or np.random.default_rng(0)
    n = len(roots_per_model)
    if strategy == "lo":
        # LO samples only within the local partition (that *is* the bias
        # the paper measures in §7.9): drop cross-partition edges so every
        # sampled neighbor — hence every feature — is local.
        from repro.graph.partition import drop_cross_edges
        graph = drop_cross_edges(graph, part)
    amat = _assignment_for(strategy, [np.asarray(r, np.int64)
                                      for r in roots_per_model], part, assignment)
    T = amat.num_steps

    # Padding roots must add no phantom remote traffic: each (shard, step)
    # block is sampled over its *true* roots only and then padded with a
    # constant local vertex at every tree position (not with the pad
    # vertex's real sampled neighborhood, which could be remote). The
    # stateless sampler makes a root's subtree independent of its batch
    # position, so true-root trees are unchanged; padded positions carry
    # weight 0 and never touch the loss. This also makes planned remote
    # requests a pure function of (roots, seed) — what the repro.cache
    # epoch prefetcher predicts.
    pad_vertex = np.zeros(n, np.int64)
    for s in range(n):
        loc = np.nonzero(owner == s)[0]
        pad_vertex[s] = loc[0] if loc.size else 0

    counts = amat.root_counts()                      # (T, N)
    if batch_pad is None:
        batch_pad = max(1, int(counts.max()))
    if counts.max() > batch_pad:
        raise PlanOverflow("batch_pad", int(counts.max()), int(batch_pad))

    # ---- sample one TreeBlock per (shard, step), pad with local rows ----
    lab_arr = np.zeros((n, T, batch_pad), np.int32)
    w_arr = np.zeros((n, T, batch_pad), np.float32)
    jobs = []                                   # (s, t, true_roots, k)
    for s in range(n):
        for t in range(T):
            roots = amat.roots_at(s, t)
            k = roots.size
            if k:
                lab_arr[s, t, :k] = labels[roots]
                w_arr[s, t, :k] = 1.0
            jobs.append((s, t, roots, k))

    sample_exec = executor if sample_seed is not None else None
    blks = _pmap(sample_exec,
                 lambda j: sample_tree_block(graph, j[2], num_layers, fanout,
                                             rng=rng, seed=sample_seed),
                 jobs, label="plan.sample")
    blocks: list[list[TreeBlock]] = [[None] * T for _ in range(n)]  # [s][t]
    true_root_blocks: list[TreeBlock] = []      # unpadded, for accounting
    for (s, t, _, k), blk in zip(jobs, blks):
        blocks[s][t] = _pad_tree_block(blk, batch_pad, pad_vertex[s])
        if k:
            true_root_blocks.append(blk)

    # ---- gather plans ----
    def shard_needed(s: int, ts: Sequence[int]) -> np.ndarray:
        ids = [blocks[s][t].all_ids() for t in ts]
        return np.concatenate(ids) if ids else np.zeros(0, np.int64)

    hop_sizes = [batch_pad * fanout ** h for h in range(num_layers + 1)]
    hop_idx = [np.zeros((n, T, sz), np.int32) for sz in hop_sizes]

    if pregather:
        needed = [shard_needed(s, range(T)) for s in range(n)]
        if streamed:
            local_ids, l_max_eff = split_local_touched(needed, owner, l_max)
            plan = build_gather_plan(needed, owner, local_idx, n, l_max_eff,
                                     r_max, cache=cache_index)
        else:
            local_ids, l_max_eff = None, 0
            plan = build_gather_plan(needed, owner, local_idx, n, local_rows,
                                     r_max, cache=cache_index)
        req, step_req = plan.req, None
        r_max_eff = plan.r_max
        c_max_eff = plan.c_max

        def translate_shard(s: int) -> None:
            # writes land in disjoint (s, t) slices — thread-safe fan-out
            for t in range(T):
                widx = (stream_workspace_indices(blocks[s][t].hops, s,
                                                 owner, local_ids[s], plan)
                        if streamed else
                        workspace_indices(blocks[s][t].hops, s, owner,
                                          local_idx, plan))
                for h in range(num_layers + 1):
                    hop_idx[h][s, t] = widx[h]

        _pmap(executor, translate_shard, list(range(n)),
              label="plan.translate")
        remote_exact = plan.remote_rows_exact()
        cache_hit_rows = plan.cache_hit_rows()
        # only trailing-LFU observation consumes remote_ids; don't tax the
        # cache-off planning hot path with the copies
        remote_ids = ([plan.slot_map.shard_ids(s).copy() for s in range(n)]
                      if cache_index is not None else None)
        if streamed:
            feat_local, feat_fetch, tier_stats = _stream_features(
                feature_store, plan, local_ids, local_idx, l_max_eff, n)
        else:
            feat_local = feat_fetch = tier_stats = None
    else:
        # per-step exchange: dedup within a step only — redundant fetches
        # across steps remain (that is exactly what §5.2 eliminates). A
        # resident cache still dedups across steps implicitly: a cached
        # vertex is a hit at *every* step that touches it.
        step_plans = _pmap(
            executor,
            lambda t: build_gather_plan([shard_needed(s, [t])
                                         for s in range(n)],
                                        owner, local_idx, n, local_rows,
                                        r_max, cache=cache_index),
            list(range(T)), label="plan.step_gather")
        r_max_eff = r_max or max(p.r_max for p in step_plans)
        c_max_eff = step_plans[0].c_max if step_plans else 0
        if any(p.req_count.max() > r_max_eff for p in step_plans):
            raise PlanOverflow(
                "r_max", int(max(p.req_count.max() for p in step_plans)),
                int(r_max_eff))
        step_req = np.zeros((n, T, n, r_max_eff), np.int32)

        def translate_step(t: int) -> None:
            p = step_plans[t]
            if p.r_max != r_max_eff:   # rebuild with the common r_max
                p = build_gather_plan([shard_needed(s, [t]) for s in range(n)],
                                      owner, local_idx, n, local_rows,
                                      r_max_eff, cache=cache_index)
                step_plans[t] = p
            step_req[:, t] = p.req
            for s in range(n):
                widx = workspace_indices(blocks[s][t].hops, s, owner,
                                         local_idx, p)
                for h in range(num_layers + 1):
                    hop_idx[h][s, t] = widx[h]

        _pmap(executor, translate_step, list(range(T)),
              label="plan.translate")
        req = np.zeros((n, n, r_max_eff), np.int32)  # unused in per-step mode
        l_max_eff = 0
        feat_local = feat_fetch = tier_stats = None
        remote_exact = sum(p.remote_rows_exact() for p in step_plans)
        cache_hit_rows = sum(p.cache_hit_rows() for p in step_plans)
        remote_ids = ([
            np.unique(np.concatenate(
                [p.slot_map.shard_ids(s) for p in step_plans]
                or [np.zeros(0, np.int64)]))
            for s in range(n)] if cache_index is not None else None)

    # ---- accounting over true (unpadded) roots ----
    total_rows = sum(b.num_feature_rows() for b in true_root_blocks)
    uniq_all: list[np.ndarray] = []
    remote_nodedup = 0
    step_unique = 0
    for s in range(n):
        per_step_ids = []
        for t in range(T):
            roots = amat.roots_at(s, t)
            if roots.size == 0:
                continue
            ids = blocks[s][t].select(np.arange(roots.size)).all_ids()
            per_step_ids.append(ids)
        if per_step_ids:
            allids = np.concatenate(per_step_ids)
            uniq_all.append(np.unique(allids))
            for ids in per_step_ids:
                u = np.unique(ids)
                step_unique += u.size
                remote_nodedup += int((owner[u] != s).sum())
    unique_rows = int(sum(u.size for u in uniq_all))

    return IterationPlan(
        num_shards=n, num_steps=T, fanout=fanout, num_layers=num_layers,
        pregather=pregather, local_rows=local_rows, r_max=r_max_eff,
        batch_pad=batch_pad,
        global_batch=int(sum(np.asarray(r).size for r in roots_per_model)),
        req=req, step_req=step_req, hop_idx=hop_idx, labels=lab_arr,
        weights=w_arr,
        remote_rows_exact=remote_exact, remote_rows_nodedup=remote_nodedup,
        total_rows=total_rows, unique_rows=unique_rows,
        step_unique_rows=step_unique,
        true_counts=counts, assignment=amat,
        c_max=c_max_eff,
        cache_version=(cache_index.version if cache_index is not None
                       else -1),
        cache_hit_rows=cache_hit_rows, remote_ids=remote_ids,
        streamed=streamed, l_max=l_max_eff,
        feat_local=feat_local, feat_fetch=feat_fetch, tier_stats=tier_stats)


def _stream_features(store, plan: GatherPlan, local_ids: list, local_idx,
                     l_max: int, n: int):
    """Host-gather a streamed plan's feature blocks through the store's
    tier chain. Padded rows stay zero (padded slots are never read — the
    same contract as padded request slots in the exchange path)."""
    d = store.feature_dim
    snap = store.stats.snapshot()
    feat_local = np.zeros((n, l_max, d), store.dtype)
    for s in range(n):
        k = int(local_ids[s].size)
        if k:
            feat_local[s, :k] = store.gather(s, local_idx[local_ids[s]])
    feat_fetch = np.zeros((n, n, plan.r_max, d), store.dtype)
    cnt = plan.req_count
    for p in range(n):
        segs = [(s, int(cnt[s, p])) for s in range(n) if cnt[s, p]]
        if not segs:
            continue
        # one tier-chain gather per OWNING shard: all requesting shards'
        # misses from peer p are batched (better hot-tier locality, one
        # counted gather)
        cat = np.concatenate([plan.req[s, p, :c] for s, c in segs]
                             ).astype(np.int64)
        rows = store.gather(p, cat)
        off = 0
        for s, c in segs:
            feat_fetch[s, p, :c] = rows[off:off + c]
            off += c
    delta = store.stats.delta(snap)
    rb = store.row_bytes
    tier_stats = dict(tier1_rows=int(delta.t1_rows),
                      tier2_rows=int(delta.t2_rows),
                      tier1_bytes=int(delta.t1_rows) * rb,
                      tier2_bytes=int(delta.t2_rows) * rb,
                      upload_bytes=int(feat_local.nbytes
                                       + feat_fetch.nbytes))
    return feat_local, feat_fetch, tier_stats


# ===========================================================================
# Online inference (repro.serve): batched forward plan, no training state
# ===========================================================================

@dataclasses.dataclass
class InferencePlan:
    """One serving micro-batch, planned host-side for the compiled forward.

    The workspace layout is ``[cached | fetched]``: the server's hot
    feature rows (a repro.cache CacheStore, height ``c_max``) followed by
    the batch's remaining unique rows, host-gathered through the feature
    store's tier chain. ``hop_idx[h]`` indexes that workspace for every
    tree position of hop h. The fetched-region *height* is not fixed here —
    positions only ever point below ``c_max + fetch_ids.size``, so the
    server pads the gather buffer to its ShapeBudget rung (``u_max``)
    without re-planning (unlike training, there is no exchange array whose
    shape the planner must commit to).
    """

    nodes: np.ndarray            # (k,) true requested vertices, caller order
    batch_pad: int               # padded root count (pow2 serve rung)
    fanout: int
    c_max: int                   # cached-region height the plan indexes into
    cache_version: int           # CacheIndex.version guarded at dispatch
    hop_idx: list                # [h]: (batch_pad * fanout**h,) int32
    fetch_ids: np.ndarray        # sorted unique global ids to host-gather
    cache_hit_rows: int          # unique rows served from the cached region
    touched: np.ndarray          # sorted unique ids of the TRUE trees
    touched_counts: np.ndarray   # aligned multiplicities (admission signal)

    @property
    def num_layers(self) -> int:
        return len(self.hop_idx) - 1


def plan_inference(graph: CSRGraph, nodes: np.ndarray, num_layers: int,
                   fanout: int, *, sample_seed: int,
                   batch_pad: Optional[int] = None,
                   cache_index=None,
                   pad_vertex: int = 0) -> InferencePlan:
    """Plan one serving micro-batch: sample, dedup, translate.

    Bit-parity contract with the offline eval path (repro.train's
    ``Trainer.evaluate``): the stateless sampler makes each root's tree a
    pure function of ``(root, sample_seed)`` — independent of batch
    composition — and the forward is row-wise per root, so the logits of a
    served vertex equal the offline ``take_global``-path forward's exactly,
    no matter how the micro-batcher packed it. Padding roots (``pad_vertex``
    trees filling the rung) are computed and discarded.

    ``cache_index`` splits unique ids into hot rows (already device-resident
    in the serve cache, slot < c_max) and ``fetch_ids`` misses; indices are
    translated against the ``[cached | fetched]`` layout in one searchsorted
    pass — the same SlotMap idiom as the training GatherPlan.
    """
    nodes = np.asarray(nodes, np.int64).ravel()
    k = int(nodes.size)
    if batch_pad is None:
        batch_pad = max(k, 1)
    if k > batch_pad:
        raise PlanOverflow("batch_pad", k, int(batch_pad))
    blk = sample_tree_block(graph, nodes, num_layers, fanout,
                            seed=sample_seed)
    touched, touched_counts = np.unique(blk.all_ids(), return_counts=True)
    blk = _pad_tree_block(blk, int(batch_pad), int(pad_vertex))
    uniq = blk.unique_ids()

    if cache_index is not None:
        hit, slots = cache_index.hit_split(0, uniq)
        c_max = int(cache_index.c_max)
        version = int(cache_index.version)
    else:
        hit = np.zeros(uniq.size, bool)
        slots = np.zeros(uniq.size, np.int64)
        c_max, version = 0, 0
    miss = ~hit
    fetch_ids = uniq[miss]
    # workspace position of uniq[i]: its cache slot on a hit, else c_max +
    # rank among the misses (fetched rows are uploaded in sorted-id order)
    wspos = np.where(hit, slots, c_max + np.cumsum(miss) - 1)
    hop_idx = [wspos[np.searchsorted(uniq, ids)].astype(np.int32)
               for ids in blk.hops]
    return InferencePlan(nodes=nodes, batch_pad=int(batch_pad),
                         fanout=int(fanout), c_max=c_max,
                         cache_version=version, hop_idx=hop_idx,
                         fetch_ids=fetch_ids,
                         cache_hit_rows=int(hit.sum()),
                         touched=touched, touched_counts=touched_counts)
