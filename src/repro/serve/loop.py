"""One batched-serving loop for every stack (repro.serve tentpole, part a).

Online inference is queue-shaped everywhere: requests arrive one at a
time, latency is measured per request, but the device wants micro-batches.
:class:`BatchingLoop` owns exactly that translation — a thread-safe FIFO
:class:`RequestQueue`, a dynamic micro-batcher that drains up to
``max_batch`` pending tickets (waiting at most ``max_wait_s`` for the
first), and per-request latency accounting — and delegates the model to a
``dispatch(tickets) -> results`` callable. The GNN server
(repro.serve.server) and the transformer prefill/decode driver
(repro.launch.serve.LLMServer) are both thin dispatch functions over this
one loop, which is what keeps their latency semantics and observability
identical.

Observability (repro.obs): the idle wait for work is a ``<name>.queue.wait``
span, each dispatch a ``<name>.batch`` span; the registry carries
``<name>.queue_depth`` / ``<name>.qps`` gauges, a ``<name>.latency_ms``
histogram (submit → result, the user-visible number), a
``<name>.queue_wait_ms`` histogram (submit → drain), and
``<name>.requests`` / ``<name>.batches`` / ``<name>.errors`` counters.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional, Sequence

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


class ServeShutdown(RuntimeError):
    """The serving loop stopped before this ticket was dispatched.

    Raised from ``ticket.wait()`` for every request still queued when
    :meth:`BatchingLoop.stop` gave up draining — an explicit answer
    instead of a silently-forever-pending ticket."""


class Ticket:
    """One pending request: payload in, result (or error) out."""

    __slots__ = ("payload", "t_submit", "t_drain", "t_done", "result",
                 "error", "via", "_done")

    def __init__(self, payload):
        self.payload = payload
        self.t_submit = time.perf_counter()
        self.t_drain = 0.0
        self.t_done = 0.0
        self.result = None
        self.error: Optional[BaseException] = None
        self.via = ""                  # serving tier that answered (server-set)
        self._done = threading.Event()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None):
        """Block until served; returns the result or raises the dispatch
        error. TimeoutError if the deadline passes first."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    def _finish(self, result=None, error: Optional[BaseException] = None):
        self.result = result
        self.error = error
        self.t_done = time.perf_counter()
        self._done.set()


class RequestQueue:
    """Thread-safe FIFO of tickets with a batching drain."""

    def __init__(self):
        self._q: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)

    def put(self, payload) -> Ticket:
        t = payload if isinstance(payload, Ticket) else Ticket(payload)
        with self._nonempty:
            self._q.append(t)
            self._nonempty.notify()
        return t

    def depth(self) -> int:
        return len(self._q)

    def drain(self, max_n: int, wait_s: float = 0.0) -> list:
        """Up to ``max_n`` tickets, FIFO. Blocks at most ``wait_s`` for the
        *first* ticket; once any are pending, takes what is there without
        further waiting — the dynamic-batching tradeoff (a bounded wait
        buys a fuller batch; an empty queue never stalls a ready one)."""
        with self._nonempty:
            if not self._q and wait_s > 0:
                self._nonempty.wait_for(lambda: bool(self._q), wait_s)
            out = []
            while self._q and len(out) < max_n:
                out.append(self._q.popleft())
        now = time.perf_counter()
        for t in out:
            t.t_drain = now
        return out


class BatchingLoop:
    """Dynamic micro-batcher around a model-specific ``dispatch``.

    ``dispatch(tickets)`` serves one drained micro-batch and returns the
    results aligned with ``tickets`` (it may also set ``ticket.via``).
    Drive the loop synchronously with :meth:`pump` (tests, benchmarks,
    offline drains) or in a background thread with :meth:`start`/
    :meth:`stop` (open-loop load). A dispatch exception fails that batch's
    tickets (each ``wait()`` re-raises it) and is counted, not swallowed.
    """

    def __init__(self, dispatch: Callable[[Sequence[Ticket]], Sequence],
                 *, max_batch: int = 64, max_wait_s: float = 0.002,
                 name: str = "serve", qps_window_s: float = 2.0,
                 drain_deadline_s: float = 30.0):
        self.dispatch = dispatch
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.drain_deadline_s = float(drain_deadline_s)
        self.name = name
        self.queue = RequestQueue()
        self.served = 0
        self.batches = 0
        self.errors = 0
        self._qps_window_s = float(qps_window_s)
        self._done_ts: collections.deque = collections.deque()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ------------------------------------------------------------------

    def submit(self, payload) -> Ticket:
        t = self.queue.put(payload)
        _metrics.set_gauge(f"{self.name}.queue_depth", self.queue.depth())
        return t

    def pump(self, wait_s: Optional[float] = None) -> int:
        """Serve one micro-batch; returns how many tickets it answered
        (0 if the queue stayed empty through the wait)."""
        with _trace.span(f"{self.name}.queue.wait",
                         depth=self.queue.depth()):
            tickets = self.queue.drain(
                self.max_batch,
                self.max_wait_s if wait_s is None else wait_s)
        _metrics.set_gauge(f"{self.name}.queue_depth", self.queue.depth())
        if not tickets:
            return 0
        try:
            with _trace.span(f"{self.name}.batch", n=len(tickets)):
                results = self.dispatch(tickets)
        except BaseException as e:                       # noqa: BLE001
            self.errors += 1
            _metrics.inc(f"{self.name}.errors")
            for t in tickets:
                t._finish(error=e)
            raise
        for t, r in zip(tickets, results):
            t._finish(result=r)
        self._account(tickets)
        return len(tickets)

    def _account(self, tickets) -> None:
        self.batches += 1
        self.served += len(tickets)
        _metrics.inc(f"{self.name}.requests", len(tickets))
        _metrics.inc(f"{self.name}.batches")
        now = time.perf_counter()
        for t in tickets:
            _metrics.observe(f"{self.name}.latency_ms",
                             1e3 * t.latency_s())
            _metrics.observe(f"{self.name}.queue_wait_ms",
                             1e3 * (t.t_drain - t.t_submit))
            self._done_ts.append(now)
        horizon = now - self._qps_window_s
        while self._done_ts and self._done_ts[0] < horizon:
            self._done_ts.popleft()
        span = now - self._done_ts[0] if len(self._done_ts) > 1 else 0.0
        qps = len(self._done_ts) / span if span > 0 else 0.0
        _metrics.set_gauge(f"{self.name}.qps", qps)

    # ------------------------------------------------------------------
    # Background serving (open-loop clients)
    # ------------------------------------------------------------------

    def start(self) -> "BatchingLoop":
        if self._thread is not None:
            raise RuntimeError("loop already started")
        self._stop.clear()

        def run():
            # the drain's condition variable wakes on submit, so a longer
            # idle wait costs no latency — it only bounds the empty-queue
            # spin rate
            while not self._stop.is_set():
                try:
                    self.pump(wait_s=0.05)
                except BaseException:                    # noqa: BLE001
                    # the batch's tickets already carry the error; the
                    # loop keeps serving later requests
                    continue

        self._thread = threading.Thread(target=run,
                                        name=f"{self.name}-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the background loop.

        With ``drain`` (default) waits up to ``drain_deadline_s`` (ctor
        parameter) for the queue to empty first. Any ticket still queued
        after the loop stops — drain disabled, deadline missed, or
        submitted during shutdown — is failed with :class:`ServeShutdown`
        so its ``wait()`` raises promptly instead of timing out."""
        if self._thread is None:
            return
        if drain:
            deadline = time.perf_counter() + self.drain_deadline_s
            while self.queue.depth() and time.perf_counter() < deadline:
                time.sleep(0.001)
        self._stop.set()
        self._thread.join(timeout=max(self.drain_deadline_s, 1.0))
        self._thread = None
        undrained = self.queue.drain(max_n=2**31, wait_s=0.0)
        if undrained:
            self.errors += len(undrained)
            _metrics.inc(f"{self.name}.shutdown_failed", len(undrained))
            err = ServeShutdown(
                f"{self.name}: loop stopped with {len(undrained)} "
                f"request(s) undrained (drain_deadline_s="
                f"{self.drain_deadline_s})")
            for t in undrained:
                t._finish(error=err)
        _metrics.set_gauge(f"{self.name}.queue_depth", self.queue.depth())

    def stats(self) -> dict:
        return {"served": self.served, "batches": self.batches,
                "errors": self.errors, "queue_depth": self.queue.depth()}
