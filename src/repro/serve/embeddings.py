"""Precomputed-embedding tier: offline full-graph forward, persisted.

The cold-vertex fast path. An offline pass runs every vertex through the
same compiled serving forward (same stateless ``sample_seed``, same
``plan_inference`` → ``get_compiled_inference`` pipeline the live server
uses) and persists the resulting logits table next to the training
checkpoints — ``<ckpt_dir>/embeddings/`` — with repro.checkpoint's
crash-atomic npz+manifest discipline. Serving a cold vertex then bypasses
sampling, feature gathering, and the device entirely: one table row.

Because the precompute IS the serving forward, table rows are bit-identical
to what a live fresh compute (and the offline eval path) would produce —
until the params move. The manifest therefore records ``params_step`` and
``sample_seed``; :func:`load_embeddings` refuses a snapshot whose stamp
disagrees with the server's unless explicitly allowed (the staleness
policy after fine-tuning is a ROADMAP follow-on — today the contract is
fail-loud, not serve-stale).
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core import get_compiled_inference, plan_inference
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.train.budget import next_bucket

EMB_SUBDIR = "embeddings"


def embeddings_dir(ckpt_dir) -> Path:
    return Path(ckpt_dir) / EMB_SUBDIR


def precompute_embeddings(graph, store, params, cfg, *, ckpt_dir,
                          sample_seed: int = 999, params_step: int = 0,
                          chunk: int = 256, keep: int = 2) -> Path:
    """Full-graph offline forward → ``<ckpt_dir>/embeddings/``.

    Runs all ``N`` vertices in ``chunk``-sized micro-batches through the
    compiled serving forward (one pow2 rung ⇒ one trace for the whole
    pass), gathering features through ``store``'s tier chain. Returns the
    snapshot directory. ``params_step`` should be the checkpoint step the
    params came from — it is the staleness stamp.
    """
    import jax.numpy as jnp

    n = int(graph.num_vertices)
    d = store.feature_dim
    batch_pad = next_bucket(min(chunk, n))
    fn = get_compiled_inference(cfg)
    empty = jnp.zeros((0, d), str(store.dtype))
    out = np.zeros((n, cfg.num_classes), np.float32)
    u_max = 0
    with _trace.span("serve.precompute", vertices=n):
        for lo in range(0, n, batch_pad):
            nodes = np.arange(lo, min(lo + batch_pad, n), dtype=np.int64)
            plan = plan_inference(graph, nodes, cfg.num_layers, cfg.fanout,
                                  sample_seed=sample_seed,
                                  batch_pad=batch_pad)
            u = int(plan.fetch_ids.size)
            # one fetch bucket for the whole pass (chunks are same-sized,
            # so the unique-row count is tightly banded)
            if u > u_max:
                u_max = next_bucket(int(u * 1.5))
            fetch = np.zeros((u_max, d), store.dtype)
            fetch[:u] = store.take_global(plan.fetch_ids)
            logits = fn(params, empty, jnp.asarray(fetch),
                        *[jnp.asarray(h) for h in plan.hop_idx])
            out[lo:lo + nodes.size] = np.asarray(logits)[:nodes.size]
    directory = embeddings_dir(ckpt_dir)
    extra = {"kind": "serve-embeddings", "num_vertices": n,
             "num_classes": int(cfg.num_classes),
             "sample_seed": int(sample_seed),
             "params_step": int(params_step), "model": cfg.model,
             "num_layers": int(cfg.num_layers), "fanout": int(cfg.fanout)}
    save_checkpoint(directory, params_step, {"logits": out}, extra=extra,
                    keep=keep)
    _metrics.inc("serve.precomputed_rows", n)
    return directory


class EmbeddingTable:
    """Loaded snapshot: ``(N, C)`` logits + its staleness stamp."""

    def __init__(self, logits: np.ndarray, extra: dict, step: int):
        self.logits = logits
        self.extra = extra
        self.step = int(step)

    @property
    def num_vertices(self) -> int:
        return int(self.logits.shape[0])

    def lookup(self, ids) -> np.ndarray:
        return self.logits[np.asarray(ids, np.int64)]


def load_embeddings(ckpt_dir, *, params_step: Optional[int] = None,
                    sample_seed: Optional[int] = None,
                    allow_stale: bool = False) -> EmbeddingTable:
    """Load the newest durable embedding snapshot under ``ckpt_dir``.

    With ``params_step``/``sample_seed`` given, a snapshot stamped
    differently raises ``ValueError`` (stale precomputed logits would
    silently break the served-equals-offline parity contract) unless
    ``allow_stale=True``.
    """
    directory = embeddings_dir(ckpt_dir)
    if latest_step(directory) is None:
        raise FileNotFoundError(f"no embedding snapshot under {directory}")
    tree, step, extra = load_checkpoint(
        directory, {"logits": np.zeros((0, 0), np.float32)})
    if not allow_stale:
        if params_step is not None \
                and int(extra.get("params_step", -1)) != int(params_step):
            raise ValueError(
                f"embedding snapshot is stale: precomputed at params_step="
                f"{extra.get('params_step')} but the server holds step "
                f"{params_step} (re-run precompute_embeddings, or pass "
                f"allow_stale=True to serve stale logits knowingly)")
        if sample_seed is not None \
                and int(extra.get("sample_seed", -1)) != int(sample_seed):
            raise ValueError(
                f"embedding snapshot sampled with seed "
                f"{extra.get('sample_seed')} != server seed {sample_seed}")
    return EmbeddingTable(np.asarray(tree["logits"]), extra, step)
