"""repro.serve — online inference: queue, dynamic micro-batcher, tiers.

One batched-serving loop (:class:`BatchingLoop`) shared by the GNN server
and the transformer driver (repro.launch.serve.LLMServer); a tiered GNN
prediction server (:class:`GNNServer`) whose fresh path reuses training's
plan → compiled-forward machinery and whose cold path reads a persisted
offline full-graph forward (:func:`precompute_embeddings`). Served
predictions are bit-identical to the offline eval forward; steady-state
serving never retraces after :meth:`GNNServer.warmup`.
"""
from repro.serve.loop import (BatchingLoop, RequestQueue,
                             ServeShutdown, Ticket)
from repro.serve.embeddings import (EmbeddingTable, embeddings_dir,
                                    load_embeddings, precompute_embeddings)
from repro.serve.server import GNNServer

__all__ = [
    "BatchingLoop", "RequestQueue", "ServeShutdown", "Ticket",
    "EmbeddingTable", "embeddings_dir", "load_embeddings",
    "precompute_embeddings",
    "GNNServer",
]
