"""Online GNN inference server (repro.serve tentpole, part b).

LeapGNN's feature-centric insight applied to serving: the model is tiny and
replicated, the features are the traffic — so the serving path is organized
around keeping feature bytes off the per-request critical path, in three
tiers per requested vertex:

  1. **Hot feature tier** — the repro.cache table doing double duty: an
     LFU over observed *request* frequencies (the roots of every
     micro-batch plus the tree rows fresh computes touch) admits the hot
     working set into a device-resident ``CacheStore``; fresh computes of
     frequently-requested vertices then upload only their cache-miss rows.
  2. **Precomputed-embedding tier** (repro.serve.embeddings) — cold
     vertices are answered from the offline full-graph forward's persisted
     logits table: no sampling, no gather, no device dispatch.
  3. **Fresh compute** — a dynamic micro-batch through the same
     ``plan_inference`` → ``get_compiled_inference`` pipeline training's
     eval uses: stateless sampling (``sample_seed=999``, the eval seed),
     unique-row dedup against the cache index, pow2 ``ShapeBudget`` serve
     rungs, one compiled program per rung. Served logits are bit-identical
     to the offline eval forward regardless of how requests were packed.

``mode="auto"`` routes a request fresh when its vertex sits in the hot set
(frequent vertices get current-params answers at cached-feature cost) and
precomputed otherwise; ``"fresh"``/``"precomputed"`` force one path.

Compile-once contract: :meth:`warmup` traces every serve rung once (and
seeds each rung's fetch bucket with headroom); steady-state serving then
retraces zero times — asserted in tests against the engine's shared trace
log, exactly like the training loop.

Request payloads: an ``int`` vertex id → ``(num_classes,)`` logits; a
``(u, v)`` pair → an edge score (dot of the endpoint logit vectors), both
endpoints resolved through the same tiers.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.cache import CacheStore, LFUPolicy, budget_rows
from repro.core import get_compiled_inference, plan_inference
from repro.core.distributed import infer_trace_count
from repro.features import FeatureStore
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.serve.embeddings import load_embeddings
from repro.serve.loop import BatchingLoop, Ticket
from repro.train.budget import ShapeBudget, next_bucket

EVAL_SAMPLE_SEED = 999     # repro.train Trainer.evaluate's stateless seed


class GNNServer:
    """Queue-fed, micro-batched, tiered GNN prediction server.

    ``store`` is a bound :class:`repro.features.FeatureStore` (resident or
    tiered/spilled — the read path is the same ``take_global`` chain
    training's planner streams through) or a classic ``(N, rows, d)``
    sharded table plus ``owner``/``local_idx`` to wrap one from.
    """

    def __init__(self, *, graph, params, cfg, store,
                 owner: Optional[np.ndarray] = None,
                 local_idx: Optional[np.ndarray] = None,
                 budget: Optional[ShapeBudget] = None,
                 max_batch: int = 64, max_wait_s: float = 0.002,
                 sample_seed: int = EVAL_SAMPLE_SEED,
                 cache_budget_bytes: int = 0,
                 cache_refresh_every: int = 16,
                 cache_decay: float = 0.5,
                 ckpt_dir=None, mode: str = "auto",
                 params_step: int = 0, allow_stale_embeddings: bool = False,
                 name: str = "serve"):
        if mode not in ("auto", "fresh", "precomputed"):
            raise ValueError(f"unknown serve mode {mode!r}")
        self.graph = graph
        self.params = params
        self.cfg = cfg
        if not isinstance(store, FeatureStore):
            arr = np.asarray(store)
            if arr.ndim == 2:          # dense (N, d) global table → 1 shard
                n = arr.shape[0]
                owner = np.zeros(n, np.int64) if owner is None else owner
                local_idx = (np.arange(n, dtype=np.int64)
                             if local_idx is None else local_idx)
                arr = arr[None]
            store = FeatureStore.from_array(arr, owner=owner,
                                            local_idx=local_idx)
        if store.owner is None or store.local_idx is None:
            raise ValueError("feature store must be bound "
                             "(owner/local_idx) — take_global is the "
                             "serving read path")
        self.store = store
        self.budget = budget if budget is not None else ShapeBudget()
        self.max_batch = int(max_batch)
        self.sample_seed = int(sample_seed)
        self.mode = mode
        self.name = name
        d = store.feature_dim

        # hot feature tier: single-view CacheStore pre-sized to its final
        # pow2 height, so enabling it never changes device shapes mid-serve
        self._cache_rows = budget_rows(cache_budget_bytes, d,
                                       store.dtype.itemsize)
        if self._cache_rows > 0:
            self.cache: Optional[CacheStore] = CacheStore(
                1, d, c_max=self._cache_rows, dtype=store.dtype)
            self.policy: Optional[LFUPolicy] = LFUPolicy(
                1, decay=cache_decay)
        else:
            self.cache = None
            self.policy = None
        self.cache_refresh_every = int(cache_refresh_every)
        self._cache_dev = None          # (c_max, d) slice, refreshed on install

        # precomputed tier (stamped; stale snapshots are refused)
        self.embeddings = None
        if ckpt_dir is not None:
            self.embeddings = load_embeddings(
                ckpt_dir, params_step=params_step,
                sample_seed=self.sample_seed,
                allow_stale=allow_stale_embeddings)
        if mode == "precomputed" and self.embeddings is None:
            raise ValueError("mode='precomputed' needs ckpt_dir with an "
                             "embedding snapshot")

        self._fn = get_compiled_inference(cfg)
        import jax.numpy as jnp
        self._empty_cache = jnp.zeros((0, d), str(store.dtype))
        self._jnp = jnp
        self.loop = BatchingLoop(self._dispatch, max_batch=max_batch,
                                 max_wait_s=max_wait_s, name=name)
        # stats
        self._dispatches = 0
        self.fresh_batches = 0
        self.fresh_requests = 0
        self.precomputed_hits = 0
        self.cache_hit_rows = 0
        self.fetch_rows = 0
        self.warm = False
        self._traces_at_warmup = infer_trace_count()

    # ------------------------------------------------------------------
    # Client surface
    # ------------------------------------------------------------------

    def submit(self, payload) -> Ticket:
        """Enqueue one request: ``int`` vertex → logits, ``(u, v)`` →
        edge score. Returns a :class:`Ticket` (``.wait()`` for the result).
        """
        return self.loop.submit(payload)

    def predict(self, nodes: Sequence[int], timeout: float = 120.0
                ) -> np.ndarray:
        """Synchronous convenience: serve ``nodes`` through the queue and
        micro-batcher (NOT one forced batch — packing is the batcher's)
        and return ``(len(nodes), num_classes)`` logits."""
        tickets = [self.submit(int(v)) for v in nodes]
        if self.loop._thread is None:
            deadline = time.perf_counter() + timeout
            while not all(t.done() for t in tickets):
                if time.perf_counter() > deadline:
                    raise TimeoutError("predict timed out")
                self.loop.pump(wait_s=0.0)
        return np.stack([t.wait(timeout) for t in tickets])

    def start(self) -> "GNNServer":
        self.loop.start()
        return self

    def stop(self, drain: bool = True) -> None:
        self.loop.stop(drain=drain)

    # ------------------------------------------------------------------
    # Warmup: trace every rung once, seed fetch buckets with headroom
    # ------------------------------------------------------------------

    def warmup(self, nodes: Optional[np.ndarray] = None) -> dict:
        """Compile every serve rung (pow2 ladder up to ``max_batch``) by
        dispatching one representative micro-batch per rung. ``nodes``
        (default: a deterministic spread of the graph's vertices) should
        resemble live traffic — each rung's fetch bucket is seeded from its
        probe × ``r_max_headroom``, which is what absorbs batch-to-batch
        unique-row variance without retracing."""
        n = int(self.graph.num_vertices)
        if nodes is None:
            nodes = np.linspace(0, n - 1, min(n, self.max_batch * 4),
                                dtype=np.int64)
        nodes = np.unique(np.asarray(nodes, np.int64))
        rungs, bp = [], 0
        while bp < next_bucket(self.max_batch, self.budget.min_batch_pad):
            bp = next_bucket(bp + 1, self.budget.min_batch_pad)
            rungs.append(bp)
        before = infer_trace_count()
        with _trace.span(f"{self.name}.warmup", rungs=len(rungs)):
            for bp in rungs:
                take = nodes[np.linspace(0, nodes.size - 1,
                                         min(bp, nodes.size),
                                         dtype=np.int64)]
                self._forward(take, record_stats=False)
        self.warm = True
        self._traces_at_warmup = infer_trace_count()
        return {"rungs": rungs,
                "traces": self._traces_at_warmup - before,
                "ladder": self.budget.serve_rungs()}

    @property
    def retraces_since_warmup(self) -> int:
        """Serving-forward traces after :meth:`warmup` — the steady-state
        compile-once gate (must be 0; CI-asserted)."""
        return infer_trace_count() - self._traces_at_warmup

    # ------------------------------------------------------------------
    # Dispatch (one drained micro-batch)
    # ------------------------------------------------------------------

    def _dispatch(self, tickets) -> list:
        vertices = []
        for t in tickets:
            if isinstance(t.payload, (int, np.integer)):
                vertices.append(int(t.payload))
            else:
                u, v = t.payload
                vertices.extend((int(u), int(v)))
        vertices = np.unique(np.asarray(vertices, np.int64))
        pre_mask = self._precomputed_mask(vertices)
        fresh = vertices[~pre_mask]
        pre = vertices[pre_mask]
        # request-frequency admission sees every root, whichever tier
        # answers it — that is what promotes a warming vertex from the
        # precomputed tier into the hot (fresh) set
        if self.policy is not None:
            self.policy.observe(0, vertices)
        logits = {}
        if pre.size:
            for v, row in zip(pre, self.embeddings.lookup(pre)):
                logits[int(v)] = row
            self.precomputed_hits += int(pre.size)
            _metrics.inc(f"{self.name}.precomputed_hits", int(pre.size))
        if fresh.size:
            for v, row in zip(fresh, self._forward(fresh)):
                logits[int(v)] = row
        self._dispatches += 1
        self._maybe_refresh_cache()
        pre_set = set(int(x) for x in pre)
        out = []
        for t in tickets:
            if isinstance(t.payload, (int, np.integer)):
                t.via = ("precomputed" if int(t.payload) in pre_set
                         else "fresh")
                out.append(logits[int(t.payload)])
            else:
                u, v = t.payload
                t.via = "edge"
                out.append(float(np.dot(logits[int(u)], logits[int(v)])))
        return out

    def _precomputed_mask(self, vertices: np.ndarray) -> np.ndarray:
        """Which requested vertices the precomputed tier answers."""
        if self.embeddings is None or self.mode == "fresh":
            return np.zeros(vertices.size, bool)
        if self.mode == "precomputed":
            return np.ones(vertices.size, bool)
        # auto: hot vertices (feature row admitted to the serve cache) go
        # fresh — current params at cached-feature cost; cold go precomputed
        if self.cache is None:
            return np.ones(vertices.size, bool)
        hot, _ = self.cache.index.hit_split(0, vertices)
        return ~hot

    def _forward(self, nodes: np.ndarray, record_stats: bool = True
                 ) -> np.ndarray:
        """Fresh compute for a deduped vertex set: plan → gather → device.
        Returns ``(len(nodes), num_classes)`` float32 logits."""
        jnp = self._jnp
        d = self.store.feature_dim
        with _trace.span(f"{self.name}.batch.build", n=int(nodes.size)):
            bp = self.budget.serve_batch_pad(int(nodes.size))
            cache_index = self.cache.index if self.cache is not None else None
            plan = plan_inference(self.graph, nodes, self.cfg.num_layers,
                                  self.cfg.fanout,
                                  sample_seed=self.sample_seed,
                                  batch_pad=bp, cache_index=cache_index)
            u = int(plan.fetch_ids.size)
            u_max = self.budget.serve_fetch_pad(bp, u)
            fetch = np.zeros((u_max, d), self.store.dtype)
            if u:
                fetch[:u] = self.store.take_global(plan.fetch_ids)
            if self.policy is not None:
                self.policy.observe(0, plan.touched, plan.touched_counts)
        with _trace.span(f"{self.name}.dispatch", batch_pad=bp, u_max=u_max,
                         c_max=plan.c_max):
            if self.cache is not None and plan.c_max:
                if plan.cache_version != self.cache.version:
                    raise RuntimeError(
                        f"stale serve plan: built against cache version "
                        f"{plan.cache_version}, store is at "
                        f"{self.cache.version}")
                cache_tab = self._cache_device()
            else:
                cache_tab = self._empty_cache
            dev = self._fn(self.params, cache_tab, jnp.asarray(fetch),
                           *[jnp.asarray(h) for h in plan.hop_idx])
        with _trace.span(f"{self.name}.sync"):
            logits = np.asarray(dev)[:nodes.size]
        if record_stats:
            self.fresh_batches += 1
            self.fresh_requests += int(nodes.size)
            self.cache_hit_rows += plan.cache_hit_rows
            self.fetch_rows += u
            _metrics.inc(f"{self.name}.cache_hit_rows", plan.cache_hit_rows)
            _metrics.inc(f"{self.name}.fetch_rows", u)
        return logits

    # ------------------------------------------------------------------
    # Hot-tier admission (request-frequency LFU → CacheStore install)
    # ------------------------------------------------------------------

    def _cache_device(self):
        if self._cache_dev is None:
            self._cache_dev = self.cache.device_table[0]
        return self._cache_dev

    def _maybe_refresh_cache(self) -> None:
        if self.cache is None:
            return
        # cadence counts *all* dispatches, not just fresh ones — a cold
        # (all-precomputed) workload must still admit its frequent roots,
        # or auto mode could never promote anything to the fresh tier
        if self._dispatches == 0 \
                or self._dispatches % self.cache_refresh_every:
            return
        sel = self.policy.select(0, self._cache_rows)
        if np.array_equal(sel, self.cache.index.ids[0]):
            return
        # install between micro-batches on the loop thread: plans and
        # installs are serialized, so no in-flight plan can go stale
        self.cache.install_from(self.store, [sel])
        self._cache_dev = None

    # ------------------------------------------------------------------

    def stats(self) -> dict:
        out = dict(self.loop.stats())
        out.update(fresh_batches=self.fresh_batches,
                   fresh_requests=self.fresh_requests,
                   precomputed_hits=self.precomputed_hits,
                   cache_hit_rows=self.cache_hit_rows,
                   fetch_rows=self.fetch_rows,
                   cache_installs=(self.cache.installs
                                   if self.cache is not None else 0),
                   cached_rows=(self.cache.rows_installed()
                                if self.cache is not None else 0),
                   retraces_since_warmup=self.retraces_since_warmup,
                   serve_rungs=self.budget.serve_rungs())
        return out
