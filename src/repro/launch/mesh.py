"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (device count is locked at first jax init; the
dry-run must set XLA_FLAGS *before* that).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 4, model: int = 2):
    """Small CPU mesh for integration tests (needs
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))
