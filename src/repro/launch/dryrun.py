import os
if __name__ == "__main__":
    # Script-only: force 512 placeholder host devices BEFORE jax backend
    # init. Must not run on import — tests import this module for
    # collective_bytes, and a process-wide XLA_FLAGS poisons every other
    # test's device count.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

This is how the distribution config is proven coherent without hardware:
``jax.jit(step, in_shardings=…).lower(**ShapeDtypeStructs).compile()`` runs
GSPMD partitioning and XLA compilation for the full production mesh on 512
placeholder host devices — sharding mismatches, compile-time OOMs and
unsupported collectives all surface here as hard failures.

Per combination we record ``memory_analysis()`` (proves it fits),
``cost_analysis()`` (FLOPs/bytes for §Roofline) and the collective-op byte
census parsed from the optimized HLO, into benchmarks/results/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (SHAPES, ARCH_IDS, get_config, input_specs,
                           shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.launch import sharding as shd
from repro.launch.train import make_train_step, pick_accum, pick_optimizer
from repro.models.transformer import (decode_step, forward, init_decode_state,
                                      init_params, prefill)
from repro.models.transformer.common import set_mesh_axes
from repro.models.transformer.model import scan_length, set_scan_unroll

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|u64|u32|s16|u16|"
                       r"s8|u8|pred)\[([0-9,]*)\]")
_ITEM = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
         "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
         "pred": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the optimized HLO.

    Documented approximation (DESIGN.md §5): for all-gather this counts the
    gathered output (upper-bounds per-link traffic); for reduce-scatter the
    scattered output (lower bound). Start/done async pairs are counted once
    (the -start op carries the shape)."""
    per_op: dict[str, int] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        total = 0
        for sm in _SHAPE_RE.finditer(shape_str):
            dtype, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _ITEM.get(dtype.split("[")[0][:4].rstrip("["), 4)
        per_op[op] = per_op.get(op, 0) + total
        count[op] = count.get(op, 0) + 1
    return {"bytes_by_op": per_op, "count_by_op": count,
            "total_bytes": sum(per_op.values())}


def _abstract(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                moe_dispatch: str | None = None,
                fsdp: bool = True,
                seq_shard: bool = True,
                accum: int | None = None,
                kv_tp_repeat: int = 1,
                remat_policy: str = "full",
                extra_tag: str = "") -> dict:
    """Lower + compile one (arch, shape, mesh) and return the record."""
    import dataclasses
    cfg = get_config(arch)
    if moe_dispatch and cfg.moe_num_experts:
        cfg = dataclasses.replace(cfg, moe_dispatch=moe_dispatch)
    if kv_tp_repeat > 1:
        cfg = dataclasses.replace(cfg, kv_tp_repeat=kv_tp_repeat)
    ok, reason = shape_applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "family": cfg.family, "tag": extra_tag}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh_axes(dp=shd.dp_axes(mesh), tp=("model",))
    from repro.models.transformer.model import (set_remat_policy,
                                                set_sequence_sharding)
    set_sequence_sharding(seq_shard)
    set_remat_policy(remat_policy)
    rec["seq_shard"] = seq_shard
    rec["remat_policy"] = remat_policy
    sh = SHAPES[shape_name]

    params_shape = _abstract(lambda: init_params(jax.random.PRNGKey(0), cfg))
    p_specs = shd.param_pspecs(params_shape, fsdp=fsdp)
    rec["fsdp"] = fsdp
    data_shape = input_specs(cfg, shape_name)

    t0 = time.perf_counter()

    def build_lowered():
        if sh.kind == "train":
            opt = pick_optimizer(cfg)
            opt_shape = _abstract(opt.init, params_shape)
            o_specs = shd.opt_pspecs(opt_shape, p_specs)
            b_specs = shd.batch_pspecs(cfg, mesh, data_shape)
            accum_eff = accum or pick_accum(cfg, sh.global_batch)
            rec["accum"] = accum_eff
            step = make_train_step(cfg, opt, accum=accum_eff)
            jitted = jax.jit(
                step,
                in_shardings=(shd.to_shardings(mesh, p_specs),
                              shd.to_shardings(mesh, o_specs),
                              shd.to_shardings(mesh, b_specs)),
                out_shardings=(shd.to_shardings(mesh, p_specs),
                               shd.to_shardings(mesh, o_specs), None))
            lowered = jitted.lower(params_shape, opt_shape, data_shape)
        elif sh.kind == "prefill":
            b_specs = shd.batch_pspecs(cfg, mesh, data_shape)

            def prefill_step(params, batch):
                from repro.models.transformer.model import (_head_matrix,
                                                            forward_hidden)
                x, _ = forward_hidden(params, cfg, batch)
                return x[:, -1] @ _head_matrix(params)
            jitted = jax.jit(
                prefill_step,
                in_shardings=(shd.to_shardings(mesh, p_specs),
                              shd.to_shardings(mesh, b_specs)))
            lowered = jitted.lower(params_shape, data_shape)
        else:  # decode
            B, S = sh.global_batch, sh.seq_len
            if cfg.family == "audio":
                De = cfg.encoder_d_model or cfg.d_model
                enc_shape = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, De), cfg.activation_dtype)
                state_shape = _abstract(
                    lambda p, e: init_decode_state(cfg, B, S, enc=e,
                                                   params=p),
                    params_shape, enc_shape)
            else:
                state_shape = _abstract(lambda: init_decode_state(cfg, B, S))
            s_specs = shd.decode_state_pspecs(cfg, mesh, state_shape)
            tok_spec = jax.sharding.PartitionSpec(
                shd.dp_for_batch(mesh, B))

            def serve_step(params, token, state):
                return decode_step(params, cfg, token, state)
            jitted = jax.jit(
                serve_step,
                in_shardings=(shd.to_shardings(mesh, p_specs),
                              jax.NamedSharding(mesh, tok_spec),
                              shd.to_shardings(mesh, s_specs)),
                out_shardings=(None, shd.to_shardings(mesh, s_specs)))
            lowered = jitted.lower(
                params_shape, jax.ShapeDtypeStruct((B,), jnp.int32),
                state_shape)
        return lowered

    # XLA counts while-loop bodies ONCE in cost_analysis; compile at
    # unroll=1 and unroll=2 and extrapolate: true = f1 + (L-1)·(f2-f1).
    L = scan_length(cfg)
    results = {}
    with mesh:
        for unroll in (1, 2):
            set_scan_unroll(unroll)
            try:
                compiled = build_lowered().compile()
            finally:
                set_scan_unroll(1)
            cost = compiled.cost_analysis()
            results[unroll] = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": collective_bytes(compiled.as_text()),
                "compiled": compiled,
            }
            if L <= 1:
                results[2] = results[1]
                break

    t1 = time.perf_counter()
    f1, f2 = results[1]["flops"], results[2]["flops"]
    b1, b2 = results[1]["bytes"], results[2]["bytes"]
    c1 = results[1]["coll"]["total_bytes"]
    c2 = results[2]["coll"]["total_bytes"]
    flops_true = f1 + max(0.0, f2 - f1) * (L - 1)
    bytes_true = b1 + max(0.0, b2 - b1) * (L - 1)
    coll_true = c1 + max(0, c2 - c1) * (L - 1)
    ops1 = results[1]["coll"]["bytes_by_op"]
    ops2 = results[2]["coll"]["bytes_by_op"]
    coll_by_op_true = {
        op: ops1.get(op, 0) + max(0, ops2.get(op, 0) - ops1.get(op, 0))
        * (L - 1)
        for op in set(ops1) | set(ops2)}

    compiled = results[1]["compiled"]
    mem = compiled.memory_analysis()
    rec.update(
        status="ok",
        compile_seconds=round(t1 - t0, 1),
        memory={k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")},
        scan_length=L,
        flops_hlo_raw=f1,
        flops=flops_true,
        bytes_accessed_raw=b1,
        bytes_accessed=bytes_true,
        collectives=results[1]["coll"],
        collective_bytes_total=coll_true,
        collective_bytes_by_op=coll_by_op_true,
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-dispatch", default=None,
                    help="override MoE dispatch mode (tokens|weights|auto)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="TP-only parameters (no data-axis sharding)")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable sequence-parallel carry sharding")
    ap.add_argument("--accum", type=int, default=None,
                    help="override gradient-accumulation microbatch count")
    ap.add_argument("--kv-tp-repeat", type=int, default=1,
                    help="KV-head replication factor for TP")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"],
                    help="per-layer checkpoint policy")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    combos = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for mp in meshes:
                    combos.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            combos.append((args.arch, args.shape, mp))

    n_ok = n_skip = n_fail = 0
    for arch, shape_name, mp in combos:
        tagsfx = f".{args.tag}" if args.tag else ""
        fname = RESULTS_DIR / (
            f"{arch}.{shape_name}.{'2x16x16' if mp else '16x16'}{tagsfx}.json")
        if args.skip_done and fname.exists():
            existing = json.loads(fname.read_text())
            if existing.get("status") in ("ok", "skipped"):
                print(f"[cached ] {fname.name}")
                n_ok += existing["status"] == "ok"
                n_skip += existing["status"] == "skipped"
                continue
        try:
            rec = lower_combo(arch, shape_name, mp,
                              moe_dispatch=args.moe_dispatch,
                              fsdp=not args.no_fsdp,
                              seq_shard=not args.no_seq_shard,
                              accum=args.accum,
                              kv_tp_repeat=args.kv_tp_repeat,
                              remat_policy=args.remat_policy,
                              extra_tag=args.tag)
        except Exception as e:                        # noqa: BLE001
            rec = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        fname.write_text(json.dumps(rec, indent=1))
        s = rec["status"]
        n_ok += s == "ok"
        n_skip += s == "skipped"
        n_fail += s == "failed"
        extra = (f" {rec.get('compile_seconds', '')}s "
                 f"flops={rec.get('flops', 0):.3g}" if s == "ok" else
                 rec.get("reason", rec.get("error", "")))
        print(f"[{s:7s}] {arch} × {shape_name} × "
              f"{'2x16x16' if mp else '16x16'}{extra}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
