import os
if __name__ == "__main__":
    # Script-only (see dryrun.py): never set XLA_FLAGS on plain import.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""GNN-side dry-run: lower + compile the LeapGNN shard_map iteration on the
production data mesh (256 shards single-pod / 512 two-pod).

The paper runs 4 GPU servers; this proves the SPMD engine's collectives
(request/feature all_to_all, gradient psum) partition for a pod-scale
`data` axis. Plan arrays are ShapeDtypeStruct stand-ins — no host planning
for 256 shards happens here (plans are per-iteration host work; their
device-side shapes are what the compiler needs).

    PYTHONPATH=src python -m repro.launch.dryrun_gnn [--multi-pod]
"""
import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import make_sharded_iteration
from repro.launch.dryrun import RESULTS_DIR, collective_bytes
from repro.models.gnn import GNNConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model", default="sage")
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--fanout", type=int, default=10)
    ap.add_argument("--feature-dim", type=int, default=600)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--batch-pad", type=int, default=8)
    ap.add_argument("--local-rows", type=int, default=16384)
    ap.add_argument("--r-max", type=int, default=2048)
    args = ap.parse_args()

    n = 512 if args.multi_pod else 256
    mesh = jax.make_mesh((n,), ("data",))
    cfg = GNNConfig(model=args.model, num_layers=args.layers,
                    hidden_dim=args.hidden, feature_dim=args.feature_dim,
                    num_classes=47, fanout=args.fanout)
    T = n                      # the full rotation: one step per shard
    f = args.fanout
    bp = args.batch_pad

    # abstract params
    from repro.models.gnn import init_gnn
    params = jax.eval_shape(lambda: init_gnn(jax.random.PRNGKey(0), cfg))

    table = jax.ShapeDtypeStruct((n, args.local_rows, args.feature_dim),
                                 jnp.float32)
    # no resident remote-feature cache in the dry-run: height-0 cached region
    cache = jax.ShapeDtypeStruct((n, 0, args.feature_dim), jnp.float32)
    dev = dict(
        req=jax.ShapeDtypeStruct((n, n, args.r_max), jnp.int32),
        step_req=None,
        hop_idx=[jax.ShapeDtypeStruct((n, T, bp * f ** h), jnp.int32)
                 for h in range(args.layers + 1)],
        labels=jax.ShapeDtypeStruct((n, T, bp), jnp.int32),
        weights=jax.ShapeDtypeStruct((n, T, bp), jnp.float32),
    )

    fn = make_sharded_iteration(cfg, pregather=True, mesh=mesh)
    denom = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = fn.lower(params, table, cache, dev, denom)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # older jax: one dict per computation
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    rec = {
        "kind": "hopgnn_gnn_iteration",
        "mesh": f"{n}x1(data)",
        "model": args.model,
        "status": "ok",
        "memory": {k: int(getattr(mem, k, 0)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes")},
        "flops": float(cost.get("flops", 0.0)),
        "collectives": coll,
    }
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out = RESULTS_DIR / f"hopgnn.{args.model}.{n}shards.json"
    out.write_text(json.dumps(rec, indent=1))
    print(f"[ok] hopgnn {args.model} iteration on {n}-shard mesh: "
          f"temp {mem.temp_size_in_bytes / 1e9:.2f} GB/dev, "
          f"collectives {coll['total_bytes'] / 1e9:.2f} GB "
          f"({coll['count_by_op']})")


if __name__ == "__main__":
    main()
