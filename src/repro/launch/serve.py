"""Batched serving driver: prefill a batch of prompts, then decode.

Small-scale runnable on CPU (smoke variants); on the production mesh the
same functions lower under the sharding rules (launch/dryrun.py proves it).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16

:class:`LLMServer` wraps :func:`generate` behind the same
``repro.serve.BatchingLoop`` the GNN server uses — one queue, one dynamic
micro-batcher, one set of latency metrics (``llm.latency_ms`` etc.) for
both stacks. Prompts are padded to pow2 (batch, seq) buckets so steady
traffic reuses a handful of compiled programs, mirroring the GNN side's
ShapeBudget rungs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import decode_step, init_params, prefill
from repro.models.transformer.config import ArchConfig

# one jitted decode step per (frozen, hashable) config — re-jitting inside
# generate() would retrace on every call, which the serving loop forbids
_STEP_CACHE: dict = {}


def _decode_fn(cfg: ArchConfig):
    fn = _STEP_CACHE.get(cfg)
    if fn is None:
        fn = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
        _STEP_CACHE[cfg] = fn
    return fn


def generate(params, cfg: ArchConfig, batch: dict, gen_tokens: int,
             max_seq: int, greedy: bool = True, seed: int = 0):
    """Prefill + autoregressive decode. Returns (B, gen_tokens) int32."""
    logits, state = prefill(params, cfg, batch, max_seq=max_seq)
    step = _decode_fn(cfg)
    key = jax.random.PRNGKey(seed)
    toks = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        toks.append(tok)
        logits, state = step(params, tok, state)
        logits = logits[:, : cfg.vocab_size]
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
    return jnp.stack(toks, axis=1)


class LLMServer:
    """Queue-fed token generation over the shared batched-serving loop.

    A request payload is a 1-D int32 prompt; the result is a
    ``(gen_tokens,)`` int32 array. Drained prompts are right-padded to a
    pow2 sequence bucket and stacked into a pow2 batch bucket, so the
    compiled prefill/decode programs are shared across steady traffic.
    (Token-level results for a short prompt padded into a longer bucket
    reflect the pad tokens — acceptable for this synthetic-token driver;
    the bit-parity serving contract lives on the GNN side.)
    """

    def __init__(self, params, cfg: ArchConfig, *, gen_tokens: int = 16,
                 max_batch: int = 8, max_wait_s: float = 0.002,
                 min_seq_pad: int = 8, greedy: bool = True, seed: int = 0,
                 name: str = "llm"):
        from repro.serve import BatchingLoop
        self.params = params
        self.cfg = cfg
        self.gen_tokens = int(gen_tokens)
        self.min_seq_pad = int(min_seq_pad)
        self.greedy = greedy
        self.seed = int(seed)
        self.loop = BatchingLoop(self._dispatch, max_batch=max_batch,
                                 max_wait_s=max_wait_s, name=name)

    def submit(self, prompt):
        return self.loop.submit(np.asarray(prompt, np.int32).ravel())

    def pump(self, wait_s=None) -> int:
        return self.loop.pump(wait_s=wait_s)

    def start(self) -> "LLMServer":
        self.loop.start()
        return self

    def stop(self, drain: bool = True) -> None:
        self.loop.stop(drain=drain)

    def _dispatch(self, tickets):
        from repro.train.budget import next_bucket
        prompts = [t.payload for t in tickets]
        bp = next_bucket(len(prompts), 1)
        sp = next_bucket(max(p.size for p in prompts), self.min_seq_pad)
        toks = np.zeros((bp, sp), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : p.size] = p
        batch = {"tokens": jnp.asarray(toks)}
        out = generate(self.params, self.cfg, batch, self.gen_tokens,
                       max_seq=sp + self.gen_tokens + 8,
                       greedy=self.greedy, seed=self.seed)
        out = np.asarray(out)
        return [out[i] for i in range(len(prompts))]

    def stats(self) -> dict:
        return self.loop.stats()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_variant
    from repro.data import make_batch

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, args.batch, args.prompt_len, seed=0)
    t0 = time.perf_counter()
    out = generate(params, cfg, batch, args.gen,
                   max_seq=args.prompt_len + args.gen + 8)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(out)[:, :12])


if __name__ == "__main__":
    main()
