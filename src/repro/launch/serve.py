"""Batched serving driver: prefill a batch of prompts, then decode.

Small-scale runnable on CPU (smoke variants); on the production mesh the
same functions lower under the sharding rules (launch/dryrun.py proves it).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import decode_step, init_params, prefill
from repro.models.transformer.config import ArchConfig


def generate(params, cfg: ArchConfig, batch: dict, gen_tokens: int,
             max_seq: int, greedy: bool = True, seed: int = 0):
    """Prefill + autoregressive decode. Returns (B, gen_tokens) int32."""
    logits, state = prefill(params, cfg, batch, max_seq=max_seq)
    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    key = jax.random.PRNGKey(seed)
    toks = []
    tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        toks.append(tok)
        logits, state = step(params, tok, state)
        logits = logits[:, : cfg.vocab_size]
        if greedy:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits).astype(jnp.int32)
    return jnp.stack(toks, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import get_config, smoke_variant
    from repro.data import make_batch

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, args.batch, args.prompt_len, seed=0)
    t0 = time.perf_counter()
    out = generate(params, cfg, batch, args.gen,
                   max_seq=args.prompt_len + args.gen + 8)
    dt = time.perf_counter() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(out)[:, :12])


if __name__ == "__main__":
    main()
