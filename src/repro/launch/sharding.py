"""GSPMD sharding policy for the assigned-architecture stack.

Parameters follow an FSDP × TP recipe (DESIGN.md §4):

* weight matrices shard their *input-feature* dim over ``data`` (ZeRO-3
  style; gathered at use, which bounds per-device parameter memory — a hard
  requirement for nemotron-4-340b) and their *output-feature* / head / ffn
  dim over ``model`` (Megatron TP);
* down-projections mirror that (model, data) so the TP collective pattern
  is the canonical all-reduce pair;
* under the multi-pod mesh, FSDP stays *within* a pod (axis ``data``) and
  parameters replicate across ``pod`` — gradient all-reduce is the only
  cross-pod collective.

Decode caches shard batch over dp and the 32k sequence (dense caches) over
``model`` — without seq-sharding a 96-layer 32k cache would not fit a v5e.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.models.transformer.config import ArchConfig

DATA, MODEL = "data", "model"


def dp_axes(mesh: Mesh):
    """Batch axes: ('pod', 'data') on a multi-pod mesh, else 'data'."""
    return ("pod", DATA) if "pod" in mesh.axis_names else (DATA,)


def dp_for_batch(mesh: Mesh, batch: int):
    """The dp axis spec for a batch dim of the given size, degrading to
    replication when the batch is too small to shard (long_500k has B=1)."""
    axes = dp_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if batch % n == 0:
        return axes if len(axes) > 1 else axes[0]
    if batch % mesh.shape[DATA] == 0:
        return DATA
    return None


# (containing key, leaf key) -> trailing-dims spec
_RULES: dict[tuple[str, str], tuple] = {
    # attention / generic linears (dicts with w/b)
    ("wq", "w"): (DATA, MODEL), ("wk", "w"): (DATA, MODEL),
    ("wv", "w"): (DATA, MODEL), ("wo", "w"): (MODEL, DATA),
    ("wq", "b"): (MODEL,), ("wk", "b"): (MODEL,), ("wv", "b"): (MODEL,),
    ("wo", "b"): (None,),
    # mlp
    ("wg", "w"): (DATA, MODEL), ("wu", "w"): (DATA, MODEL),
    ("wd", "w"): (MODEL, DATA),
    ("wg", "b"): (MODEL,), ("wu", "b"): (MODEL,), ("wd", "b"): (None,),
    # rwkv time-mix & channel-mix
    ("wr", "w"): (DATA, MODEL), ("wr", "b"): (MODEL,),
    ("ck", "w"): (DATA, MODEL), ("ck", "b"): (MODEL,),
    ("cr", "w"): (DATA, MODEL), ("cr", "b"): (MODEL,),
    ("cv", "w"): (MODEL, DATA), ("cv", "b"): (None,),
    ("w_lora_a", "w"): (DATA, None), ("w_lora_b", "w"): (None, DATA),
    # rglru
    ("w_in", "w"): (DATA, MODEL), ("w_in", "b"): (MODEL,),
    ("w_gate", "w"): (DATA, MODEL), ("w_gate", "b"): (MODEL,),
    ("wa", "w"): (DATA, MODEL), ("wa", "b"): (MODEL,),
    ("wi", "w"): (DATA, MODEL), ("wi", "b"): (MODEL,),
    ("w_out", "w"): (MODEL, DATA), ("w_out", "b"): (None,),
    # router / projections
    ("router", "w"): (DATA, None),
    ("patch_proj", "w"): (None, DATA), ("patch_proj", "b"): (None,),
}

# bare-array leaves keyed by their own name
_LEAF_RULES: dict[str, tuple] = {
    "embed": (MODEL, DATA),
    "head": (DATA, MODEL),
    "enc_pos": (None, None),
    "conv_w": (None, MODEL), "conv_b": (MODEL,),
    "lam": (MODEL,),
    "mu": (None, None), "mu_c": (None, None),
    "u": (None, None),
    "w_base": (None,),
    "gn_g": (None,), "gn_b": (None,),
    "g": (None,), "b": (None,),          # norms
    # MoE expert stacks (E, D, Fe) / (E, Fe, D): experts unsharded (60 ∤ 16),
    # FSDP on D, TP on Fe — matches the moe_forward "weights" constraint.
    "wg": (None, DATA, MODEL), "wu": (None, DATA, MODEL),
    "wd": (None, MODEL, DATA),
}


def _key_str(entry) -> str:
    if isinstance(entry, DictKey):
        return str(entry.key)
    if isinstance(entry, SequenceKey):
        return f"[{entry.idx}]"
    return str(entry)


def _spec_for(path, leaf, fsdp: bool = True) -> P:
    names = [_key_str(e) for e in path]
    leaf_name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    rule = _RULES.get((parent, leaf_name))
    if rule is None:
        rule = _LEAF_RULES.get(leaf_name)
    if rule is None and leaf_name in ("w", "b"):
        # generic linear under an unknown container: replicate
        rule = (None,) * (1 if leaf_name == "b" else 2)
    if rule is None:
        rule = ()
    if not fsdp:
        # TP-only: drop the data-axis (ZeRO-3) factor — parameters
        # replicate across data, eliminating per-microbatch all-gathers.
        rule = tuple(None if ax == DATA else ax for ax in rule)
    ndim = len(leaf.shape)
    if len(rule) > ndim:       # e.g. scalar under a rule — replicate
        rule = (None,) * ndim
    pad = (None,) * (ndim - len(rule))   # leading layer-stack axes
    return P(*(pad + tuple(rule)))


def param_pspecs(params_shape: Any, fsdp: bool = True) -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.

    ``fsdp=False`` → TP-only parameters (replicated over ``data``). §Perf
    iteration 1: for ≤~20 B-param archs, per-device params fit under pure
    TP, and dropping FSDP removes the per-microbatch parameter all-gather —
    the dominant collective in every train_4k baseline."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _spec_for(p, l, fsdp=fsdp), params_shape)


def opt_pspecs(opt_state_shape: Any, params_pspecs: Any) -> Any:
    """Optimizer state shards exactly like its parameter (ZeRO-1 via GSPMD);
    scalars (step) replicate."""
    def spec(leaf):
        return P()
    # AdamState(step, mu, nu): mu/nu mirror params
    cls = type(opt_state_shape)
    if hasattr(opt_state_shape, "mu"):
        return cls(step=P(), mu=params_pspecs, nu=params_pspecs)
    if hasattr(opt_state_shape, "momentum"):
        mom = params_pspecs if opt_state_shape.momentum is not None else None
        return cls(step=P(), momentum=mom)
    return jax.tree.map(spec, opt_state_shape)


# ---------------------------------------------------------------------------
# Decode-state sharding
# ---------------------------------------------------------------------------

def _kv_cache_pspec(dp, seq_shard: bool, stacked: bool):
    from repro.models.transformer.attention import KVCache
    lead = (None,) if stacked else ()
    seq_ax = MODEL if seq_shard else None
    return KVCache(
        k=P(*lead, dp, seq_ax, None, None),
        v=P(*lead, dp, seq_ax, None, None),
        pos=P(*((None,) * len(lead))) if lead else P())


def decode_state_pspecs(cfg: ArchConfig, mesh: Mesh, state_shape) -> Any:
    """Handcrafted per-family cache specs (DESIGN.md §4 sharding recipe)."""
    from repro.models.transformer.model import DecodeState
    from repro.models.transformer.rglru import RGLRUState
    from repro.models.transformer.rwkv6 import RWKVState
    from repro.models.transformer import encdec

    leaves = [x for x in jax.tree.leaves(state_shape) if x.ndim >= 2]
    batch = leaves[0].shape[1]          # every cache is (L/G, B, ...)
    dp = dp_for_batch(mesh, batch)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        # seq-shard the cache only when it is actually long (windowed caches
        # are small; replicating them avoids softmax cross-shard reductions)
        cache_len = state_shape.caches.k.shape[2]
        seq_shard = cache_len >= 8192
        return DecodeState(caches=_kv_cache_pspec(dp, seq_shard, True),
                           tail=None, enc=None)
    if fam == "ssm":
        return DecodeState(
            caches=RWKVState(s=P(None, dp, MODEL, None, None),
                             tm_x=P(None, dp, MODEL),
                             cm_x=P(None, dp, MODEL)),
            tail=None, enc=None)
    if fam == "hybrid":
        pat = tuple(cfg.block_pattern)

        def pos_spec(kind, stacked):
            if kind == "rec":
                lead = (None,) if stacked else ()
                return RGLRUState(h=P(*lead, dp, MODEL),
                                  conv=P(*lead, dp, None, MODEL))
            return _kv_cache_pspec(dp, seq_shard=False, stacked=stacked)
        groups = {"blocks": tuple(pos_spec(pat[j], True)
                                  for j in range(len(pat)))}
        tail = [pos_spec(pat[j % len(pat)], False)
                for j in range(len(state_shape.tail or []))]
        return DecodeState(caches=groups, tail=tail, enc=None)
    if fam == "audio":
        cache_len = state_shape.caches.self_kv.k.shape[2]
        return DecodeState(
            caches=encdec.DecLayerCache(
                self_kv=_kv_cache_pspec(dp, cache_len >= 8192, True),
                cross_k=P(None, dp, None, None, None),
                cross_v=P(None, dp, None, None, None)),
            tail=None, enc=P(dp, None, None))
    raise ValueError(fam)


def batch_pspecs(cfg: ArchConfig, mesh: Mesh, batch_shape: dict) -> dict:
    out = {}
    for k, v in batch_shape.items():
        dp = dp_for_batch(mesh, v.shape[0])
        out[k] = P(dp, *([None] * (len(v.shape) - 1)))
    return out


def to_shardings(mesh: Mesh, pspecs):
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        pspecs, is_leaf=lambda x: isinstance(x, P) or x is None)
