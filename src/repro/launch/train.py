"""Training step assembly for the assigned-architecture stack.

``make_train_step(cfg)`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with GSPMD shardings from
:mod:`repro.launch.sharding`. Gradient averaging over the data axes is
implicit (the loss is a global mean under jit's global view).

Run as a module for a real (small-scale) training loop:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.transformer import loss_fn
from repro.models.transformer.config import ArchConfig
from repro.optim import adamw, Optimizer


def pick_optimizer(cfg: ArchConfig, lr: float = 1e-4) -> Optimizer:
    """AdamW; bf16 moments above 100B params (nemotron HBM budget)."""
    big = cfg.param_count() > 100e9
    return adamw(lr, weight_decay=0.1, grad_clip=1.0,
                 state_dtype=jnp.bfloat16 if big else jnp.float32)


def pick_accum(cfg: ArchConfig, global_batch: int) -> int:
    """Gradient-accumulation microbatch count. A (B, S, D) activation at
    global batch 256 × 4k is ~150 GB/device for nemotron-340b — full-batch
    steps cannot fit; microbatching divides peak activation memory by the
    accumulation factor at zero extra FLOPs."""
    n = cfg.param_count()
    if n > 100e9:
        accum = 16
    elif n > 8e9:
        accum = 4
    else:
        return 1
    while global_batch % accum:
        accum //= 2
    return max(accum, 1)


def make_train_step(cfg: ArchConfig, opt: Optimizer, accum: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``accum`` > 1 splits the batch into microbatches and accumulates
    gradients in a *python-unrolled* loop (not lax.scan, so the dry-run's
    cost analysis counts every microbatch natively)."""
    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, parts), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, cfg, batch)
        else:
            def slice_mb(i):
                return jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:])[i], batch)
            loss = jnp.zeros(())
            parts = {"ce": jnp.zeros(()), "aux": jnp.zeros(())}
            grads = jax.tree.map(jnp.zeros_like, params)
            for i in range(accum):
                (l_i, p_i), g_i = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg, slice_mb(i))
                loss = loss + l_i / accum
                parts = {k: parts[k] + p_i[k] / accum for k in parts}
                grads = jax.tree.map(lambda a, b: a + b / accum, grads, g_i)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"]}
        return params, opt_state, metrics
    return train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke variant (CPU-sized)")
    args = ap.parse_args()

    from repro.configs import get_config, smoke_variant
    from repro.data import token_batches

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    opt = pick_optimizer(cfg, lr=3e-4)
    params = init_all(cfg)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))

    for i, batch in enumerate(token_batches(cfg, args.batch, args.seq,
                                            steps=args.steps, seed=0)):
        t0 = time.perf_counter()
        params, opt_state, m = step(params, opt_state, batch)
        loss = float(m["loss"])
        print(f"step {i:4d} loss {loss:.4f} "
              f"({time.perf_counter() - t0:.2f}s)")


def init_all(cfg: ArchConfig, seed: int = 0):
    from repro.models.transformer import init_params
    return init_params(jax.random.PRNGKey(seed), cfg)


if __name__ == "__main__":
    main()
