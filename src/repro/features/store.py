"""Tiered feature storage: one `FeatureStore` behind every feature consumer.

LeapGNN's premise is that features dominate and must stay put while models
move — so the feature side deserves a real storage hierarchy instead of a
bare host numpy array per shard. A :class:`FeatureStore` owns per-shard
feature rows across three tiers:

* **tier 0 — device cache** (repro.cache): the padded ``(N, c_max, d)``
  remote-row table that already lives next to the compiled iteration. The
  store does not manage it directly — the planner's hit/miss split does —
  but the store is what the cache *refreshes from*
  (:meth:`repro.cache.store.CacheStore.install_from`).
* **tier 1 — host hot tier**: per-shard row caches in host RAM, sized by
  ``host_budget_bytes`` and replaced wholesale by exact next-epoch
  readahead (:meth:`readahead`). On a real accelerator deployment these
  buffers would be pinned for DMA; on the CPU container they are ordinary
  aligned numpy arrays.
* **tier 2 — memory-mapped disk**: one ``.npy`` per shard
  (:func:`spill_shards`), read through ``np.memmap`` fancy indexing. Rows
  absent from the hot tier are served from here (counted — per-tier traffic
  is first-class accounting, see :class:`TierStats`).

Residency contract: ``host_budget_bytes <= 0`` means *unlimited* host
memory — the store is **resident**, :meth:`as_dense` returns the full
``(N, local_rows, d)`` table, and every consumer behaves exactly as it did
before this subsystem existed (the bit-identical back-compat gate). Any
positive budget makes the store **tiered**: the dense table is never
materialized, the Trainer switches to the streamed engine path
(repro.core.distributed ``streamed=True``), and reads resolve hot-tier →
backing.

Thread contract: :meth:`gather`/:meth:`take_global` may be called
concurrently from the Trainer's plan-prefetch thread and its cache thread
(counters are lock-protected); :meth:`readahead` installs only at epoch
boundaries, when no plan is in flight, so hot-tier swaps never race reads.
"""
from __future__ import annotations

import dataclasses
import threading
from pathlib import Path
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class TierStats:
    """Cumulative row counters per tier (bytes = rows × row_bytes).

    ``t1_rows``/``t2_rows`` count *gather* traffic (hot-tier hits vs
    backing/disk reads on the miss path); ``readahead_rows`` counts the
    tier-2 → tier-1 promotion traffic separately so steady-state miss
    accounting is not polluted by the prefetch that prevents the misses.
    """

    t1_rows: int = 0
    t2_rows: int = 0
    readahead_rows: int = 0
    gathers: int = 0

    def snapshot(self) -> tuple:
        return (self.t1_rows, self.t2_rows, self.readahead_rows, self.gathers)

    def delta(self, since: tuple) -> "TierStats":
        return TierStats(t1_rows=self.t1_rows - since[0],
                         t2_rows=self.t2_rows - since[1],
                         readahead_rows=self.readahead_rows - since[2],
                         gathers=self.gathers - since[3])


class _HotTier:
    """Per-shard wholesale-replacement row cache (tier 1).

    Same lookup idiom as the device cache's :class:`CacheIndex`: a sorted
    array of resident backing-row indices plus an aligned buffer, so a hit
    test is one ``searchsorted``. Wholesale replacement (no eviction
    bookkeeping) is the right shape here because the epoch prefetcher's
    *exact* forecast replaces the whole resident set at epoch boundaries —
    the same design the device cache uses.
    """

    def __init__(self, feature_dim: int, dtype):
        self.ids = np.zeros(0, np.int64)           # sorted backing rows
        self.buf = np.zeros((0, feature_dim), dtype)
        self.installs = 0

    @property
    def rows(self) -> int:
        return int(self.ids.size)

    def hit_split(self, query: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(hit_mask, buffer_positions) for backing-row indices ``query``."""
        query = np.asarray(query, np.int64)
        hit = np.zeros(query.size, bool)
        pos = np.zeros(query.size, np.int64)
        if self.ids.size and query.size:
            p = np.searchsorted(self.ids, query)
            ok = (p < self.ids.size) & \
                (self.ids[np.minimum(p, self.ids.size - 1)] == query)
            hit = ok
            pos[ok] = p[ok]
        return hit, pos

    def install(self, rows_idx: np.ndarray, rows: np.ndarray) -> None:
        order = np.argsort(rows_idx)
        self.ids = np.asarray(rows_idx, np.int64)[order]
        self.buf = np.ascontiguousarray(rows[order])
        self.installs += 1


class FeatureStore:
    """One tiered store for the per-shard feature rows of a training run.

    ``backing[s]`` is shard s's ``(local_rows, d)`` feature rows — a plain
    ndarray (in-RAM tier 2, used by tests and resident stores) or an
    ``np.memmap`` over a per-shard ``.npy`` (the out-of-core tier 2). All
    shards are rectangular (padded to the same ``local_rows``), mirroring
    the SPMD table layout the engine always used.
    """

    def __init__(self, backing: Sequence[np.ndarray], *,
                 host_budget_bytes: int = 0,
                 owner: Optional[np.ndarray] = None,
                 local_idx: Optional[np.ndarray] = None):
        assert len(backing) > 0 and all(b.ndim == 2 for b in backing)
        rows0, d0 = backing[0].shape
        assert all(b.shape == (rows0, d0) for b in backing), \
            "backing shards must be rectangular"
        self._backing = list(backing)
        self.num_shards = len(backing)
        self.local_rows = int(rows0)
        self.feature_dim = int(d0)
        self.dtype = np.dtype(backing[0].dtype)
        self.host_budget_bytes = int(host_budget_bytes)
        self.owner = None if owner is None else np.asarray(owner)
        self.local_idx = None if local_idx is None else np.asarray(local_idx)
        self.stats = TierStats()
        self._lock = threading.Lock()
        self._dense: Optional[np.ndarray] = None
        # residency: non-positive budget = unlimited host RAM = the
        # pre-refactor world (dense table, no hot tier, no streaming)
        self.resident = self.host_budget_bytes <= 0
        if self.resident:
            self.hot_rows = self.local_rows
            self._hot = None
        else:
            self.hot_rows = min(
                self.local_rows,
                self.host_budget_bytes
                // max(self.num_shards * self.row_bytes, 1))
            self._hot = [_HotTier(self.feature_dim, self.dtype)
                         for _ in range(self.num_shards)]

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def from_array(cls, table: np.ndarray, host_budget_bytes: int = 0,
                   owner: Optional[np.ndarray] = None,
                   local_idx: Optional[np.ndarray] = None) -> "FeatureStore":
        """Back-compat constructor: wrap the classic ``(N, local_rows, d)``
        sharded table. With the default budget the store is resident and
        every consumer behaves bit-identically to the pre-store code."""
        table = np.asarray(table)
        assert table.ndim == 3, f"expected (N, rows, d), got {table.shape}"
        st = cls([table[s] for s in range(table.shape[0])],
                 host_budget_bytes=host_budget_bytes, owner=owner,
                 local_idx=local_idx)
        if st.resident:
            st._dense = table
        return st

    @classmethod
    def build(cls, features: np.ndarray, part: np.ndarray, num_shards: int,
              directory: Optional[str] = None, host_budget_bytes: int = 0,
              chunk_rows: int = 1 << 16) -> "FeatureStore":
        """Shard ``features`` by ``part`` into a store.

        With ``directory`` the per-shard rows are scattered *chunked* into
        on-disk ``.npy`` memmaps (:func:`spill_shards`) — peak host memory
        is one chunk, so graphs larger than host RAM shard fine as long as
        ``features`` itself is a memmap (repro.graph.synthetic's spill
        writer). Without it the shards live in RAM (the classic
        ``shard_features`` layout)."""
        from repro.graph.partition import local_index_map
        owner, local_idx, max_sz = local_index_map(
            np.asarray(part), num_shards)
        if directory is None:
            table = np.zeros((num_shards, max_sz, features.shape[1]),
                             features.dtype)
            table[owner, local_idx] = features
            return cls.from_array(table, host_budget_bytes=host_budget_bytes,
                                  owner=owner, local_idx=local_idx)
        backing = spill_shards(features, owner, local_idx, num_shards,
                               max_sz, directory, chunk_rows=chunk_rows)
        return cls(backing, host_budget_bytes=host_budget_bytes,
                   owner=owner, local_idx=local_idx)

    def bind(self, owner: np.ndarray, local_idx: np.ndarray) -> "FeatureStore":
        """Attach the global-id → (owner, local row) maps
        (:meth:`take_global` needs them). Returns self for chaining."""
        self.owner = np.asarray(owner)
        self.local_idx = np.asarray(local_idx)
        return self

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def row_bytes(self) -> int:
        return self.feature_dim * self.dtype.itemsize

    @property
    def spilled(self) -> bool:
        """True when tier 2 is disk-backed (any shard is a memmap)."""
        return any(isinstance(b, np.memmap) for b in self._backing)

    def backing_nbytes(self) -> int:
        return self.num_shards * self.local_rows * self.row_bytes

    def hot_nbytes(self) -> int:
        if self._hot is None:
            return self.backing_nbytes()
        return int(sum(h.buf.nbytes for h in self._hot))

    def hot_installed_rows(self, shard: int) -> int:
        return 0 if self._hot is None else self._hot[shard].rows

    def as_dense(self) -> np.ndarray:
        """The full ``(N, local_rows, d)`` host table — resident stores
        only (a tiered store materializing it would defeat the budget)."""
        if not self.resident:
            raise ValueError(
                f"store is tiered (host_budget_bytes="
                f"{self.host_budget_bytes}); the dense table would exceed "
                "the host budget — use gather()/take_global()")
        if self._dense is None:
            self._dense = np.stack([np.asarray(b) for b in self._backing])
        return self._dense

    # ------------------------------------------------------------------
    # The read path (tier 1 -> tier 2)
    # ------------------------------------------------------------------

    def gather(self, shard: int, rows_idx: np.ndarray) -> np.ndarray:
        """Feature rows ``rows_idx`` (backing-row indices) of ``shard``,
        resolved hot-tier first, backing (disk) on miss. Duplicate indices
        are allowed and each occurrence is counted (they are real reads)."""
        rows_idx = np.asarray(rows_idx, np.int64)
        out = np.empty((rows_idx.size, self.feature_dim), self.dtype)
        if rows_idx.size == 0:
            return out
        if self._hot is None:                      # resident: all host RAM
            out[:] = self._backing[shard][rows_idx]
            with self._lock:
                self.stats.t1_rows += int(rows_idx.size)
                self.stats.gathers += 1
            return out
        hot = self._hot[shard]
        hit, pos = hot.hit_split(rows_idx)
        n_hit = int(hit.sum())
        if n_hit:
            out[hit] = hot.buf[pos[hit]]
        if n_hit < rows_idx.size:
            miss = ~hit
            out[miss] = self._backing[shard][rows_idx[miss]]
        with self._lock:
            self.stats.t1_rows += n_hit
            self.stats.t2_rows += int(rows_idx.size) - n_hit
            self.stats.gathers += 1
        return out

    def take_global(self, ids: np.ndarray) -> np.ndarray:
        """Feature rows by *global vertex id*, resolved through the tier
        chain — the store-backed replacement for the old
        ``table[owner[ids], local_idx[ids]]`` host-copy gather."""
        if self.owner is None or self.local_idx is None:
            raise ValueError("take_global needs bound owner/local_idx maps "
                             "(FeatureStore.bind)")
        ids = np.asarray(ids, np.int64)
        out = np.empty((ids.size, self.feature_dim), self.dtype)
        if ids.size == 0:
            return out
        own = self.owner[ids]
        for s in np.unique(own):
            m = own == s
            out[m] = self.gather(int(s), self.local_idx[ids[m]])
        return out

    # ------------------------------------------------------------------
    # Readahead (tier 2 -> tier 1)
    # ------------------------------------------------------------------

    def readahead(self, shard: int, rows_idx: np.ndarray,
                  counts: Optional[np.ndarray] = None) -> int:
        """Promote rows into the hot tier ahead of their reads.

        ``rows_idx`` are the backing rows a forecast says will be touched
        (the epoch prefetcher's exact next-epoch sets); ``counts`` ranks
        them when the set outgrows the budget — highest expected read count
        first, ties broken by row index for determinism. The install is
        wholesale (see :class:`_HotTier`). Returns rows installed."""
        if self._hot is None:
            return 0
        rows_idx = np.asarray(rows_idx, np.int64)
        if counts is not None:
            # counts are positional: sort rows (carrying counts along) and
            # require uniqueness — np.unique alone would silently misalign
            counts = np.asarray(counts)
            if counts.shape != rows_idx.shape:
                raise ValueError("counts must align with rows_idx")
            order = np.argsort(rows_idx, kind="stable")
            rows_idx, counts = rows_idx[order], counts[order]
            if rows_idx.size and np.any(np.diff(rows_idx) == 0):
                raise ValueError("rows_idx must be unique when ranked by "
                                 "counts")
            if rows_idx.size > self.hot_rows:
                keep = np.lexsort((rows_idx, -counts))[:self.hot_rows]
                rows_idx = np.sort(rows_idx[keep])
        else:
            rows_idx = np.unique(rows_idx)[:self.hot_rows]
        rows = np.empty((rows_idx.size, self.feature_dim), self.dtype)
        if rows_idx.size:
            rows[:] = self._backing[shard][rows_idx]
        self._hot[shard].install(rows_idx, rows)
        with self._lock:
            self.stats.readahead_rows += int(rows_idx.size)
        return int(rows_idx.size)


def spill_shards(features: np.ndarray, owner: np.ndarray,
                 local_idx: np.ndarray, num_shards: int, max_sz: int,
                 directory: str, chunk_rows: int = 1 << 16
                 ) -> list[np.memmap]:
    """Scatter global feature rows into per-shard ``.npy`` memmaps.

    The scatter walks ``features`` in row chunks, so peak host memory is
    one chunk even when both the source (a spilled synthetic dataset) and
    the shards are disk-backed. Shards are padded to ``max_sz`` rows
    (rectangular, zero padding) exactly like ``shard_features``. The
    returned memmaps are reopened read-only — the store never writes
    tier 2 after construction (features are static during training)."""
    from numpy.lib.format import open_memmap
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    d = int(features.shape[1])
    paths = [directory / f"shard_{s:03d}.npy" for s in range(num_shards)]
    mms = [open_memmap(p, mode="w+", dtype=features.dtype,
                       shape=(max_sz, d)) for p in paths]
    n = int(features.shape[0])
    for a in range(0, n, chunk_rows):
        b = min(a + chunk_rows, n)
        chunk = np.asarray(features[a:b])
        own = owner[a:b]
        for s in np.unique(own):
            m = own == s
            mms[s][local_idx[a:b][m]] = chunk[m]
    for mm in mms:
        mm.flush()
    del mms
    return [np.load(p, mmap_mode="r") for p in paths]
