"""Tiered feature storage: one `FeatureStore` behind every feature consumer.

LeapGNN's premise is that features dominate and must stay put while models
move — so the feature side deserves a real storage hierarchy instead of a
bare host numpy array per shard. A :class:`FeatureStore` owns per-shard
feature rows across three tiers:

* **tier 0 — device cache** (repro.cache): the padded ``(N, c_max, d)``
  remote-row table that already lives next to the compiled iteration. The
  store does not manage it directly — the planner's hit/miss split does —
  but the store is what the cache *refreshes from*
  (:meth:`repro.cache.store.CacheStore.install_from`).
* **tier 1 — host hot tier**: per-shard row caches in host RAM, sized by
  ``host_budget_bytes`` and replaced wholesale by exact next-epoch
  readahead (:meth:`readahead`). On a real accelerator deployment these
  buffers would be pinned for DMA; on the CPU container they are ordinary
  aligned numpy arrays.
* **tier 2 — memory-mapped disk**: one ``.npy`` per shard
  (:func:`spill_shards`), read through ``np.memmap`` fancy indexing. Rows
  absent from the hot tier are served from here (counted — per-tier traffic
  is first-class accounting, see :class:`TierStats`).

Residency contract: ``host_budget_bytes <= 0`` means *unlimited* host
memory — the store is **resident**, :meth:`as_dense` returns the full
``(N, local_rows, d)`` table, and every consumer behaves exactly as it did
before this subsystem existed (the bit-identical back-compat gate). Any
positive budget makes the store **tiered**: the dense table is never
materialized, the Trainer switches to the streamed engine path
(repro.core.distributed ``streamed=True``), and reads resolve hot-tier →
backing.

Thread contract: :meth:`gather`/:meth:`take_global` may be called
concurrently from the Trainer's plan-prefetch thread and its cache thread
(counters are lock-protected); :meth:`readahead` installs only at epoch
boundaries, when no plan is in flight, so hot-tier swaps never race reads.

Integrity (repro.resilience): the disk tier can rot — a flipped bit in a
mmap row would otherwise train silently on garbage. With checksums enabled
(:meth:`enable_checksums`; on by default for spilled stores built via
:meth:`build`), every backing shard carries a per-chunk crc32 computed at
spill time. Reads off the backing tier (gather misses, readahead
promotion) verify the chunks they touch — memoized, so each chunk pays the
scan once until marked suspect — and a mismatch *quarantines* the chunk:
its rows are re-gathered from the authoritative source feature array
(:meth:`attach_source`), held as an in-RAM patch that shadows the rotten
disk region, and counted in :class:`TierStats`. No source attached means
the corruption is unrecoverable and reads raise
:class:`CorruptFeatureError` instead of returning garbage.
"""
from __future__ import annotations

import dataclasses
import threading
import zlib
from pathlib import Path
from typing import Optional, Sequence

import numpy as np

from repro.obs import metrics as _obs_metrics


class CorruptFeatureError(RuntimeError):
    """Backing-tier checksum mismatch with no authoritative source to
    repair from (or a source that itself disagrees with the checksum)."""

    def __init__(self, msg: str):
        super().__init__(msg)
        self.site = "store"      # degradation-ladder routing key


@dataclasses.dataclass
class TierStats:
    """Cumulative row counters per tier (bytes = rows × row_bytes).

    ``t1_rows``/``t2_rows`` count *gather* traffic (hot-tier hits vs
    backing/disk reads on the miss path); ``readahead_rows`` counts the
    tier-2 → tier-1 promotion traffic separately so steady-state miss
    accounting is not polluted by the prefetch that prevents the misses.
    """

    t1_rows: int = 0
    t2_rows: int = 0
    readahead_rows: int = 0
    gathers: int = 0
    # integrity counters (repro.resilience) — deliberately NOT part of
    # snapshot()/delta(), which are positional and consumed by the
    # streamed-engine byte accounting
    crc_checked_chunks: int = 0
    crc_failures: int = 0
    repaired_rows: int = 0

    def snapshot(self) -> tuple:
        return (self.t1_rows, self.t2_rows, self.readahead_rows, self.gathers)

    def delta(self, since: tuple) -> "TierStats":
        return TierStats(t1_rows=self.t1_rows - since[0],
                         t2_rows=self.t2_rows - since[1],
                         readahead_rows=self.readahead_rows - since[2],
                         gathers=self.gathers - since[3])


class _HotTier:
    """Per-shard wholesale-replacement row cache (tier 1).

    Same lookup idiom as the device cache's :class:`CacheIndex`: a sorted
    array of resident backing-row indices plus an aligned buffer, so a hit
    test is one ``searchsorted``. Wholesale replacement (no eviction
    bookkeeping) is the right shape here because the epoch prefetcher's
    *exact* forecast replaces the whole resident set at epoch boundaries —
    the same design the device cache uses.
    """

    def __init__(self, feature_dim: int, dtype):
        self.ids = np.zeros(0, np.int64)           # sorted backing rows
        self.buf = np.zeros((0, feature_dim), dtype)
        self.installs = 0

    @property
    def rows(self) -> int:
        return int(self.ids.size)

    def hit_split(self, query: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(hit_mask, buffer_positions) for backing-row indices ``query``."""
        query = np.asarray(query, np.int64)
        hit = np.zeros(query.size, bool)
        pos = np.zeros(query.size, np.int64)
        if self.ids.size and query.size:
            p = np.searchsorted(self.ids, query)
            ok = (p < self.ids.size) & \
                (self.ids[np.minimum(p, self.ids.size - 1)] == query)
            hit = ok
            pos[ok] = p[ok]
        return hit, pos

    def install(self, rows_idx: np.ndarray, rows: np.ndarray) -> None:
        order = np.argsort(rows_idx)
        self.ids = np.asarray(rows_idx, np.int64)[order]
        self.buf = np.ascontiguousarray(rows[order])
        self.installs += 1


class FeatureStore:
    """One tiered store for the per-shard feature rows of a training run.

    ``backing[s]`` is shard s's ``(local_rows, d)`` feature rows — a plain
    ndarray (in-RAM tier 2, used by tests and resident stores) or an
    ``np.memmap`` over a per-shard ``.npy`` (the out-of-core tier 2). All
    shards are rectangular (padded to the same ``local_rows``), mirroring
    the SPMD table layout the engine always used.
    """

    def __init__(self, backing: Sequence[np.ndarray], *,
                 host_budget_bytes: int = 0,
                 owner: Optional[np.ndarray] = None,
                 local_idx: Optional[np.ndarray] = None):
        assert len(backing) > 0 and all(b.ndim == 2 for b in backing)
        rows0, d0 = backing[0].shape
        assert all(b.shape == (rows0, d0) for b in backing), \
            "backing shards must be rectangular"
        self._backing = list(backing)
        self.num_shards = len(backing)
        self.local_rows = int(rows0)
        self.feature_dim = int(d0)
        self.dtype = np.dtype(backing[0].dtype)
        self.host_budget_bytes = int(host_budget_bytes)
        self.owner = None if owner is None else np.asarray(owner)
        self.local_idx = None if local_idx is None else np.asarray(local_idx)
        self.stats = TierStats()
        self._lock = threading.Lock()
        self._dense: Optional[np.ndarray] = None
        # --- integrity state (enable_checksums) ---
        self.crc_chunk_rows = 0
        self._crc: Optional[list[np.ndarray]] = None   # per-shard chunk crcs
        self._verified: list[set] = [set() for _ in range(self.num_shards)]
        self._patches: list[dict] = [{} for _ in range(self.num_shards)]
        self._source: Optional[np.ndarray] = None      # global feature rows
        self._shard_globals_cache: dict[int, np.ndarray] = {}
        self._crc_lock = threading.RLock()
        self._hot_bypass = False
        # residency: non-positive budget = unlimited host RAM = the
        # pre-refactor world (dense table, no hot tier, no streaming)
        self.resident = self.host_budget_bytes <= 0
        if self.resident:
            self.hot_rows = self.local_rows
            self._hot = None
        else:
            self.hot_rows = min(
                self.local_rows,
                self.host_budget_bytes
                // max(self.num_shards * self.row_bytes, 1))
            self._hot = [_HotTier(self.feature_dim, self.dtype)
                         for _ in range(self.num_shards)]

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def from_array(cls, table: np.ndarray, host_budget_bytes: int = 0,
                   owner: Optional[np.ndarray] = None,
                   local_idx: Optional[np.ndarray] = None) -> "FeatureStore":
        """Back-compat constructor: wrap the classic ``(N, local_rows, d)``
        sharded table. With the default budget the store is resident and
        every consumer behaves bit-identically to the pre-store code."""
        table = np.asarray(table)
        assert table.ndim == 3, f"expected (N, rows, d), got {table.shape}"
        st = cls([table[s] for s in range(table.shape[0])],
                 host_budget_bytes=host_budget_bytes, owner=owner,
                 local_idx=local_idx)
        if st.resident:
            st._dense = table
        return st

    @classmethod
    def build(cls, features: np.ndarray, part: np.ndarray, num_shards: int,
              directory: Optional[str] = None, host_budget_bytes: int = 0,
              chunk_rows: int = 1 << 16,
              checksums: Optional[bool] = None,
              crc_chunk_rows: int = 1024) -> "FeatureStore":
        """Shard ``features`` by ``part`` into a store.

        With ``directory`` the per-shard rows are scattered *chunked* into
        on-disk ``.npy`` memmaps (:func:`spill_shards`) — peak host memory
        is one chunk, so graphs larger than host RAM shard fine as long as
        ``features`` itself is a memmap (repro.graph.synthetic's spill
        writer). Without it the shards live in RAM (the classic
        ``shard_features`` layout).

        ``checksums`` defaults to on for spilled (disk-tier) stores and off
        for in-RAM ones; the crcs are persisted as ``shard_*.crc32.npz``
        sidecars next to the shard files, and ``features`` is attached as
        the authoritative repair source."""
        from repro.graph.partition import local_index_map
        owner, local_idx, max_sz = local_index_map(
            np.asarray(part), num_shards)
        if directory is None:
            table = np.zeros((num_shards, max_sz, features.shape[1]),
                             features.dtype)
            table[owner, local_idx] = features
            st = cls.from_array(table, host_budget_bytes=host_budget_bytes,
                                owner=owner, local_idx=local_idx)
            if checksums:
                st.attach_source(features)
                st.enable_checksums(crc_chunk_rows)
            return st
        backing = spill_shards(features, owner, local_idx, num_shards,
                               max_sz, directory, chunk_rows=chunk_rows)
        st = cls(backing, host_budget_bytes=host_budget_bytes,
                 owner=owner, local_idx=local_idx)
        if checksums is None or checksums:
            st.attach_source(features)
            st.enable_checksums(crc_chunk_rows, persist_dir=directory)
        return st

    def bind(self, owner: np.ndarray, local_idx: np.ndarray) -> "FeatureStore":
        """Attach the global-id → (owner, local row) maps
        (:meth:`take_global` needs them). Returns self for chaining."""
        self.owner = np.asarray(owner)
        self.local_idx = np.asarray(local_idx)
        return self

    def _reconstruct_source(self) -> np.ndarray:
        """Global feature rows recovered from the current backing through
        the bound owner/local_idx maps (crc-verified reads, patches
        honored). The reshard fallback when no authoritative source is
        attached."""
        if self.owner is None or self.local_idx is None:
            raise ValueError("reshard needs an attached source or bound "
                             "owner/local_idx maps (FeatureStore.bind)")
        n = int(self.owner.size)
        out = np.empty((n, self.feature_dim), self.dtype)
        for s in range(self.num_shards):
            ids = np.flatnonzero(self.owner == s)
            if ids.size:
                out[ids] = self._read_backing(
                    s, self.local_idx[ids].astype(np.int64))
        return out

    def reshard(self, part: np.ndarray, num_shards: int, *,
                directory: Optional[str] = None) -> "FeatureStore":
        """Rebuild the tier chain for a new world view (repro.membership).

        After a confirmed peer death the survivors re-own the dead shard's
        vertices (``graph.partition.reassign_partition``) and every tier
        must be rebuilt for the new ``(part, num_shards)``: new rectangular
        backing, fresh hot tiers, fresh crc sidecars. Rows come from the
        authoritative source when one is attached — the same
        repair-from-source path disk corruption uses; on a real deployment
        this is the shared feature store the dead worker's rows survive
        in — otherwise the global rows are reconstructed from the *current*
        backing through the bound owner/local_idx maps, the single-process
        stand-in for survivors re-reading their local tiers.

        Returns a new bound store with the same host budget. ``directory``
        spills the new backing to disk — pass a fresh per-generation
        directory; the old shard files stay mapped until the old store is
        dropped."""
        src = self._source if self._source is not None \
            else self._reconstruct_source()
        st = FeatureStore.build(
            np.asarray(src), np.asarray(part), int(num_shards),
            directory=directory,
            host_budget_bytes=self.host_budget_bytes,
            checksums=self.checksums_enabled,
            crc_chunk_rows=self.crc_chunk_rows or 1024)
        if self._source is not None and st._source is None:
            st.attach_source(self._source)
        return st

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def row_bytes(self) -> int:
        return self.feature_dim * self.dtype.itemsize

    @property
    def spilled(self) -> bool:
        """True when tier 2 is disk-backed (any shard is a memmap)."""
        return any(isinstance(b, np.memmap) for b in self._backing)

    def backing_nbytes(self) -> int:
        return self.num_shards * self.local_rows * self.row_bytes

    def hot_nbytes(self) -> int:
        if self._hot is None:
            return self.backing_nbytes()
        return int(sum(h.buf.nbytes for h in self._hot))

    def hot_installed_rows(self, shard: int) -> int:
        return 0 if self._hot is None else self._hot[shard].rows

    def as_dense(self) -> np.ndarray:
        """The full ``(N, local_rows, d)`` host table — resident stores
        only (a tiered store materializing it would defeat the budget)."""
        if not self.resident:
            raise ValueError(
                f"store is tiered (host_budget_bytes="
                f"{self.host_budget_bytes}); the dense table would exceed "
                "the host budget — use gather()/take_global()")
        if self._dense is None:
            if self._crc is not None:
                # verified materialization — corruption must not leak into
                # the device table a resident run uploads once
                full = np.arange(self.local_rows, dtype=np.int64)
                self._dense = np.stack([self._read_backing(s, full)
                                        for s in range(self.num_shards)])
            else:
                self._dense = np.stack([np.asarray(b)
                                        for b in self._backing])
        return self._dense

    # ------------------------------------------------------------------
    # The read path (tier 1 -> tier 2)
    # ------------------------------------------------------------------

    def gather(self, shard: int, rows_idx: np.ndarray) -> np.ndarray:
        """Feature rows ``rows_idx`` (backing-row indices) of ``shard``,
        resolved hot-tier first, backing (disk) on miss. Duplicate indices
        are allowed and each occurrence is counted (they are real reads)."""
        rows_idx = np.asarray(rows_idx, np.int64)
        out = np.empty((rows_idx.size, self.feature_dim), self.dtype)
        if rows_idx.size == 0:
            return out
        if self._hot is None:                      # resident: all host RAM
            out[:] = self._read_backing(shard, rows_idx)
            self._acct_gather(int(rows_idx.size), 0)
            return out
        if self._hot_bypass:                       # degraded: tier 2 only
            out[:] = self._read_backing(shard, rows_idx)
            self._acct_gather(0, int(rows_idx.size))
            return out
        hot = self._hot[shard]
        hit, pos = hot.hit_split(rows_idx)
        n_hit = int(hit.sum())
        if n_hit:
            out[hit] = hot.buf[pos[hit]]
        if n_hit < rows_idx.size:
            miss = ~hit
            out[miss] = self._read_backing(shard, rows_idx[miss])
        self._acct_gather(n_hit, int(rows_idx.size) - n_hit)
        return out

    def _acct_gather(self, t1: int, t2: int) -> None:
        """One gather's tier accounting: the lock-scoped TierStats view
        and the process-wide repro.obs registry move together."""
        with self._lock:
            self.stats.t1_rows += t1
            self.stats.t2_rows += t2
            self.stats.gathers += 1
        if t1:
            _obs_metrics.inc("features.t1_rows", t1)
        if t2:
            _obs_metrics.inc("features.t2_rows", t2)
        _obs_metrics.inc("features.gathers")

    def take_global(self, ids: np.ndarray) -> np.ndarray:
        """Feature rows by *global vertex id*, resolved through the tier
        chain — the store-backed replacement for the old
        ``table[owner[ids], local_idx[ids]]`` host-copy gather."""
        if self.owner is None or self.local_idx is None:
            raise ValueError("take_global needs bound owner/local_idx maps "
                             "(FeatureStore.bind)")
        ids = np.asarray(ids, np.int64)
        out = np.empty((ids.size, self.feature_dim), self.dtype)
        if ids.size == 0:
            return out
        own = self.owner[ids]
        for s in np.unique(own):
            m = own == s
            out[m] = self.gather(int(s), self.local_idx[ids[m]])
        return out

    # ------------------------------------------------------------------
    # Readahead (tier 2 -> tier 1)
    # ------------------------------------------------------------------

    def readahead(self, shard: int, rows_idx: np.ndarray,
                  counts: Optional[np.ndarray] = None) -> int:
        """Promote rows into the hot tier ahead of their reads.

        ``rows_idx`` are the backing rows a forecast says will be touched
        (the epoch prefetcher's exact next-epoch sets); ``counts`` ranks
        them when the set outgrows the budget — highest expected read count
        first, ties broken by row index for determinism. The install is
        wholesale (see :class:`_HotTier`). Returns rows installed."""
        if self._hot is None:
            return 0
        rows_idx = np.asarray(rows_idx, np.int64)
        if counts is not None:
            # counts are positional: sort rows (carrying counts along) and
            # require uniqueness — np.unique alone would silently misalign
            counts = np.asarray(counts)
            if counts.shape != rows_idx.shape:
                raise ValueError("counts must align with rows_idx")
            order = np.argsort(rows_idx, kind="stable")
            rows_idx, counts = rows_idx[order], counts[order]
            if rows_idx.size and np.any(np.diff(rows_idx) == 0):
                raise ValueError("rows_idx must be unique when ranked by "
                                 "counts")
            if rows_idx.size > self.hot_rows:
                keep = np.lexsort((rows_idx, -counts))[:self.hot_rows]
                rows_idx = np.sort(rows_idx[keep])
        else:
            rows_idx = np.unique(rows_idx)[:self.hot_rows]
        rows = np.empty((rows_idx.size, self.feature_dim), self.dtype)
        if rows_idx.size:
            rows[:] = self._read_backing(shard, rows_idx)
        self._hot[shard].install(rows_idx, rows)
        with self._lock:
            self.stats.readahead_rows += int(rows_idx.size)
        _obs_metrics.inc("features.readahead_rows", int(rows_idx.size))
        return int(rows_idx.size)

    # ------------------------------------------------------------------
    # Integrity: per-chunk crc32, quarantine, repair (repro.resilience)
    # ------------------------------------------------------------------

    def enable_checksums(self, chunk_rows: int = 1024,
                         persist_dir: Optional[str] = None) -> None:
        """Compute (or load) per-chunk crc32s over every backing shard.

        A *chunk* is ``chunk_rows`` consecutive backing rows; the crc
        covers the chunk's raw bytes including padding rows, so repair can
        re-derive and re-verify it from the source exactly. With
        ``persist_dir``, crcs are written as ``shard_*.crc32.npz``
        sidecars (and loaded from them when present and chunk-compatible
        — reopening a spilled directory skips the rescan)."""
        self.crc_chunk_rows = int(chunk_rows)
        n_chunks = -(-self.local_rows // self.crc_chunk_rows)
        if persist_dir is not None and self._load_sidecars(persist_dir):
            return
        crcs = []
        for s in range(self.num_shards):
            c = np.empty(n_chunks, np.uint32)
            for k in range(n_chunks):
                c[k] = self._chunk_crc(s, k)
            crcs.append(c)
        self._crc = crcs
        self._verified = [set() for _ in range(self.num_shards)]
        if persist_dir is not None:
            self._write_sidecars(persist_dir)

    def _sidecar_path(self, directory, shard: int) -> Path:
        return Path(directory) / f"shard_{shard:03d}.crc32.npz"

    def _write_sidecars(self, directory) -> None:
        assert self._crc is not None
        for s in range(self.num_shards):
            np.savez(self._sidecar_path(directory, s), crc=self._crc[s],
                     chunk_rows=np.int64(self.crc_chunk_rows))

    def _load_sidecars(self, directory) -> bool:
        n_chunks = -(-self.local_rows // self.crc_chunk_rows)
        crcs = []
        for s in range(self.num_shards):
            p = self._sidecar_path(directory, s)
            if not p.exists():
                return False
            with np.load(p) as z:
                if int(z["chunk_rows"]) != self.crc_chunk_rows or \
                        z["crc"].size != n_chunks:
                    return False
                crcs.append(z["crc"].astype(np.uint32))
        self._crc = crcs
        self._verified = [set() for _ in range(self.num_shards)]
        return True

    def attach_source(self, features: np.ndarray) -> "FeatureStore":
        """Attach the authoritative global ``(n, d)`` feature rows (the
        pre-shard array or its memmap) as the repair source for
        checksum-failed chunks. Needs bound owner/local_idx maps to invert
        shard-local rows back to global ids. Returns self for chaining."""
        self._source = features
        return self

    @property
    def checksums_enabled(self) -> bool:
        return self._crc is not None

    @property
    def hot_bypass(self) -> bool:
        return self._hot_bypass

    def bypass_hot(self, flag: bool = True) -> None:
        """Degradation-ladder switch: route every gather straight to the
        (checksum-verified) backing tier, ignoring the hot tier. Used when
        a suspect hot-tier install must not serve reads; readahead still
        installs, so clearing the flag restores tiered service."""
        self._hot_bypass = bool(flag)

    def mark_suspect(self, shard: int,
                     rows_idx: Optional[np.ndarray] = None) -> None:
        """Drop verification memos for the chunks covering ``rows_idx``
        (whole shard when None) — the next read re-verifies them. This is
        the hook a scrubber or an EIO handler calls when it no longer
        trusts previously-verified disk regions."""
        with self._crc_lock:
            if self._crc is not None:
                self._dense = None     # re-materialize verified on next use
            if rows_idx is None:
                self._verified[shard] = set()
                return
            rows_idx = np.asarray(rows_idx, np.int64)
            for c in np.unique(rows_idx // max(self.crc_chunk_rows, 1)):
                self._verified[shard].discard(int(c))

    def corrupt_rows(self, shard: int, rows_idx: np.ndarray,
                     seed: int = 0) -> None:
        """Deterministically overwrite backing rows with garbage — the
        fault-injection entry point (repro.resilience ``disk_corrupt``).
        Spilled shards are rewritten through a fresh r+ mapping of the
        same ``.npy`` so the store's read-only view observes the damage;
        the touched chunks are marked suspect so memoized verification
        does not mask it."""
        rows_idx = np.asarray(rows_idx, np.int64)
        if rows_idx.size == 0:
            return
        rng = np.random.default_rng(
            (int(seed) & 0x7FFFFFFF, shard, int(rows_idx[0])))
        garbage = rng.standard_normal(
            (rows_idx.size, self.feature_dim)) * 1e3
        b = self._backing[shard]
        if isinstance(b, np.memmap):
            from numpy.lib.format import open_memmap
            mm = open_memmap(b.filename, mode="r+")
            mm[rows_idx] = garbage.astype(self.dtype)
            mm.flush()
            del mm
        else:
            b[rows_idx] = garbage.astype(self.dtype)
        self.mark_suspect(shard, rows_idx)

    def verify_all(self) -> int:
        """Scrub every chunk of every shard now (repairing failures);
        returns the number of crc failures found."""
        if self._crc is None:
            return 0
        before = self.stats.crc_failures
        full = np.arange(self.local_rows, dtype=np.int64)
        for s in range(self.num_shards):
            self._check_rows(s, full)
        return self.stats.crc_failures - before

    def _chunk_bounds(self, chunk: int) -> tuple[int, int]:
        a = chunk * self.crc_chunk_rows
        return a, min(a + self.crc_chunk_rows, self.local_rows)

    def _chunk_crc(self, shard: int, chunk: int) -> int:
        a, b = self._chunk_bounds(chunk)
        block = np.ascontiguousarray(np.asarray(self._backing[shard][a:b]))
        return zlib.crc32(block.tobytes()) & 0xFFFFFFFF

    def _shard_globals(self, shard: int) -> np.ndarray:
        """Inverse map: shard-local backing row → global vertex id
        (−1 for padding rows). Cached per shard."""
        got = self._shard_globals_cache.get(shard)
        if got is not None:
            return got
        if self.owner is None or self.local_idx is None:
            raise CorruptFeatureError(
                "repair needs bound owner/local_idx maps (FeatureStore.bind)")
        inv = np.full(self.local_rows, -1, np.int64)
        ids = np.flatnonzero(self.owner == shard)
        inv[self.local_idx[ids]] = ids
        self._shard_globals_cache[shard] = inv
        return inv

    def _repair_chunk(self, shard: int, chunk: int) -> None:
        """Re-gather a checksum-failed chunk from the authoritative source
        into an in-RAM patch that shadows the rotten disk region. The
        rebuilt chunk must re-verify against the stored crc — if it does
        not, the source itself disagrees and we refuse to guess."""
        if self._source is None:
            raise CorruptFeatureError(
                f"shard {shard} chunk {chunk}: crc32 mismatch and no "
                "authoritative source attached (FeatureStore.attach_source)")
        a, b = self._chunk_bounds(chunk)
        glob = self._shard_globals(shard)[a:b]
        good = np.zeros((b - a, self.feature_dim), self.dtype)
        real = glob >= 0
        if real.any():
            good[real] = np.asarray(self._source[glob[real]],
                                    dtype=self.dtype)
        rebuilt = zlib.crc32(
            np.ascontiguousarray(good).tobytes()) & 0xFFFFFFFF
        if rebuilt != int(self._crc[shard][chunk]):
            raise CorruptFeatureError(
                f"shard {shard} chunk {chunk}: source re-gather does not "
                "match the recorded crc32 — source and sidecar disagree")
        self._patches[shard][chunk] = good
        with self._lock:
            self.stats.repaired_rows += int(real.sum())
        _obs_metrics.inc("features.repaired_rows", int(real.sum()))

    def _check_rows(self, shard: int, rows_idx: np.ndarray) -> None:
        """Verify (memoized) the chunks covering ``rows_idx``; quarantine
        and repair any that fail."""
        chunks = np.unique(rows_idx // self.crc_chunk_rows)
        verified = self._verified[shard]
        patches = self._patches[shard]
        todo = [int(c) for c in chunks
                if int(c) not in verified and int(c) not in patches]
        if not todo:
            return
        with self._crc_lock:
            for c in todo:
                if c in self._verified[shard] or c in patches:
                    continue       # another thread beat us to it
                got = self._chunk_crc(shard, c)
                with self._lock:
                    self.stats.crc_checked_chunks += 1
                _obs_metrics.inc("features.crc_checked_chunks")
                if got == int(self._crc[shard][c]):
                    self._verified[shard].add(c)
                    continue
                with self._lock:
                    self.stats.crc_failures += 1
                _obs_metrics.inc("features.crc_failures")
                self._repair_chunk(shard, c)

    def _read_backing(self, shard: int, rows_idx: np.ndarray) -> np.ndarray:
        """Tier-2 row read: crc-verify the touched chunks (when enabled)
        and serve quarantined chunks from their in-RAM patches instead of
        the rotten disk region."""
        if self._crc is not None:
            self._check_rows(shard, rows_idx)
        patches = self._patches[shard]
        if not patches:
            return self._backing[shard][rows_idx]
        ck = rows_idx // self.crc_chunk_rows
        out = np.empty((rows_idx.size, self.feature_dim), self.dtype)
        patched = np.isin(ck, np.fromiter(patches.keys(), np.int64,
                                          len(patches)))
        if (~patched).any():
            out[~patched] = self._backing[shard][rows_idx[~patched]]
        for c in np.unique(ck[patched]):
            m = ck == c
            out[m] = patches[int(c)][rows_idx[m] - int(c)
                                     * self.crc_chunk_rows]
        return out


def spill_shards(features: np.ndarray, owner: np.ndarray,
                 local_idx: np.ndarray, num_shards: int, max_sz: int,
                 directory: str, chunk_rows: int = 1 << 16
                 ) -> list[np.memmap]:
    """Scatter global feature rows into per-shard ``.npy`` memmaps.

    The scatter walks ``features`` in row chunks, so peak host memory is
    one chunk even when both the source (a spilled synthetic dataset) and
    the shards are disk-backed. Shards are padded to ``max_sz`` rows
    (rectangular, zero padding) exactly like ``shard_features``. The
    returned memmaps are reopened read-only — the store never writes
    tier 2 after construction (features are static during training)."""
    from numpy.lib.format import open_memmap
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    d = int(features.shape[1])
    paths = [directory / f"shard_{s:03d}.npy" for s in range(num_shards)]
    mms = [open_memmap(p, mode="w+", dtype=features.dtype,
                       shape=(max_sz, d)) for p in paths]
    n = int(features.shape[0])
    for a in range(0, n, chunk_rows):
        b = min(a + chunk_rows, n)
        chunk = np.asarray(features[a:b])
        own = owner[a:b]
        for s in np.unique(own):
            m = own == s
            mms[s][local_idx[a:b][m]] = chunk[m]
    for mm in mms:
        mm.flush()
    del mms
    return [np.load(p, mmap_mode="r") for p in paths]
