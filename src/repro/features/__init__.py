"""repro.features — tiered feature storage (device cache → host hot tier →
mmap disk). See store.py for the tier contract."""
from repro.features.store import (CorruptFeatureError, FeatureStore,
                                  TierStats, spill_shards)

__all__ = ["FeatureStore", "TierStats", "spill_shards",
           "CorruptFeatureError"]
