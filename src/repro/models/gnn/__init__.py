"""GNN model zoo (paper §7.1): GCN, GraphSAGE, GAT, DeepGCN, GNN-FiLM.

All models operate on fixed-fanout *tree blocks* (see
:mod:`repro.graph.sampler`): per-hop feature tensors of shape
(B * f**h, d). This is the TPU-native re-expression of DGL's message-flow
graphs — aggregation is a dense reshape+reduce, never a scatter.
"""
from repro.models.gnn.models import (
    GNNConfig, MODEL_REGISTRY, init_gnn, gnn_forward, gnn_loss, model_param_bytes,
)

__all__ = ["GNNConfig", "MODEL_REGISTRY", "init_gnn", "gnn_forward",
           "gnn_loss", "model_param_bytes"]
