"""GNN layer primitives over tree blocks.

A *tree level pair* is ``(parent, child)`` with shapes
``parent: (n, d_in)``, ``child: (n, f, d_in)`` — children of parent i are
``child[i]``. Every layer maps this pair to updated parent embeddings
``(n, d_out)``.

The neighbor aggregation (`gather + reduce` — DGL's SpMM, the compute
hot-spot the paper's domain optimizes) is injectable so the Pallas kernel in
:mod:`repro.kernels.gather_agg` can replace the jnp reference on TPU.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


# ---------------------------------------------------------------------------
# Aggregators (child: (n, f, d) -> (n, d))
# ---------------------------------------------------------------------------

def agg_mean(child: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(child, axis=1)


def agg_sum(child: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(child, axis=1)


def agg_max(child: jnp.ndarray) -> jnp.ndarray:
    return jnp.max(child, axis=1)


AGGREGATORS = {"mean": agg_mean, "sum": agg_sum, "max": agg_max}


# ---------------------------------------------------------------------------
# Layers. Each layer is (init_fn, apply_fn) over (parent, child).
# ---------------------------------------------------------------------------

def gcn_init(key, d_in, d_out):
    return {"w": glorot(key, (d_in, d_out)), "b": jnp.zeros((d_out,))}


def gcn_apply(p, parent, child, act=jax.nn.relu):
    """Kipf-Welling GCN with mean normalization (self + neighbors)."""
    f = child.shape[1]
    agg = (parent + jnp.sum(child, axis=1)) / (f + 1.0)
    return act(agg @ p["w"] + p["b"])


def sage_init(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {"w_self": glorot(k1, (d_in, d_out)),
            "w_nbr": glorot(k2, (d_in, d_out)),
            "b": jnp.zeros((d_out,))}


def sage_apply(p, parent, child, act=jax.nn.relu):
    """GraphSAGE-mean: act(W_s h_v + W_n mean(h_N(v)))."""
    return act(parent @ p["w_self"] + agg_mean(child) @ p["w_nbr"] + p["b"])


def gat_init(key, d_in, d_out, heads=4):
    assert d_out % heads == 0
    k1, k2, k3 = jax.random.split(key, 3)
    dh = d_out // heads
    return {"w": glorot(k1, (d_in, heads * dh)),
            "a_src": 0.1 * jax.random.normal(k2, (heads, dh)),
            "a_dst": 0.1 * jax.random.normal(k3, (heads, dh))}


def gat_apply(p, parent, child, act=jax.nn.elu):
    """GAT: softmax(LeakyReLU(a^T[Wh_i || Wh_j])) attention over sampled
    neighbors (incl. self edge, as DGL does with add_self_loop)."""
    heads = p["a_src"].shape[0]  # heads inferred from attention params
    n, f, d_in = child.shape
    dh = p["w"].shape[1] // heads
    hp = (parent @ p["w"]).reshape(n, heads, dh)
    hc = (child @ p["w"]).reshape(n, f, heads, dh)
    # attention logits: e_ij = leaky(a_src . h_i + a_dst . h_j)
    e_src = jnp.einsum("nhd,hd->nh", hp, p["a_src"])            # (n, h)
    e_dst = jnp.einsum("nfhd,hd->nfh", hc, p["a_dst"])          # (n, f, h)
    e_self = jax.nn.leaky_relu(e_src + jnp.einsum("nhd,hd->nh", hp, p["a_dst"]), 0.2)
    e = jax.nn.leaky_relu(e_src[:, None, :] + e_dst, 0.2)       # (n, f, h)
    logits = jnp.concatenate([e_self[:, None, :], e], axis=1)    # (n, f+1, h)
    alpha = jax.nn.softmax(logits, axis=1)
    vals = jnp.concatenate([hp[:, None], hc], axis=1)            # (n, f+1, h, dh)
    out = jnp.einsum("nfh,nfhd->nhd", alpha, vals).reshape(n, heads * dh)
    return act(out)


def deepgcn_init(key, d_in, d_out):
    # ResGCN+ block: pre-norm, GCN aggregation, residual.
    k1, _ = jax.random.split(key)
    return {"w": glorot(k1, (d_in, d_out)), "b": jnp.zeros((d_out,)),
            "ln_g": jnp.ones((d_in,)), "ln_b": jnp.zeros((d_in,))}


def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def deepgcn_apply(p, parent, child, act=jax.nn.relu):
    """DeepGCN (ResGCN+): h + W·act(LN(mean-agg)). Residual requires
    d_in == d_out (enforced by the model builder for hidden layers)."""
    f = child.shape[1]
    agg = (parent + jnp.sum(child, axis=1)) / (f + 1.0)
    y = act(_layernorm(agg, p["ln_g"], p["ln_b"])) @ p["w"] + p["b"]
    return parent + y if parent.shape[-1] == y.shape[-1] else y


def film_init(key, d_in, d_out):
    k1, k2 = jax.random.split(key)
    return {"w": glorot(k1, (d_in, d_out)),
            "w_film": glorot(k2, (d_in, 2 * d_out)),
            "b": jnp.zeros((d_out,))}


def film_apply(p, parent, child, act=jax.nn.relu):
    """GNN-FiLM: messages W·h_j modulated by FiLM(γ,β) of the target node."""
    n, f, _ = child.shape
    d_out = p["w"].shape[1]
    gamma_beta = parent @ p["w_film"]                            # (n, 2*d_out)
    gamma, beta = gamma_beta[:, :d_out], gamma_beta[:, d_out:]
    msg = child @ p["w"]                                         # (n, f, d_out)
    mod = gamma[:, None, :] * msg + beta[:, None, :]
    return act(jnp.mean(mod, axis=1) + parent @ p["w"] + p["b"])


LAYER_REGISTRY: dict[str, tuple[Callable, Callable]] = {
    "gcn": (gcn_init, gcn_apply),
    "sage": (sage_init, sage_apply),
    "gat": (gat_init, gat_apply),
    "deepgcn": (deepgcn_init, deepgcn_apply),
    "film": (film_init, film_apply),
}
