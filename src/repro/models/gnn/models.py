"""Full GNN models over tree blocks.

``gnn_forward`` consumes per-hop feature tensors
``feats[h] : (B * f**h, d)`` (h = 0 … k) and returns logits for the B root
vertices. Layer ℓ updates the embeddings of hops 0 … k-ℓ from the pair
(hop h, hop h+1) — exactly DGL's message-flow-graph schedule, re-expressed
on the fixed-fanout tree.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.gnn.layers import LAYER_REGISTRY, glorot


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "gcn"            # key in LAYER_REGISTRY
    num_layers: int = 3           # k; paper: 3 shallow, 7 DeepGCN, 10 FiLM
    hidden_dim: int = 128         # paper evaluates 16 and 128
    feature_dim: int = 128
    num_classes: int = 40
    fanout: int = 10              # paper default fanout (§7.1)

    def layer_dims(self) -> list[tuple[int, int]]:
        dims = []
        d = self.feature_dim
        for _ in range(self.num_layers):
            dims.append((d, self.hidden_dim))
            d = self.hidden_dim
        return dims


# Paper model suite (§7.1): 3 shallow (3L) + DeepGCN (7L) + GNN-FiLM (10L).
MODEL_REGISTRY = {
    "gcn": dict(model="gcn", num_layers=3),
    "sage": dict(model="sage", num_layers=3),
    "gat": dict(model="gat", num_layers=3),
    "deepgcn": dict(model="deepgcn", num_layers=7),
    "film": dict(model="film", num_layers=10),
}


def init_gnn(key, cfg: GNNConfig):
    init_fn, _ = LAYER_REGISTRY[cfg.model]
    keys = jax.random.split(key, cfg.num_layers + 1)
    layers = [init_fn(keys[i], d_in, d_out)
              for i, (d_in, d_out) in enumerate(cfg.layer_dims())]
    head = {"w": glorot(keys[-1], (cfg.hidden_dim, cfg.num_classes)),
            "b": jnp.zeros((cfg.num_classes,))}
    return {"layers": layers, "head": head}


def gnn_forward(params, cfg: GNNConfig, feats: Sequence[jnp.ndarray]
                ) -> jnp.ndarray:
    """feats[h]: (B*f**h, d_feat) for h in 0..k. Returns (B, n_classes)."""
    k = cfg.num_layers
    assert len(feats) == k + 1, (len(feats), k)
    _, apply_fn = LAYER_REGISTRY[cfg.model]
    f = cfg.fanout
    hs = list(feats)
    for layer in range(k):
        p = params["layers"][layer]
        new_hs = []
        for h in range(k - layer):
            parent = hs[h]
            d = hs[h + 1].shape[-1]
            child = hs[h + 1].reshape(parent.shape[0], f, d)
            new_hs.append(apply_fn(p, parent, child))
        hs = new_hs
    root = hs[0]
    return root @ params["head"]["w"] + params["head"]["b"]


def gnn_loss(params, cfg: GNNConfig, feats, labels, weight=None):
    """Mean softmax cross-entropy over root vertices.

    ``weight``: optional (B,) 0/1 mask — padding roots contribute 0 loss
    (needed by LeapGNN's padded micrograph batches). Normalization uses the
    *true* count so gradient accumulation across time steps matches the
    model-centric gradient exactly (accuracy-fidelity invariant, §5.1)."""
    logits = gnn_forward(params, cfg, feats)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    if weight is None:
        return nll.mean(), logits
    w = weight.astype(nll.dtype)
    return jnp.sum(nll * w), logits  # caller divides by global batch size


def gnn_accuracy(params, cfg, feats, labels):
    logits = gnn_forward(params, cfg, feats)
    return (jnp.argmax(logits, -1) == labels).mean()


def model_param_bytes(params) -> int:
    """Model size in bytes — denominator of the paper's α ratio (Fig. 5)."""
    leaves = jax.tree.leaves(params)
    return int(sum(x.size * x.dtype.itemsize for x in leaves
                   if hasattr(x, "dtype")))
