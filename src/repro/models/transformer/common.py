"""Shared transformer building blocks: norms, RoPE, inits, sharding hints.

All parameters are plain dict pytrees (no flax): every leaf is created by an
``init_*`` helper and consumed by a pure ``apply`` function, so GSPMD
sharding is controlled entirely by ``in_shardings`` on the jitted step plus
``with_sharding_constraint`` hints at block boundaries.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def he_normal(key, shape, dtype, fan_in=None):
    fan = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) * (2.0 / fan) ** 0.5).astype(dtype)


def init_linear(key, d_in, d_out, dtype, bias=False):
    p = {"w": he_normal(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_rmsnorm(d, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["g"]


def init_layernorm(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * p["g"] + p["b"]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, Dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Sharding hints
# ---------------------------------------------------------------------------

# Logical→mesh axis mapping. The launcher rebinds "dp" to ("pod", "data")
# for the multi-pod mesh; models only ever name logical axes.
_MESH_AXES = {"dp": ("data",), "tp": ("model",)}


def set_mesh_axes(dp, tp) -> None:
    _MESH_AXES["dp"] = tuple(dp) if isinstance(dp, (tuple, list)) else (dp,)
    _MESH_AXES["tp"] = tuple(tp) if isinstance(tp, (tuple, list)) else (tp,)


def resolve_axes(name):
    if name == "dp":
        return _MESH_AXES["dp"]
    if name == "tp":
        ax = _MESH_AXES["tp"]
        return ax[0] if len(ax) == 1 else ax
    return name


def shard(x, *spec):
    """Best-effort with_sharding_constraint with logical axis names
    ("dp"/"tp"); no-op outside a mesh context (CPU unit tests)."""
    resolved = tuple(resolve_axes(s) if isinstance(s, str) else
                     (tuple(resolve_axes(a) for a in s)
                      if isinstance(s, (tuple, list)) else s)
                     for s in spec)
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except (ValueError, RuntimeError, TypeError, KeyError):
        return x


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token NLL; logits (B,S,V) f32-upcast, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return nll.mean()
