"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The recurrent branch: temporal conv (width 4) → Real-Gated LRU
    r_t = σ(W_a x_t),  i_t = σ(W_i x_t)
    log a_t = -c · r_t · softplus(Λ)          (c = 8, per the paper)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)
gated by a GeLU branch, then projected out. The linear recurrence runs as a
``jax.lax.associative_scan`` over the sequence in training/prefill — O(log S)
depth, TPU-friendly — and as a single fused update in decode.

Decode state = (h: (B, W) f32, conv tail: (B, conv_width-1, W)), constant
per token — what qualifies recurrentgemma-9b for long_500k.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.transformer.common import init_linear, linear

C_SCALE = 8.0


class RGLRUState(NamedTuple):
    h: jnp.ndarray            # (B, W) f32
    conv: jnp.ndarray         # (B, conv_width-1, W)


def init_rglru_block(key, cfg, dtype):
    D = cfg.d_model
    W = cfg.rglru_width or D
    ks = jax.random.split(key, 6)
    return {
        "w_in": init_linear(ks[0], D, W, dtype),      # recurrent branch in
        "w_gate": init_linear(ks[1], D, W, dtype),    # gelu gate branch
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, W)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "wa": init_linear(ks[3], W, W, dtype),        # recurrence gate
        "wi": init_linear(ks[4], W, W, dtype),        # input gate
        "lam": jnp.full((W,), 2.0, jnp.float32),      # Λ (softplus > 0)
        "w_out": init_linear(ks[5], W, D, dtype),
    }


def _conv1d(p, x):
    """Causal depthwise temporal conv, width cw. x: (B, S, W)."""
    cw = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * p["conv_w"][i] for i in range(cw))
    return out + p["conv_b"]


def _gates(p, u):
    r = jax.nn.sigmoid(linear(p["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["wi"], u).astype(jnp.float32))
    log_a = -C_SCALE * r * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) \
        * (i * u.astype(jnp.float32))
    return a, gated_in


def rglru_scan(a, b):
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    return jax.lax.associative_scan(combine, (a, b), axis=1)[1]


def rglru_block(p, cfg, x, norm, return_state: bool = False):
    """Full-sequence path. x: (B, S, D). With ``return_state`` also returns
    the RGLRUState after the last token (stateful prefill)."""
    from repro.models.transformer.common import rmsnorm
    h_in = rmsnorm(norm, x)
    gate = jax.nn.gelu(linear(p["w_gate"], h_in))
    u_proj = linear(p["w_in"], h_in)
    u = _conv1d(p, u_proj)
    a, b = _gates(p, u)
    h = rglru_scan(a, b)
    out = x + linear(p["w_out"], h.astype(x.dtype) * gate)
    if return_state:
        cw = p["conv_w"].shape[0]
        tail = jnp.pad(u_proj, ((0, 0), (max(cw - 1 - x.shape[1], 0), 0),
                                (0, 0)))[:, -(cw - 1):]
        return out, RGLRUState(h=h[:, -1], conv=tail)
    return out


def init_rglru_state(batch: int, cfg) -> RGLRUState:
    W = cfg.rglru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, W), jnp.float32),
                      conv=jnp.zeros((batch, cfg.conv_width - 1, W),
                                     cfg.activation_dtype))


def rglru_block_decode(p, cfg, x, norm, state: RGLRUState):
    """x: (B, 1, D) single token."""
    from repro.models.transformer.common import rmsnorm
    h_in = rmsnorm(norm, x)
    gate = jax.nn.gelu(linear(p["w_gate"], h_in))[:, 0]
    u_t = linear(p["w_in"], h_in)[:, 0]                  # (B, W)
    window = jnp.concatenate([state.conv, u_t[:, None]], axis=1)
    cw = p["conv_w"].shape[0]
    u_conv = sum(window[:, i] * p["conv_w"][i] for i in range(cw)) \
        + p["conv_b"]
    a, b = _gates(p, u_conv[:, None, :] if u_conv.ndim == 2 else u_conv)
    a, b = a.reshape(u_t.shape[0], -1), b.reshape(u_t.shape[0], -1)
    h = a * state.h + b
    out = linear(p["w_out"], (h.astype(x.dtype) * gate))
    return x + out[:, None], RGLRUState(h=h, conv=window[:, 1:])
