"""Whisper-style encoder-decoder backbone.

Per the assignment carve-out, the mel-spectrogram + conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, S_enc, D) — this
module implements everything downstream: a bidirectional pre-LN encoder and
a causal decoder with cached self-attention plus cross-attention to the
encoder states.

Whisper uses LayerNorm + GELU + learned positions (no RoPE); we keep that.
Decoder positions are learned up to ``max_positions`` (sized by the largest
decode shape).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.transformer.attention import (
    KVCache, _repeat_kv, attend_decode, attend_full, cache_append,
    init_kv_cache)
from repro.models.transformer.common import (
    init_layernorm, init_linear, layernorm, linear)


def _init_mha(key, d_model, heads, dtype):
    ks = jax.random.split(key, 4)
    return {"wq": init_linear(ks[0], d_model, d_model, dtype, bias=True),
            "wk": init_linear(ks[1], d_model, d_model, dtype),
            "wv": init_linear(ks[2], d_model, d_model, dtype, bias=True),
            "wo": init_linear(ks[3], d_model, d_model, dtype, bias=True)}


def _mha(p, x_q, x_kv, heads, causal):
    b, sq, d = x_q.shape
    dh = d // heads
    q = linear(p["wq"], x_q).reshape(b, sq, heads, dh)
    k = linear(p["wk"], x_kv).reshape(b, x_kv.shape[1], heads, dh)
    v = linear(p["wv"], x_kv).reshape(b, x_kv.shape[1], heads, dh)
    o = attend_full(q, k, v, causal=causal)
    return linear(p["wo"], o.reshape(b, sq, d))


def init_encoder_layer(key, d_model, heads, d_ff, dtype):
    ks = jax.random.split(key, 4)
    return {"ln1": init_layernorm(d_model, dtype),
            "attn": _init_mha(ks[0], d_model, heads, dtype),
            "ln2": init_layernorm(d_model, dtype),
            "wu": init_linear(ks[1], d_model, d_ff, dtype, bias=True),
            "wd": init_linear(ks[2], d_ff, d_model, dtype, bias=True)}


def encoder_layer(p, x, heads):
    x = x + _mha(p["attn"], layernorm(p["ln1"], x), layernorm(p["ln1"], x),
                 heads, causal=False)
    h = layernorm(p["ln2"], x)
    return x + linear(p["wd"], jax.nn.gelu(linear(p["wu"], h)))


def init_decoder_layer(key, d_model, heads, d_ff, dtype):
    ks = jax.random.split(key, 5)
    return {"ln1": init_layernorm(d_model, dtype),
            "self_attn": _init_mha(ks[0], d_model, heads, dtype),
            "ln_x": init_layernorm(d_model, dtype),
            "cross_attn": _init_mha(ks[1], d_model, heads, dtype),
            "ln2": init_layernorm(d_model, dtype),
            "wu": init_linear(ks[2], d_model, d_ff, dtype, bias=True),
            "wd": init_linear(ks[3], d_ff, d_model, dtype, bias=True)}


def decoder_layer(p, x, enc, heads):
    """Training/prefill over the whole target sequence."""
    h = layernorm(p["ln1"], x)
    x = x + _mha(p["self_attn"], h, h, heads, causal=True)
    x = x + _mha(p["cross_attn"], layernorm(p["ln_x"], x), enc, heads,
                 causal=False)
    h = layernorm(p["ln2"], x)
    return x + linear(p["wd"], jax.nn.gelu(linear(p["wu"], h)))


class DecLayerCache(NamedTuple):
    self_kv: KVCache
    cross_k: jnp.ndarray      # (B, S_enc, H, Dh) — precomputed from encoder
    cross_v: jnp.ndarray


def init_decoder_cache(p, enc, batch, max_seq, heads, d_model, dtype
                       ) -> DecLayerCache:
    dh = d_model // heads
    k = linear(p["cross_attn"]["wk"], enc).reshape(batch, enc.shape[1],
                                                   heads, dh)
    v = linear(p["cross_attn"]["wv"], enc).reshape(batch, enc.shape[1],
                                                   heads, dh)
    return DecLayerCache(
        self_kv=init_kv_cache(batch, max_seq, heads, dh, dtype),
        cross_k=k, cross_v=v)


def decoder_layer_decode(p, x, cache: DecLayerCache, heads
                         ) -> tuple[jnp.ndarray, DecLayerCache]:
    """x: (B, 1, D) one target token."""
    b, _, d = x.shape
    dh = d // heads
    h = layernorm(p["ln1"], x)
    q = linear(p["self_attn"]["wq"], h).reshape(b, 1, heads, dh)
    k = linear(p["self_attn"]["wk"], h).reshape(b, 1, heads, dh)
    v = linear(p["self_attn"]["wv"], h).reshape(b, 1, heads, dh)
    self_kv = cache_append(cache.self_kv, k, v)
    o = attend_decode(q, self_kv)
    x = x + linear(p["self_attn"]["wo"], o.reshape(b, 1, d))

    hx = layernorm(p["ln_x"], x)
    q = linear(p["cross_attn"]["wq"], hx).reshape(b, 1, heads, dh)
    s = jnp.einsum("bqhd,bkhd->bhqk",
                   q.astype(jnp.float32) * dh ** -0.5,
                   cache.cross_k.astype(jnp.float32))
    pzn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", pzn,
                   cache.cross_v.astype(jnp.float32)).astype(x.dtype)
    x = x + linear(p["cross_attn"]["wo"], o.reshape(b, 1, d))

    h2 = layernorm(p["ln2"], x)
    x = x + linear(p["wd"], jax.nn.gelu(linear(p["wu"], h2)))
    return x, DecLayerCache(self_kv=self_kv, cross_k=cache.cross_k,
                            cross_v=cache.cross_v)
