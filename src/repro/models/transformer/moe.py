"""Mixture-of-experts layer (qwen2-moe, deepseek-moe) with HopMoE dispatch.

Routing is GShard-style capacity-based dispatch, computed *per batch row* so
every cumsum/scatter stays local to the row's data shard (no cross-device
sequential ops). Expert compute is one stacked einsum over (E, C, D) buffers
— real FLOPs proportional to capacity, not to E (no masked-matmul padding
waste beyond the capacity factor).

**HopMoE (beyond-paper, DESIGN.md §4):** the paper's feature-centric
principle — "move the small thing to the big thing" — applied to the one
place in these architectures with the same structure. Two shardings of the
same math:

* ``tokens``  (model-centric analogue): routed expert weights sharded over
  the ``model`` axis on the *expert* dim; token buffers must be laid out
  expert-major, so GSPMD inserts an all-to-all moving activation bytes.
* ``weights`` (feature-centric analogue): expert weights sharded on the
  *hidden* (d_ff) dim; tokens never leave their data shard — the *weights*
  are what's distributed. Costs an extra all-reduce of the expert output
  partial sums on the model axis.

``auto`` computes the paper's α ratio per layer —
α = dispatched-activation-bytes / expert-weight-bytes — and picks the
cheaper side at trace time (shapes are static, so this is a free decision).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.transformer.common import init_linear, shard


def moe_capacity(seq: int, top_k: int, num_experts: int,
                 capacity_factor: float, multiple: int = 8) -> int:
    # decode (seq == 1): each expert serves at most 1 token per row — the
    # 8-multiple padding would make every expert buffer 8× oversized (the
    # baseline roofline's useful_ratio ≈ 0.02 for MoE decode; §Perf)
    if seq == 1:
        return 1
    c = int(seq * top_k / num_experts * capacity_factor) + 1
    return max(multiple, -(-c // multiple) * multiple)


def init_moe(key, cfg, dtype):
    D, E, Fe = cfg.d_model, cfg.moe_num_experts, cfg.moe_expert_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": init_linear(ks[0], D, E, jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, D, Fe)) * (2.0 / D) ** 0.5
               ).astype(dtype),
        "wu": (jax.random.normal(ks[2], (E, D, Fe)) * (2.0 / D) ** 0.5
               ).astype(dtype),
        "wd": (jax.random.normal(ks[3], (E, Fe, D)) * (2.0 / Fe) ** 0.5
               ).astype(dtype),
    }
    if cfg.moe_num_shared:
        from repro.models.transformer.mlp import init_mlp
        p["shared"] = init_mlp(ks[4], D, cfg.moe_num_shared * Fe,
                               "swiglu", dtype)
    return p


@dataclasses.dataclass
class MoEStats:
    aux_loss: jnp.ndarray
    dispatch_bytes: int
    weight_bytes: int
    mode: str


def _alpha_mode(cfg, batch: int, seq: int) -> tuple[str, int, int]:
    """HopMoE α decision: compare bytes that must cross the model axis."""
    D, E, Fe = cfg.d_model, cfg.moe_num_experts, cfg.moe_expert_d_ff
    itemsize = 2 if cfg.dtype == "bfloat16" else 4
    C = moe_capacity(seq, cfg.moe_top_k, E, cfg.moe_capacity_factor)
    # tokens mode: buffers (B,E,C,D) cross model axis out and back (×2)
    dispatch_bytes = 2 * batch * E * C * D * itemsize
    # weights mode: partial-sum all-reduce of the output (B,S,D) on model axis
    weight_bytes = 2 * batch * seq * D * 4   # f32 partials
    mode = cfg.moe_dispatch
    if mode == "auto":
        mode = "tokens" if dispatch_bytes < weight_bytes else "weights"
    return mode, dispatch_bytes, weight_bytes


def moe_forward(p, cfg, x: jnp.ndarray) -> tuple[jnp.ndarray, MoEStats]:
    """x: (B, S, D). Returns (out (B,S,D), stats with aux loss)."""
    B, S, D = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    Fe = cfg.moe_expert_d_ff
    C = moe_capacity(S, k, E, cfg.moe_capacity_factor)
    mode, db, wb = _alpha_mode(cfg, B, S)

    logits = (x.astype(jnp.float32) @ p["router"]["w"])        # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (B,S,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch): E * Σ_e f_e · m_e ---
    me = probs.mean(axis=(0, 1))                               # (E,)
    fe = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(fe * me)

    # --- per-row capacity dispatch ---
    eid = top_e.reshape(B, S * k)                              # (B, N)
    onehot = jax.nn.one_hot(eid, E, dtype=jnp.int32)           # (B, N, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                       # (B, N, E)
    my_pos = jnp.take_along_axis(pos, eid[..., None], 2)[..., 0]   # (B, N)
    keep = my_pos < C
    slot = jnp.where(keep, eid * C + my_pos, E * C)            # drop → spill row
    x_rep = jnp.repeat(x, k, axis=1)                           # (B, N, D)
    gate = (top_p.reshape(B, S * k) * keep).astype(x.dtype)

    buf = jnp.zeros((B, E * C + 1, D), x.dtype)
    buf = buf.at[jnp.arange(B)[:, None], slot].add(
        x_rep * keep[..., None].astype(x.dtype))
    buf = buf[:, : E * C].reshape(B, E, C, D)

    # --- sharding per HopMoE mode ---
    if mode == "tokens":
        buf = shard(buf, "dp", "tp", None, None)
        wg = shard(p["wg"], "tp", None, None)
        wu = shard(p["wu"], "tp", None, None)
        wd = shard(p["wd"], "tp", None, None)
    else:
        buf = shard(buf, "dp", None, None, None)
        wg = shard(p["wg"], None, None, "tp")
        wu = shard(p["wu"], None, None, "tp")
        wd = shard(p["wd"], None, "tp", None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) \
        * jnp.einsum("becd,edf->becf", buf, wu)
    out_buf = jnp.einsum("becf,efd->becd", h, wd)              # (B,E,C,D)

    if mode == "tokens":
        out_buf = shard(out_buf, "dp", "tp", None, None)
    out_flat = out_buf.reshape(B, E * C, D)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((B, 1, D), out_flat.dtype)], axis=1)
    gathered = jnp.take_along_axis(
        out_flat, jnp.where(keep, slot, E * C)[..., None], axis=1)  # (B,N,D)
    routed = (gathered * gate[..., None]).reshape(B, S, k, D).sum(2)
    routed = shard(routed, "dp", None, None)

    if "shared" in p:
        from repro.models.transformer.mlp import mlp_forward
        routed = routed + mlp_forward(p["shared"], x, "swiglu")

    return routed, MoEStats(aux_loss=aux, dispatch_bytes=db,
                            weight_bytes=wb, mode=mode)
