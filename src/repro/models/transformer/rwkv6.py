"""RWKV6 ("Finch") block — attention-free, data-dependent decay.

Per layer: a *time-mix* block (token-shift lerp → r/k/v/g projections, a
LoRA-conditioned per-channel decay w_t, the gated-linear-attention core from
:mod:`repro.kernels` with per-head state, group-norm, silu(g) gate) and a
*channel-mix* block (token-shift, squared-ReLU FFN with sigmoid receptance).

Training runs the chunked kernel over the whole sequence; decode carries
(state: (B, H, dk, dv) f32, last_x per mix) — constant-size per token, which
is what qualifies rwkv6-7b for the long_500k shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.transformer.common import init_linear, linear


class RWKVState(NamedTuple):
    s: jnp.ndarray          # (B, H, dk, dv) f32 — linattn state
    tm_x: jnp.ndarray       # (B, D) — last token seen by time-mix
    cm_x: jnp.ndarray       # (B, D) — last token seen by channel-mix


def init_rwkv_block(key, cfg, dtype):
    D, F = cfg.d_model, cfg.d_ff
    hd = cfg.rwkv_head_dim
    H = D // hd
    lora = 64
    ks = jax.random.split(key, 12)
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, D), dtype),       # lerp for r,k,v,g,w
        "wr": init_linear(ks[0], D, D, dtype),
        "wk": init_linear(ks[1], D, D, dtype),
        "wv": init_linear(ks[2], D, D, dtype),
        "wg": init_linear(ks[3], D, D, dtype),
        "wo": init_linear(ks[4], D, D, dtype),
        "w_base": jnp.full((D,), -6.0, jnp.float32),   # decay bias (≈ w→1)
        "w_lora_a": init_linear(ks[5], D, lora, dtype),
        "w_lora_b": init_linear(ks[6], lora, D, dtype),
        "u": jnp.zeros((H, hd), jnp.float32),          # per-head bonus
        "gn_g": jnp.ones((D,), dtype),                 # group-norm (per head)
        "gn_b": jnp.zeros((D,), dtype),
        # channel-mix
        "mu_c": 0.5 * jnp.ones((2, D), dtype),
        "ck": init_linear(ks[7], D, F, dtype),
        "cr": init_linear(ks[8], D, D, dtype),
        "cv": init_linear(ks[9], F, D, dtype),
    }


def _group_norm(x, g, b, heads, eps=1e-5):
    B, S, D = x.shape
    xh = x.reshape(B, S, heads, D // heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return xh.reshape(B, S, D).astype(x.dtype) * g + b


def _decay(p, xw):
    """Data-dependent per-channel decay w_t ∈ (0, 1), near 1."""
    lora = linear(p["w_lora_b"], jnp.tanh(linear(p["w_lora_a"], xw)))
    return jnp.exp(-jnp.exp(p["w_base"] + lora.astype(jnp.float32)))


def _timemix_inputs(p, x, x_prev):
    """Token-shift lerp for each of r,k,v,g,w. x_prev: x shifted right."""
    mu = p["mu"]
    xs = [x + (x_prev - x) * mu[i] for i in range(5)]
    return xs  # r, k, v, g, w


def rwkv_timemix(p, cfg, x, x_prev, state_s):
    """x: (B,S,D); x_prev: right-shifted x; state_s: (B,H,dk,dv) or None."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    xr, xk, xv, xg, xw = _timemix_inputs(p, x, x_prev)
    r = linear(p["wr"], xr).reshape(B, S, H, hd)
    k = linear(p["wk"], xk).reshape(B, S, H, hd)
    v = linear(p["wv"], xv).reshape(B, S, H, hd)
    g = linear(p["wg"], xg)
    w = _decay(p, xw).reshape(B, S, H, hd)

    def to_bh(t):  # (B,S,H,hd) -> (B*H, S, hd)
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    chunk = 64 if S % 64 == 0 else (S if S < 64 else 1)
    if S % chunk:
        chunk = 1
    u_bh = jnp.tile(p["u"], (B, 1))                        # (B*H, hd)
    o, s_new = ops.linattn(to_bh(r).astype(jnp.float32),
                           to_bh(k).astype(jnp.float32),
                           to_bh(v).astype(jnp.float32),
                           to_bh(w), u_bh,
                           state=(state_s.reshape(B * H, hd, hd)
                                  if state_s is not None else None),
                           chunk=chunk)
    o = o.reshape(B, H, S, hd).transpose(0, 2, 1, 3).reshape(B, S, D)
    o = _group_norm(o.astype(x.dtype), p["gn_g"], p["gn_b"], H)
    out = linear(p["wo"], o * jax.nn.silu(g))
    return out, s_new.reshape(B, H, hd, hd)


def rwkv_channelmix(p, x, x_prev):
    mu = p["mu_c"]
    xk = x + (x_prev - x) * mu[0]
    xr = x + (x_prev - x) * mu[1]
    kk = jnp.square(jax.nn.relu(linear(p["ck"], xk)))
    return jax.nn.sigmoid(linear(p["cr"], xr)) * linear(p["cv"], kk)


def _shift_right(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv_block(p, cfg, x, norms, return_state: bool = False):
    """Full-sequence training/prefill. norms = (ln1, ln2) rmsnorm params.
    With ``return_state`` also returns the RWKVState after the last token
    (stateful prefill for serving)."""
    from repro.models.transformer.common import rmsnorm
    h = rmsnorm(norms[0], x)
    tm, s_new = rwkv_timemix(p, cfg, h, _shift_right(h), None)
    tm_x_last = h[:, -1]
    x = x + tm
    h2 = rmsnorm(norms[1], x)
    x = x + rwkv_channelmix(p, h2, _shift_right(h2))
    if return_state:
        return x, RWKVState(s=s_new, tm_x=tm_x_last, cm_x=h2[:, -1])
    return x


def rwkv_block_decode(p, cfg, x, norms, state: RWKVState):
    """x: (B, 1, D) one token; returns (x, new_state)."""
    from repro.models.transformer.common import rmsnorm
    B, _, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    h = rmsnorm(norms[0], x)
    h_prev = state.tm_x[:, None, :]
    xr, xk, xv, xg, xw = _timemix_inputs(p, h, h_prev)
    r = linear(p["wr"], xr).reshape(B, H, hd)
    k = linear(p["wk"], xk).reshape(B, H, hd)
    v = linear(p["wv"], xv).reshape(B, H, hd)
    g = linear(p["wg"], xg)
    w = _decay(p, xw).reshape(B, H, hd)
    o, s_new = ops.linattn_step(
        r.reshape(B * H, hd).astype(jnp.float32),
        k.reshape(B * H, hd).astype(jnp.float32),
        v.reshape(B * H, hd).astype(jnp.float32),
        w.reshape(B * H, hd), jnp.tile(p["u"], (B, 1)),
        state.s.reshape(B * H, hd, hd))
    o = o.reshape(B, 1, D).astype(x.dtype)
    o = _group_norm(o, p["gn_g"], p["gn_b"], H)
    x = x + linear(p["wo"], o * jax.nn.silu(g))
    tm_x_new = h[:, 0]

    h2 = rmsnorm(norms[1], x)
    x = x + rwkv_channelmix(p, h2, state.cm_x[:, None, :])
    return x, RWKVState(s=s_new.reshape(B, H, hd, hd),
                        tm_x=tm_x_new, cm_x=h2[:, 0])
