"""Assigned-architecture transformer stack (DESIGN.md §4).

Families: dense GQA (± SWA, ± QKV bias, swiglu/sqrelu/gelu), MoE
(shared + routed top-k, HopMoE α-dispatch), RWKV6 SSM, RG-LRU hybrid,
whisper-style encoder-decoder (audio), and VLM (stub patch frontend +
dense decoder).
"""
from repro.models.transformer.config import ArchConfig
from repro.models.transformer.model import (
    DecodeState, decode_step, forward, init_decode_state, init_params,
    loss_fn, prefill)

__all__ = ["ArchConfig", "DecodeState", "decode_step", "forward",
           "init_decode_state", "init_params", "loss_fn", "prefill"]
