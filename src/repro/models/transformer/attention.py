"""Grouped-query attention with sliding-window and KV-cache support.

Three entry points:

* ``attend_full``  — training / prefill over a whole sequence. Blockwise
  online-softmax over KV chunks (flash-attention re-expressed in lax.scan):
  the (S, S) score matrix never materializes, which is what lets the 32k
  prefill shapes compile within VMEM/HBM budgets.
* ``attend_decode`` — one query token against a (possibly ring-buffered)
  KV cache; the serve_step path.
* ``Cache`` helpers — allocate / update caches. Sliding-window archs keep a
  ring buffer of ``window`` entries, which is what makes long_500k decode
  feasible for them (bounded state; DESIGN.md §4).

Keys are RoPE'd at *write* time with absolute positions, queries at read
time — the standard cache-friendly formulation.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer.common import apply_rope


def _repeat_kv(x: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, K, Dh) -> (B, S, K*groups, Dh) by repeating each kv head."""
    if groups == 1:
        return x
    b, s, k, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, k, groups, d)
                            ).reshape(b, s, k * groups, d)


def attend_full(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                *, causal: bool = True, window: Optional[int] = None,
                q_offset: int = 0, kv_chunk: int = 1024) -> jnp.ndarray:
    """q: (B, Sq, H, Dh); k, v: (B, Skv, K, Dh) with H % K == 0.

    Returns (B, Sq, H, Dh). Online-softmax over KV chunks; causal and
    window masks are applied per chunk. ``q_offset`` is the absolute
    position of q[0] relative to k[0] (prefill continuation).

    Memory discipline (the nemotron-340b fit depends on this): GQA heads
    are *grouped in the einsum*, never materialized via repeat; q/k/v stay
    in their storage dtype, with f32 appearing only in the per-chunk score
    block and the (B, H, Sq, Dh) accumulator.
    """
    b, sq, h, dh = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    scale = dh ** -0.5
    ck = min(kv_chunk, skv)
    nck = -(-skv // ck)
    pad = nck * ck - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    q5 = q.reshape(b, sq, kh, g, dh)
    kc_all = k.reshape(b, nck, ck, kh, dh)
    vc_all = v.reshape(b, nck, ck, kh, dh)
    q_pos = q_offset + jnp.arange(sq)

    def kv_step(carry, inputs):
        m, l, acc = carry
        kc, vc, c = inputs                      # (B,ck,K,Dh) ×2, chunk idx
        kv_pos = c * ck + jnp.arange(ck)
        s = jnp.einsum("bqkgd,bckd->bkgqc", q5, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = kv_pos[None, :] <= (skv - 1)     # padding
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kh, g, sq), -jnp.inf),
            jnp.zeros((b, kh, g, sq)),
            jnp.zeros((b, kh, g, sq, dh)))
    (m, l, acc), _ = jax.lax.scan(
        kv_step, init,
        (kc_all.transpose(1, 0, 2, 3, 4), vc_all.transpose(1, 0, 2, 3, 4),
         jnp.arange(nck)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,K,G,Sq,Dh)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, L, K, Dh) — L = min(max_seq, window)
    v: jnp.ndarray
    pos: jnp.ndarray        # () int32 — absolute count of tokens written


def init_kv_cache(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                  dtype, window: Optional[int] = None) -> KVCache:
    length = min(max_seq, window) if window else max_seq
    return KVCache(
        k=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, length, kv_heads, head_dim), dtype),
        pos=jnp.zeros((), jnp.int32))


def cache_append(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray
                 ) -> KVCache:
    """Append one token (k_new, v_new: (B, 1, K, Dh)); ring-buffered."""
    length = cache.k.shape[1]
    slot = cache.pos % length
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    return KVCache(k=k, v=v, pos=cache.pos + 1)


def attend_decode(q: jnp.ndarray, cache: KVCache, *,
                  window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, 1, H, Dh) for the token at absolute position cache.pos - 1
    (already appended). Attends to every valid cache entry. GQA heads are
    grouped in the einsum (no repeated-KV materialization — a 12× temp for
    nemotron's 96q/8kv)."""
    b, _, h, dh = q.shape
    length, kh = cache.k.shape[1], cache.k.shape[2]
    g = h // kh
    scale = dh ** -0.5
    q5 = q.reshape(b, kh, g, dh)
    s = jnp.einsum("bkgd,bckd->bkgc", q5, cache.k,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(length) < cache.pos          # ring: all valid once full
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p.astype(cache.v.dtype), cache.v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full GQA block (projections + rope + attend)
# ---------------------------------------------------------------------------

def init_attn(key, cfg, dtype, d_model=None):
    from repro.models.transformer.common import init_linear
    D = d_model or cfg.d_model
    dh, H, K = cfg.hdim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], D, H * dh, dtype, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], D, K * dh, dtype, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], D, K * dh, dtype, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], H * dh, D, dtype),
    }


def attn_forward(p, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                 window: Optional[int] = None,
                 kv_chunk: int = 1024) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill)."""
    from repro.models.transformer.common import linear, shard
    b, s, _ = x.shape
    dh, H, K = cfg.hdim, cfg.num_heads, cfg.num_kv_heads
    q = linear(p["wq"], x).reshape(b, s, H, dh)
    k = linear(p["wk"], x).reshape(b, s, K, dh)
    v = linear(p["wv"], x).reshape(b, s, K, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.kv_tp_repeat > 1:
        # replicate KV heads so the grouped attention shards cleanly on a
        # single (K·rep)-sized head axis across TP — §Perf iteration
        k = _repeat_kv(k, cfg.kv_tp_repeat)
        v = _repeat_kv(v, cfg.kv_tp_repeat)
        k = shard(k, "dp", None, "tp", None)
        v = shard(v, "dp", None, "tp", None)
        q = shard(q, "dp", None, "tp", None)
    o = attend_full(q, k, v, causal=True, window=window, kv_chunk=kv_chunk)
    return linear(p["wo"], o.reshape(b, s, H * dh))


def attn_decode(p, cfg, x: jnp.ndarray, cache: KVCache,
                window: Optional[int] = None) -> tuple[jnp.ndarray, KVCache]:
    """x: (B, 1, D) single token; returns (out (B,1,D), updated cache)."""
    from repro.models.transformer.common import linear
    b = x.shape[0]
    dh, H, K = cfg.hdim, cfg.num_heads, cfg.num_kv_heads
    pos = cache.pos[None]                           # absolute position
    q = linear(p["wq"], x).reshape(b, 1, H, dh)
    k = linear(p["wk"], x).reshape(b, 1, K, dh)
    v = linear(p["wv"], x).reshape(b, 1, K, dh)
    q = apply_rope(q, jnp.broadcast_to(pos, (b, 1)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(pos, (b, 1)), cfg.rope_theta)
    cache = cache_append(cache, k, v)
    o = attend_decode(q, cache, window=window)
    return linear(p["wo"], o.reshape(b, 1, H * dh)), cache
