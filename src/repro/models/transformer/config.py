"""Architecture configuration for the assigned-architecture stack.

One frozen dataclass describes every family the pool spans: dense GQA
(± sliding window, ± QKV bias, several MLP activations), MoE
(shared + routed top-k), attention-free SSM (RWKV6), hybrid recurrent
(RG-LRU + local attention), encoder-decoder audio (whisper), and VLM
(vision-stub + decoder). ``src/repro/configs/<id>.py`` instantiates one of
these per assigned architecture with the exact published numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention options ---
    head_dim: Optional[int] = None          # default d_model // num_heads
    qkv_bias: bool = False                  # qwen2 family
    swa_window: Optional[int] = None        # sliding-window attention
    rope_theta: float = 10_000.0

    # --- MLP options ---
    mlp: str = "swiglu"           # swiglu | sqrelu | gelu
    # --- MoE options ---
    moe_num_experts: int = 0               # routed experts (0 = dense MLP)
    moe_top_k: int = 0
    moe_num_shared: int = 0
    moe_expert_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_dispatch: str = "tokens"           # tokens | weights | auto (HopMoE α)

    # --- hybrid / ssm options ---
    block_pattern: Optional[Sequence[str]] = None   # e.g. ("rec","rec","attn")
    rglru_width: int = 0                   # RG-LRU recurrence width (=d_model)
    local_attn_window: int = 2048
    conv_width: int = 4
    rwkv_head_dim: int = 64

    # --- encoder-decoder / multimodal options ---
    encoder_layers: int = 0                # whisper encoder depth
    encoder_seq: int = 0                   # stub frontend output length
    encoder_d_model: int = 0
    num_patches: int = 0                   # VLM stub patch count (train shape)
    patch_dim: int = 0                     # stub patch embedding width

    # --- sharding/perf knobs (§Perf) ---
    kv_tp_repeat: int = 1      # replicate KV heads so K·rep divides the TP
    #                            axis — standard GQA-under-TP practice; kills
    #                            GSPMD's mixed 2-axis head split (§Perf it.)

    # --- training ---
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    vocab_pad_to: int = 2048               # Megatron-style padded vocab shard

    # --- citation ---
    source: str = ""

    @property
    def hdim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return (self.vocab_size + m - 1) // m * m

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context (bounded per-token state)?"""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.swa_window is not None

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        total = V * D                                  # embed
        if not self.tie_embeddings:
            total += V * D                             # lm head
        per_layer = 0
        if self.family == "ssm":
            H = D // self.rwkv_head_dim
            # rwkv6: r,k,v,g,o projections + decay/ln params + channel mix
            per_layer = 5 * D * D + 2 * D * 64 + 2 * D + D // 1 \
                + D * F + F * D + D * D
        else:
            kv = self.num_kv_heads * self.hdim
            q = self.num_heads * self.hdim
            attn = D * q + 2 * D * kv + q * D
            if self.mlp == "swiglu":
                mlp = 3 * D * F
            else:
                mlp = 2 * D * F
            if self.moe_num_experts:
                fe = self.moe_expert_d_ff
                routed = self.moe_num_experts * 3 * D * fe
                shared = self.moe_num_shared * 3 * D * fe
                mlp = routed + shared + D * self.moe_num_experts
            per_layer = attn + mlp + 2 * D
        total += self.num_layers * per_layer
        if self.encoder_layers:
            De = self.encoder_d_model or D
            enc = self.encoder_layers * (4 * De * De + 2 * De * (4 * De) + 2 * De)
            total += enc + self.num_layers * (2 * De * D + 2 * D * self.hdim * self.num_heads)
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: shared + top-k routed)."""
        if not self.moe_num_experts:
            return self.param_count()
        D, fe = self.d_model, self.moe_expert_d_ff
        inactive = (self.moe_num_experts - self.moe_top_k) * 3 * D * fe
        return int(self.param_count() - self.num_layers * inactive)
