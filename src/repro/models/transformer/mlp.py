"""Feed-forward variants used across the assigned architectures.

* ``swiglu``  — llama/mistral/qwen family: silu(x W_g) ⊙ (x W_u) W_d.
* ``sqrelu``  — nemotron-4: relu(x W_u)² W_d (squared-ReLU, 2 matrices).
* ``gelu``    — whisper/ViT classic: gelu(x W_u) W_d.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer.common import init_linear, linear


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wg": init_linear(ks[0], d_model, d_ff, dtype),
                "wu": init_linear(ks[1], d_model, d_ff, dtype),
                "wd": init_linear(ks[2], d_ff, d_model, dtype)}
    if kind in ("sqrelu", "gelu"):
        return {"wu": init_linear(ks[0], d_model, d_ff, dtype),
                "wd": init_linear(ks[1], d_ff, d_model, dtype)}
    raise ValueError(kind)


def mlp_forward(p, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        return linear(p["wd"], jax.nn.silu(linear(p["wg"], x))
                      * linear(p["wu"], x))
    if kind == "sqrelu":
        h = jax.nn.relu(linear(p["wu"], x))
        return linear(p["wd"], jnp.square(h))
    if kind == "gelu":
        return linear(p["wd"], jax.nn.gelu(linear(p["wu"], x)))
    raise ValueError(kind)
