"""Unified model assembly for every assigned architecture family.

One functional API over plain-dict params:

  * ``init_params(key, cfg)``            — pure (traceable via eval_shape)
  * ``forward(params, cfg, batch)``      — train/prefill logits (+ aux)
  * ``loss_fn(params, cfg, batch)``      — next-token CE (+ MoE aux)
  * ``init_decode_state(cfg, batch, max_seq)`` — per-family cache pytree
  * ``decode_step(params, cfg, token, state)`` — one-token serve step

Layers are *scanned* over stacked parameters (HLO size O(1) in depth —
a 96-layer nemotron lowers like a 1-layer model plus a loop), with
``jax.checkpoint`` on the per-layer body for activation remat. Hybrid
architectures scan over pattern *groups* (e.g. (rec, rec, attn)) plus an
explicit tail when depth isn't a multiple of the period.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer.attention import (
    KVCache, attn_decode, attn_forward, init_attn, init_kv_cache)
from repro.models.transformer.common import (
    cross_entropy, init_linear, init_rmsnorm, linear, rmsnorm, shard)
from repro.models.transformer.config import ArchConfig
from repro.models.transformer.mlp import init_mlp, mlp_forward
from repro.models.transformer.moe import init_moe, moe_forward
from repro.models.transformer import encdec
from repro.models.transformer.rglru import (
    RGLRUState, init_rglru_block, init_rglru_state, rglru_block,
    rglru_block_decode)
from repro.models.transformer.rwkv6 import (
    RWKVState, init_rwkv_block, rwkv_block, rwkv_block_decode)


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# Scan unroll factor. XLA's HloCostAnalysis counts a while-loop body ONCE
# regardless of trip count; the dry-run lowers each program twice (unroll=1
# and unroll=2) and extrapolates true FLOPs/bytes as f1 + (L-1)·(f2-f1).
_SCAN_UNROLL = [1]

# Sequence parallelism (Korthikanti et al.): shard the residual stream's
# *sequence* dim over the model axis at layer-scan boundaries. The scan's
# saved-carry stack (L, B, S, D) — the dominant training temp — then shards
# 16× over tp; GSPMD inserts the gather before attention, exactly the
# sequence-parallel collective schedule. Toggleable for §Perf A/B runs.
_SEQ_SHARD = [True]


def set_sequence_sharding(on: bool) -> None:
    _SEQ_SHARD[0] = bool(on)


def _carry_shard(x):
    if _SEQ_SHARD[0]:
        return shard(x, "dp", "tp", None)
    return shard(x, "dp", None, None)


def set_scan_unroll(k: int) -> None:
    _SCAN_UNROLL[0] = int(k)


# Remat policy for the per-layer checkpoint. "full" recomputes everything
# (min memory, but collectives inside the layer fire twice — fwd and
# recompute); "dots" saves matmul outputs, so the backward pass reuses them
# and cross-shard partial-sum reductions run once (§Perf lever).
_REMAT_POLICY = ["full"]


def set_remat_policy(name: str) -> None:
    assert name in ("full", "dots"), name
    _REMAT_POLICY[0] = name


def _ckpt(fn):
    if _REMAT_POLICY[0] == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=_SCAN_UNROLL[0])


def scan_length(cfg: ArchConfig) -> int:
    """Trip count of the layer scan(s) — the dry-run's extrapolation L.
    (For audio, encoder and decoder scans share the same length.)"""
    if cfg.family == "hybrid":
        return cfg.num_layers // len(tuple(cfg.block_pattern))
    if cfg.family == "audio":
        assert cfg.encoder_layers == cfg.num_layers
        return cfg.num_layers
    return cfg.num_layers


# ===========================================================================
# init
# ===========================================================================

def _init_dense_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": init_rmsnorm(cfg.d_model, dtype),
         "attn": init_attn(k1, cfg, dtype),
         "ln2": init_rmsnorm(cfg.d_model, dtype)}
    if cfg.moe_num_experts:
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def _init_rwkv_layer(key, cfg, dtype):
    return {"ln1": init_rmsnorm(cfg.d_model, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "blk": init_rwkv_block(key, cfg, dtype)}


def _init_hybrid_position(key, cfg, dtype, kind: str):
    k1, k2 = jax.random.split(key)
    if kind == "rec":
        p = {"ln1": init_rmsnorm(cfg.d_model, dtype),
             "blk": init_rglru_block(k1, cfg, dtype)}
    else:
        p = {"ln1": init_rmsnorm(cfg.d_model, dtype),
             "attn": init_attn(k1, cfg, dtype)}
    p["ln2"] = init_rmsnorm(cfg.d_model, dtype)
    p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
    return p


def init_params(key, cfg: ArchConfig):
    dtype = cfg.activation_dtype
    D, V = cfg.d_model, cfg.padded_vocab
    keys = jax.random.split(key, cfg.num_layers + 8)
    p: dict[str, Any] = {
        "embed": (jax.random.normal(keys[-1], (V, D)) * 0.02).astype(dtype),
        "norm_f": init_rmsnorm(D, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(keys[-2], (D, V)) * 0.02).astype(dtype)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        p["layers"] = _stack([_init_dense_layer(keys[i], cfg, dtype)
                              for i in range(cfg.num_layers)])
        if fam == "vlm":
            p["patch_proj"] = init_linear(keys[-3], cfg.patch_dim, D, dtype)
    elif fam == "ssm":
        p["layers"] = _stack([_init_rwkv_layer(keys[i], cfg, dtype)
                              for i in range(cfg.num_layers)])
    elif fam == "hybrid":
        pat = tuple(cfg.block_pattern)
        period = len(pat)
        n_groups = cfg.num_layers // period
        rem = cfg.num_layers - n_groups * period
        groups = []
        ki = 0
        for g in range(n_groups):
            grp = [_init_hybrid_position(keys[ki + j], cfg, dtype, pat[j])
                   for j in range(period)]
            ki += period
            groups.append({"blocks": tuple(grp)})
        p["groups"] = _stack(groups)
        p["tail"] = [
            _init_hybrid_position(keys[ki + j], cfg, dtype, pat[j % period])
            for j in range(rem)]
    elif fam == "audio":
        De = cfg.encoder_d_model or D
        ek = jax.random.split(keys[-4], cfg.encoder_layers)
        dk = jax.random.split(keys[-5], cfg.num_layers)
        p["enc_pos"] = (jax.random.normal(keys[-6], (cfg.encoder_seq, De))
                        * 0.02).astype(dtype)
        p["enc_layers"] = _stack([
            encdec.init_encoder_layer(ek[i], De, cfg.num_heads, De * 4, dtype)
            for i in range(cfg.encoder_layers)])
        p["enc_ln_f"] = init_rmsnorm(De, dtype)
        p["dec_layers"] = _stack([
            encdec.init_decoder_layer(dk[i], D, cfg.num_heads, cfg.d_ff,
                                      dtype)
            for i in range(cfg.num_layers)])
    else:
        raise ValueError(fam)
    return p


# ===========================================================================
# layer bodies
# ===========================================================================

def _dense_layer_fwd(layer_p, cfg, x, positions):
    h = rmsnorm(layer_p["ln1"], x)
    x = x + attn_forward(layer_p["attn"], cfg, h, positions,
                         window=cfg.swa_window)
    x = shard(x, "dp", None, None)
    h = rmsnorm(layer_p["ln2"], x)
    if cfg.moe_num_experts:
        y, stats = moe_forward(layer_p["moe"], cfg, h)
        return x + y, stats.aux_loss
    return x + mlp_forward(layer_p["mlp"], h, cfg.mlp), jnp.zeros(())


def _hybrid_position_fwd(pos_p, cfg, x, positions, kind: str):
    if kind == "rec":
        x = rglru_block(pos_p["blk"], cfg, x, pos_p["ln1"])
    else:
        h = rmsnorm(pos_p["ln1"], x)
        x = x + attn_forward(pos_p["attn"], cfg, h, positions,
                             window=cfg.local_attn_window)
    h = rmsnorm(pos_p["ln2"], x)
    return x + mlp_forward(pos_p["mlp"], h, cfg.mlp)


# ===========================================================================
# forward (train / prefill logits)
# ===========================================================================

def forward_hidden(params, cfg: ArchConfig, batch: dict
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Backbone only: returns (final-normed hidden (B, S, D), aux_loss).

    batch keys by family:
      dense/moe/ssm/hybrid: tokens (B, S)
      vlm:   tokens (B, S_text), patches (B, P, patch_dim); S = P + S_text
      audio: tokens (B, S_dec), frames (B, S_enc, De)
    """
    fam = cfg.family
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if fam == "vlm":
        pe = linear(params["patch_proj"], batch["patches"])
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
    x = shard(x, "dp", None, None)
    B, S, D = x.shape
    positions = jnp.arange(S)

    aux = jnp.zeros(())
    if fam in ("dense", "moe", "vlm"):
        def body(carry, layer_p):
            x, aux = carry
            x, a = _dense_layer_fwd(layer_p, cfg, x, positions)
            return (_carry_shard(x), aux + a), None
        (x, aux), _ = _scan(_ckpt(body), (x, aux),
                            params["layers"])
    elif fam == "ssm":
        def body(carry, layer_p):
            x = rwkv_block(layer_p["blk"], cfg, carry,
                           (layer_p["ln1"], layer_p["ln2"]))
            return _carry_shard(x), None
        x, _ = _scan(_ckpt(body), x, params["layers"])
    elif fam == "hybrid":
        period = len(tuple(cfg.block_pattern))

        pat = tuple(cfg.block_pattern)

        def body(x, grp):
            for j in range(period):
                x = _hybrid_position_fwd(grp["blocks"][j], cfg, x, positions,
                                         pat[j])
            return _carry_shard(x), None
        x, _ = _scan(_ckpt(body), x, params["groups"])
        for j, pos_p in enumerate(params["tail"]):
            x = _hybrid_position_fwd(pos_p, cfg, x, positions,
                                     pat[j % period])
    elif fam == "audio":
        De = cfg.encoder_d_model or D
        enc = batch["frames"].astype(x.dtype) + params["enc_pos"]

        def ebody(e, layer_p):
            return _carry_shard(
                encdec.encoder_layer(layer_p, e, cfg.num_heads)), None
        enc, _ = _scan(_ckpt(ebody), enc, params["enc_layers"])
        enc = rmsnorm(params["enc_ln_f"], enc)

        def dbody(x, layer_p):
            return _carry_shard(
                encdec.decoder_layer(layer_p, x, enc, cfg.num_heads)), None
        x, _ = _scan(_ckpt(dbody), x, params["dec_layers"])
    else:
        raise ValueError(fam)

    return rmsnorm(params["norm_f"], x), aux


def _head_matrix(params):
    head = params.get("head")
    return head if head is not None else params["embed"].T


def forward(params, cfg: ArchConfig, batch: dict) -> tuple[jnp.ndarray,
                                                           jnp.ndarray]:
    """Full logits (B, S, V_padded) — serving/debug path. Training goes
    through ``loss_fn`` (chunked CE; full-sequence f32 logits never
    materialize)."""
    x, aux = forward_hidden(params, cfg, batch)
    logits = x @ _head_matrix(params)
    return shard(logits, "dp", None, "tp"), aux


def _labels_and_mask(cfg: ArchConfig, batch: dict, S: int):
    """Next-token labels aligned to hidden positions, with a validity mask.
    For VLM, position p ≥ P-1 predicts text token p-(P-1); the patch prefix
    itself is unsupervised."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    if cfg.family == "vlm":
        P = batch["patches"].shape[1]
        s_text = tokens.shape[1]
        idx = jnp.arange(S) - (P - 1)
        valid = (idx >= 0) & (idx < s_text)
        labels = jnp.take(tokens, jnp.clip(idx, 0, s_text - 1), axis=1)
        mask = jnp.broadcast_to(valid[None], (B, S))
        # last position has no next token
        mask = mask & (jnp.arange(S) < S - 1)[None]
        return labels, mask
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((B, 1), tokens.dtype)], axis=1)
    mask = jnp.broadcast_to((jnp.arange(S) < S - 1)[None], (B, S))
    return labels, mask


def chunked_ce(params, x: jnp.ndarray, labels: jnp.ndarray,
               mask: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy scanned over sequence chunks with remat: the (B, S, V)
    f32 logits tensor never exists — per chunk only (B, C, V) does. This is
    what lets the 256k-vocab configs train within HBM."""
    W = _head_matrix(params)
    B, S, D = x.shape
    C = min(chunk, S)
    pad = (-S) % C
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = x.shape[1] // C

    def body(carry, xs):
        s_nll, s_cnt = carry
        xc, lc, mc = xs                             # (B,C,D), (B,C), (B,C)
        logits = (xc @ W).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        m = mc.astype(jnp.float32)
        return (s_nll + jnp.sum((logz - gold) * m), s_cnt + jnp.sum(m)), None

    xs = (x.reshape(B, nc, C, D).transpose(1, 0, 2, 3),
          labels.reshape(B, nc, C).transpose(1, 0, 2),
          mask.reshape(B, nc, C).transpose(1, 0, 2))
    (s_nll, s_cnt), _ = jax.lax.scan(
        _ckpt(body), (jnp.zeros(()), jnp.zeros(())), xs)
    return s_nll / jnp.maximum(s_cnt, 1.0)


def loss_fn(params, cfg: ArchConfig, batch: dict,
            aux_weight: float = 0.01) -> tuple[jnp.ndarray, dict]:
    x, aux = forward_hidden(params, cfg, batch)
    labels, mask = _labels_and_mask(cfg, batch, x.shape[1])
    ce = chunked_ce(params, x, labels, mask)
    total = ce + aux_weight * aux
    return total, {"ce": ce, "aux": aux}


# ===========================================================================
# decode
# ===========================================================================

class DecodeState(NamedTuple):
    caches: Any             # per-family stacked cache pytree
    tail: Any               # hybrid tail caches (list) or None
    enc: Any                # audio: encoder output; vlm/dense: None


def init_decode_state(cfg: ArchConfig, batch: int, max_seq: int,
                      enc: Optional[jnp.ndarray] = None,
                      params=None) -> DecodeState:
    dtype = cfg.activation_dtype
    fam = cfg.family
    L = cfg.num_layers
    if fam in ("dense", "moe", "vlm"):
        def one():
            return init_kv_cache(batch, max_seq, cfg.num_kv_heads, cfg.hdim,
                                 dtype, window=cfg.swa_window)
        caches = _stack([one() for _ in range(L)])
        return DecodeState(caches=caches, tail=None, enc=None)
    if fam == "ssm":
        hd = cfg.rwkv_head_dim
        H = cfg.d_model // hd

        def one():
            return RWKVState(s=jnp.zeros((batch, H, hd, hd), jnp.float32),
                             tm_x=jnp.zeros((batch, cfg.d_model), dtype),
                             cm_x=jnp.zeros((batch, cfg.d_model), dtype))
        return DecodeState(caches=_stack([one() for _ in range(L)]),
                           tail=None, enc=None)
    if fam == "hybrid":
        pat = tuple(cfg.block_pattern)
        period = len(pat)
        n_groups = L // period
        rem = L - n_groups * period

        def pos_cache(kind):
            if kind == "rec":
                return init_rglru_state(batch, cfg)
            return init_kv_cache(batch, max_seq, cfg.num_kv_heads, cfg.hdim,
                                 dtype, window=cfg.local_attn_window)
        groups = _stack([
            {"blocks": tuple(pos_cache(pat[j]) for j in range(period))}
            for _ in range(n_groups)])
        tail = [pos_cache(pat[j % period]) for j in range(rem)]
        return DecodeState(caches=groups, tail=tail, enc=None)
    if fam == "audio":
        assert enc is not None and params is not None
        dec = [encdec.init_decoder_cache(
            jax.tree.map(lambda t: t[i], params["dec_layers"]), enc, batch,
            max_seq, cfg.num_heads, cfg.d_model, dtype)
            for i in range(L)]
        return DecodeState(caches=_stack(dec), tail=None, enc=enc)
    raise ValueError(fam)


def decode_step(params, cfg: ArchConfig, token: jnp.ndarray,
                state: DecodeState) -> tuple[jnp.ndarray, DecodeState]:
    """token: (B,) int32 — returns (logits (B, V), new state)."""
    fam = cfg.family
    x = jnp.take(params["embed"], token[:, None], axis=0)   # (B, 1, D)

    if fam in ("dense", "moe", "vlm"):
        def body(x, xs):
            layer_p, cache = xs
            h = rmsnorm(layer_p["ln1"], x)
            a, cache = attn_decode(layer_p["attn"], cfg, h, cache,
                                   window=cfg.swa_window)
            x = x + a
            h = rmsnorm(layer_p["ln2"], x)
            if cfg.moe_num_experts:
                y, _ = moe_forward(layer_p["moe"], cfg, h)
                x = x + y
            else:
                x = x + mlp_forward(layer_p["mlp"], h, cfg.mlp)
            return x, cache
        x, caches = _scan(body, x, (params["layers"], state.caches))
        state = state._replace(caches=caches)
    elif fam == "ssm":
        def body(x, xs):
            layer_p, st = xs
            x, st = rwkv_block_decode(layer_p["blk"], cfg, x,
                                      (layer_p["ln1"], layer_p["ln2"]), st)
            return x, st
        x, caches = _scan(body, x, (params["layers"], state.caches))
        state = state._replace(caches=caches)
    elif fam == "hybrid":
        period = len(tuple(cfg.block_pattern))

        pat = tuple(cfg.block_pattern)

        def body(x, xs):
            grp_p, grp_c = xs
            new_c = []
            for j in range(period):
                x, pos_c = _hybrid_position_decode(
                    grp_p["blocks"][j], cfg, x, grp_c["blocks"][j], pat[j])
                new_c.append(pos_c)
            return x, {"blocks": tuple(new_c)}
        x, caches = _scan(body, x, (params["groups"], state.caches))
        new_tail = []
        for j, (pos_p, pos_c) in enumerate(zip(params["tail"], state.tail)):
            x, pos_c = _hybrid_position_decode(pos_p, cfg, x, pos_c,
                                               pat[j % period])
            new_tail.append(pos_c)
        state = state._replace(caches=caches, tail=new_tail)
    elif fam == "audio":
        def body(x, xs):
            layer_p, cache = xs
            x, cache = encdec.decoder_layer_decode(layer_p, x, cache,
                                                   cfg.num_heads)
            return x, cache
        x, caches = _scan(body, x, (params["dec_layers"], state.caches))
        state = state._replace(caches=caches)
    else:
        raise ValueError(fam)

    x = rmsnorm(params["norm_f"], x)
    head = params.get("head")
    logits = (x @ (head if head is not None else params["embed"].T))[:, 0]
    return logits, state


def _hybrid_position_decode(pos_p, cfg, x, pos_c, kind: str):
    if kind == "rec":
        x, pos_c = rglru_block_decode(pos_p["blk"], cfg, x, pos_p["ln1"],
                                      pos_c)
    else:
        h = rmsnorm(pos_p["ln1"], x)
        a, pos_c = attn_decode(pos_p["attn"], cfg, h, pos_c,
                               window=cfg.local_attn_window)
        x = x + a
    h = rmsnorm(pos_p["ln2"], x)
    return x + mlp_forward(pos_p["mlp"], h, cfg.mlp), pos_c


# ===========================================================================
# prefill (forward + cache materialization for serving)
# ===========================================================================

def prefill(params, cfg: ArchConfig, batch: dict, max_seq: int
            ) -> tuple[jnp.ndarray, DecodeState]:
    """Run the prompt through the model, returning last-token logits and a
    decode-ready state. Dense/moe/vlm recompute K/V per layer (cheap relative
    to attention itself); ssm/hybrid prefill via their sequence paths."""
    fam = cfg.family
    if fam == "audio":
        # encoder once; decoder pass fills the self-attn caches (the decode
        # path must see the prompt's K/V — not a fresh cache)
        B = batch["tokens"].shape[0]
        enc = batch["frames"].astype(cfg.activation_dtype) + params["enc_pos"]

        def ebody(e, layer_p):
            return encdec.encoder_layer(layer_p, e, cfg.num_heads), None
        enc, _ = _scan(ebody, enc, params["enc_layers"])
        enc = rmsnorm(params["enc_ln_f"], enc)
        state = init_decode_state(cfg, B, max_seq, enc=enc, params=params)

        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        s_len = x.shape[1]

        def dbody(x, xs):
            layer_p, cache = xs
            from repro.models.transformer.common import layernorm
            h = layernorm(layer_p["ln1"], x)
            dh = cfg.d_model // cfg.num_heads
            k = linear(layer_p["self_attn"]["wk"], h).reshape(
                B, s_len, cfg.num_heads, dh)
            v = linear(layer_p["self_attn"]["wv"], h).reshape(
                B, s_len, cfg.num_heads, dh)
            length = cache.self_kv.k.shape[1]
            k_new = jax.lax.dynamic_update_slice_in_dim(
                cache.self_kv.k, k[:, :length], 0, axis=1)
            v_new = jax.lax.dynamic_update_slice_in_dim(
                cache.self_kv.v, v[:, :length], 0, axis=1)
            new_cache = cache._replace(self_kv=KVCache(
                k=k_new, v=v_new, pos=jnp.asarray(s_len, jnp.int32)))
            x = encdec.decoder_layer(layer_p, x, enc, cfg.num_heads)
            return x, new_cache
        x, caches = _scan(dbody, x, (params["dec_layers"], state.caches))
        state = state._replace(caches=caches)
        x = rmsnorm(params["norm_f"], x)
        return x[:, -1] @ _head_matrix(params), state

    tokens = batch["tokens"]
    B = tokens.shape[0]

    if fam in ("ssm", "hybrid"):
        # stateful prefill: thread the recurrent/window state through the
        # sequence pass so decode continues from the prompt
        x = jnp.take(params["embed"], tokens, axis=0)
        positions = jnp.arange(x.shape[1])
        if fam == "ssm":
            def body(x, layer_p):
                x, st = rwkv_block(layer_p["blk"], cfg, x,
                                   (layer_p["ln1"], layer_p["ln2"]),
                                   return_state=True)
                return x, st
            x, states = _scan(body, x, params["layers"])
            state = DecodeState(caches=states, tail=None, enc=None)
        else:
            pat = tuple(cfg.block_pattern)
            period = len(pat)

            def pos_prefill(pos_p, x, kind):
                if kind == "rec":
                    x2, st = rglru_block(pos_p["blk"], cfg, x,
                                         pos_p["ln1"], return_state=True)
                else:
                    h = rmsnorm(pos_p["ln1"], x)
                    cache = init_kv_cache(B, max_seq, cfg.num_kv_heads,
                                          cfg.hdim, cfg.activation_dtype,
                                          window=cfg.local_attn_window)
                    st = _prefill_kv(pos_p["attn"], cfg, h, positions,
                                     cache)
                    x2 = x + attn_forward(pos_p["attn"], cfg, h, positions,
                                          window=cfg.local_attn_window)
                h = rmsnorm(pos_p["ln2"], x2)
                return x2 + mlp_forward(pos_p["mlp"], h, cfg.mlp), st

            def gbody(x, grp):
                sts = []
                for j in range(period):
                    x, st = pos_prefill(grp["blocks"][j], x, pat[j])
                    sts.append(st)
                return x, {"blocks": tuple(sts)}
            x, gcaches = _scan(gbody, x, params["groups"])
            tail_sts = []
            for j, pos_p in enumerate(params["tail"]):
                x, st = pos_prefill(pos_p, x, pat[j % period])
                tail_sts.append(st)
            state = DecodeState(caches=gcaches, tail=tail_sts, enc=None)
        x = rmsnorm(params["norm_f"], x)
        return x[:, -1] @ _head_matrix(params), state

    x_h, _ = forward_hidden(params, cfg, batch)
    last_logits = x_h[:, -1] @ _head_matrix(params)
    state = init_decode_state(cfg, B, max_seq)

    if fam in ("dense", "moe", "vlm"):
        x = jnp.take(params["embed"], tokens, axis=0)
        if fam == "vlm":
            pe = linear(params["patch_proj"], batch["patches"])
            x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)

        def body(x, xs):
            layer_p, cache = xs
            h = rmsnorm(layer_p["ln1"], x)
            cache = _prefill_kv(layer_p["attn"], cfg, h, positions, cache)
            x, _ = _dense_layer_fwd(layer_p, cfg, x, positions)
            return x, cache
        _, caches = _scan(body, x, (params["layers"], state.caches))
        state = state._replace(caches=caches)
    return last_logits, state


def _prefill_kv(attn_p, cfg, h, positions, cache: KVCache) -> KVCache:
    from repro.models.transformer.common import linear as _lin
    from repro.models.transformer.common import apply_rope
    b, s, _ = h.shape
    K, dh = cfg.num_kv_heads, cfg.hdim
    k = _lin(attn_p["wk"], h).reshape(b, s, K, dh)
    v = _lin(attn_p["wv"], h).reshape(b, s, K, dh)
    k = apply_rope(k, positions, cfg.rope_theta)
    length = cache.k.shape[1]
    if s >= length:
        # ring-buffer layout: position p lives at slot p % length
        k_keep = jnp.roll(k[:, -length:], s % length, axis=1)
        v_keep = jnp.roll(v[:, -length:], s % length, axis=1)
        return KVCache(k=k_keep, v=v_keep,
                       pos=jnp.asarray(s, jnp.int32))
    k_new = jax.lax.dynamic_update_slice_in_dim(cache.k, k, 0, axis=1)
    v_new = jax.lax.dynamic_update_slice_in_dim(cache.v, v, 0, axis=1)
    return KVCache(k=k_new, v=v_new, pos=jnp.asarray(s, jnp.int32))
