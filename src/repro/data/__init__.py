"""Synthetic seeded data pipeline for the LLM configs.

Deterministic token streams (hash-based, like the graph sampler) so every
run and every test sees the same data without shipping a corpus. Batches
carry whatever extra modality inputs the family needs (stub patch/frame
embeddings for vlm/audio — the assignment's one sanctioned stub).
"""
from repro.data.pipeline import token_batches, make_batch

__all__ = ["token_batches", "make_batch"]
