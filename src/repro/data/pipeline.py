"""Synthetic token/modality batches, seeded and deterministic.

Tokens follow a Zipfian unigram draw with a Markov bigram twist so the loss
has learnable structure (pure-uniform tokens give a constant-loss landscape
and hide optimizer bugs). Modality stubs (patches/frames) are unit-Gaussian
embeddings of the configured width.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax.numpy as jnp

from repro.models.transformer.config import ArchConfig


def _zipf_markov_tokens(rng: np.random.Generator, batch: int, seq: int,
                        vocab: int) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq), p=p).astype(np.int32)
    # Markov twist: with prob .5, token t+1 = f(token t) — learnable bigram
    follow = rng.permutation(vocab).astype(np.int32)
    mask = rng.random((batch, seq - 1)) < 0.5
    toks[:, 1:] = np.where(mask, follow[toks[:, :-1]], toks[:, 1:])
    return toks


def make_batch(cfg: ArchConfig, batch: int, seq: int, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    out: dict = {}
    if cfg.family == "vlm":
        P = min(cfg.num_patches, max(seq // 4, 1))
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, P, cfg.patch_dim), dtype=np.float32))
        out["tokens"] = jnp.asarray(
            _zipf_markov_tokens(rng, batch, seq - P, cfg.vocab_size))
    elif cfg.family == "audio":
        De = cfg.encoder_d_model or cfg.d_model
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_seq, De),
                                dtype=np.float32))
        out["tokens"] = jnp.asarray(
            _zipf_markov_tokens(rng, batch, seq, cfg.vocab_size))
    else:
        out["tokens"] = jnp.asarray(
            _zipf_markov_tokens(rng, batch, seq, cfg.vocab_size))
    return out


def token_batches(cfg: ArchConfig, batch: int, seq: int, steps: int,
                  seed: int = 0) -> Iterator[dict]:
    for i in range(steps):
        yield make_batch(cfg, batch, seq, seed * 100_003 + i)
