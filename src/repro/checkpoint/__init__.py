"""Iteration-level checkpointing (paper §8 'Failure recovery') —
crash-atomic writes, truncated-checkpoint fallback on resume."""
from repro.checkpoint.store import (CheckpointCorrupt, latest_step,
                                    load_checkpoint, save_checkpoint,
                                    valid_steps)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "valid_steps", "CheckpointCorrupt"]
