"""Iteration-level checkpointing (paper §8).

LeapGNN's models visit several servers per iteration; the paper's insight is
that checkpointing at *iteration* boundaries (after gradients are applied
and partial-gradient state is cleared) needs only (iteration id, model
parameters) — no in-flight time-step state. We implement exactly that:
an ``npz`` of flattened pytree leaves plus a JSON manifest, atomic rename,
and a ``latest`` pointer. Works for both the GNN side and the LLM stack
(any pytree of arrays).
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


# npz cannot store ml_dtypes dtypes; view them as same-width ints.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, name))
    return arr


def _json_safe(obj):
    """Manifest ``extra`` payloads routinely carry numpy scalars (budget
    bucket sizes, epoch stats); coerce them instead of crashing the save."""
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj)!r}")


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> Path:
    """Atomically write ``step-<step>.npz`` + manifest; prune old ones."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        enc, name = _encode(np.asarray(x))
        arrays[f"leaf_{i}"] = enc
        dtypes.append(name)
    manifest = {"step": int(step), "num_leaves": len(leaves),
                "dtypes": dtypes, "treedef": str(treedef),
                "extra": extra or {}}

    final = directory / f"step-{step:08d}.npz"
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    (directory / f"step-{step:08d}.json").write_text(
        json.dumps(manifest, default=_json_safe))
    (directory / "latest").write_text(str(step))

    for old in sorted(directory.glob("step-*.npz"))[:-keep]:
        old.unlink(missing_ok=True)
        old.with_suffix(".json").unlink(missing_ok=True)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    p = Path(directory) / "latest"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def load_checkpoint(directory: str | Path, tree_like: Any,
                    step: Optional[int] = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``tree_like`` (shape/dtype template).
    Returns (tree, step, extra)."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(directory / f"step-{step:08d}.npz")
    manifest = json.loads((directory / f"step-{step:08d}.json").read_text())
    leaves, treedef = _flatten(tree_like)
    if len(leaves) != manifest["num_leaves"]:
        raise ValueError(
            f"leaf count mismatch: template {len(leaves)} vs "
            f"checkpoint {manifest['num_leaves']}")
    restored = [_decode(data[f"leaf_{i}"], manifest["dtypes"][i])
                for i in range(len(leaves))]
    tree = jax.tree.unflatten(treedef, restored)
    return tree, step, manifest["extra"]
