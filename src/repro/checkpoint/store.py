"""Iteration-level checkpointing (paper §8) — crash-atomic.

LeapGNN's models visit several servers per iteration; the paper's insight is
that checkpointing at *iteration* boundaries (after gradients are applied
and partial-gradient state is cleared) needs only (iteration id, model
parameters) — no in-flight time-step state. We implement exactly that:
an ``npz`` of flattened pytree leaves plus a JSON manifest, atomic rename,
and a ``latest`` pointer. Works for both the GNN side and the LLM stack
(any pytree of arrays).

Durability contract (repro.resilience): every file lands via
``temp file → flush → fsync → os.replace`` and the directory entry is
fsynced after, so a SIGKILL / power cut at ANY instant leaves either the
complete new checkpoint or the complete previous one — never a torn file
under a final name. Older checkpoints are pruned only *after* the new one
(npz + manifest + ``latest``) is durable. On resume,
:func:`load_checkpoint` with ``step=None`` validates candidates newest-
first and falls back past a truncated/corrupt one with a warning instead
of training on garbage (an explicitly requested ``step`` still fails
loudly — the caller asked for that exact state).
"""
from __future__ import annotations

import json
import os
import tempfile
import warnings
import zipfile
import zlib
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs.trace import span as _obs_span


class CheckpointCorrupt(RuntimeError):
    """A checkpoint's files exist but cannot be decoded (truncated write,
    bit rot, missing manifest) — resume should fall back, not crash."""


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


# npz cannot store ml_dtypes dtypes; view them as same-width ints.
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _EXOTIC:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, name))
    return arr


def _json_safe(obj):
    """Manifest ``extra`` payloads routinely carry numpy scalars (budget
    bucket sizes, epoch stats); coerce them instead of crashing the save."""
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(f"not JSON-serializable: {type(obj)!r}")


def _fsync_dir(directory: Path) -> None:
    """Make renamed directory entries durable (no-op where unsupported)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write(directory: Path, final: Path, payload) -> None:
    """temp file → flush → fsync → os.replace under ``final``'s directory.
    ``payload(f)`` writes to the open binary file object."""
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            payload(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> Path:
    """Crash-atomically write ``step-<step>.npz`` + manifest; prune old
    ones only once the new checkpoint is fully durable."""
    with _obs_span("ckpt.save", step=int(step)):
        _obs_metrics.inc("ckpt.saves")
        return _save_checkpoint(directory, step, tree, extra, keep)


def _save_checkpoint(directory, step, tree, extra, keep) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        enc, name = _encode(np.asarray(x))
        arrays[f"leaf_{i}"] = enc
        dtypes.append(name)
    manifest = {"step": int(step), "num_leaves": len(leaves),
                "dtypes": dtypes, "treedef": str(treedef),
                "extra": extra or {}}

    final = directory / f"step-{step:08d}.npz"
    _atomic_write(directory, final, lambda f: np.savez(f, **arrays))
    # manifest second: a crash between the two leaves an npz without a
    # manifest, which valid_steps/load treat as incomplete and skip
    blob = json.dumps(manifest, default=_json_safe).encode()
    _atomic_write(directory, directory / f"step-{step:08d}.json",
                  lambda f: f.write(blob))
    _atomic_write(directory, directory / "latest",
                  lambda f: f.write(str(step).encode()))
    _fsync_dir(directory)

    # previous checkpoints survive until here — the new one is durable now
    gc_checkpoints(directory, keep)
    return final


# Test seam: called with the step id between a checkpoint's manifest and
# npz deletions, so a kill-during-GC test can stop the process at the
# worst possible instant (see tests). Never set outside tests.
_GC_FAULT_HOOK = None


def gc_checkpoints(directory: str | Path, keep: int) -> list[int]:
    """Keep-last-``keep`` retention sweep; returns the steps deleted.

    Crash-safe by ordering, not by locking:

    * the step ``latest`` points at is never deleted, even if an odd
      ``keep`` computation would drop it — resume-by-pointer always works;
    * within one checkpoint the **manifest is deleted before the npz**: a
      kill between the two leaves an npz-only orphan, which
      :func:`valid_steps`/:func:`load_checkpoint` already treat as
      incomplete (newest-durable fallback keeps working mid-GC) and which
      the *next* sweep deletes — the glob is npz-driven, so the reverse
      order would strand manifest orphans forever;
    * deletion proceeds oldest-first, so an interrupted sweep has only
      removed the checkpoints least worth keeping.

    ``keep <= 0`` disables retention (nothing is deleted)."""
    directory = Path(directory)
    if keep <= 0:
        return []
    keep_set = set(valid_steps(directory)[-keep:])
    pinned = latest_step(directory)
    if pinned is not None:
        keep_set.add(pinned)
    deleted: list[int] = []
    for p in sorted(directory.glob("step-*.npz")):
        try:
            step = int(p.stem.split("-")[1])
        except (IndexError, ValueError):
            continue
        if step in keep_set:
            continue
        p.with_suffix(".json").unlink(missing_ok=True)
        if _GC_FAULT_HOOK is not None:
            _GC_FAULT_HOOK(step)
        p.unlink(missing_ok=True)
        deleted.append(step)
    if deleted:
        _fsync_dir(directory)
        _obs_metrics.inc("ckpt.gc_deleted", len(deleted))
    return deleted


def latest_step(directory: str | Path) -> Optional[int]:
    p = Path(directory) / "latest"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def valid_steps(directory: str | Path) -> list[int]:
    """Steps whose npz AND manifest both exist, ascending (completeness by
    presence only — decode errors are caught at load time)."""
    directory = Path(directory)
    out = []
    for p in sorted(directory.glob("step-*.npz")):
        try:
            step = int(p.stem.split("-")[1])
        except (IndexError, ValueError):
            continue
        if p.with_suffix(".json").exists():
            out.append(step)
    return out


def _load_step(directory: Path, step: int, tree_like: Any
               ) -> tuple[Any, int, dict]:
    """Decode one checkpoint. Raises CheckpointCorrupt for anything that
    smells like a torn/rotten file; a template/leaf-count mismatch is a
    caller-contract ValueError and propagates as such (falling back to an
    older checkpoint would silently resume the wrong run)."""
    npz_path = directory / f"step-{step:08d}.npz"
    man_path = directory / f"step-{step:08d}.json"
    try:
        manifest = json.loads(man_path.read_text())
        data = np.load(npz_path)
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, zipfile.BadZipFile, OSError,
            ValueError) as e:
        raise CheckpointCorrupt(f"step {step}: unreadable ({e})") from e
    leaves, treedef = _flatten(tree_like)
    if len(leaves) != manifest["num_leaves"]:
        raise ValueError(
            f"leaf count mismatch: template {len(leaves)} vs "
            f"checkpoint {manifest['num_leaves']}")
    try:
        restored = [_decode(data[f"leaf_{i}"], manifest["dtypes"][i])
                    for i in range(len(leaves))]
    except (KeyError, zipfile.BadZipFile, zlib.error, OSError,
            ValueError, EOFError) as e:
        # npz members decompress lazily — truncation surfaces here
        raise CheckpointCorrupt(f"step {step}: truncated ({e})") from e
    tree = jax.tree.unflatten(treedef, restored)
    return tree, step, manifest["extra"]


def load_checkpoint(directory: str | Path, tree_like: Any,
                    step: Optional[int] = None) -> tuple[Any, int, dict]:
    """Restore into the structure of ``tree_like`` (shape/dtype template).
    Returns (tree, step, extra).

    With ``step=None`` candidates are tried newest-first: a truncated or
    otherwise corrupt checkpoint (e.g. the process was SIGKILLed mid-write
    on a filesystem that reordered the rename) is skipped with a warning
    and the previous durable one is restored. An explicit ``step`` fails
    loudly instead — the caller asked for that exact state."""
    with _obs_span("ckpt.load", step=-1 if step is None else int(step)):
        _obs_metrics.inc("ckpt.loads")
        return _load_checkpoint(directory, tree_like, step)


def _load_checkpoint(directory, tree_like, step) -> tuple[Any, int, dict]:
    directory = Path(directory)
    if step is not None:
        return _load_step(directory, step, tree_like)
    candidates = valid_steps(directory)
    latest = latest_step(directory)
    if latest is not None and latest not in candidates:
        # a 'latest' pointing at an incomplete pair is itself a crash
        # artifact; try the files that exist
        warnings.warn(f"checkpoint 'latest'={latest} is incomplete in "
                      f"{directory}; falling back", RuntimeWarning)
    if not candidates:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    last_err: Optional[Exception] = None
    for cand in reversed(candidates):
        try:
            return _load_step(directory, cand, tree_like)
        except CheckpointCorrupt as e:
            warnings.warn(
                f"skipping corrupt checkpoint step {cand} in {directory} "
                f"({e}); falling back to the previous one", RuntimeWarning)
            last_err = e
    raise CheckpointCorrupt(
        f"every checkpoint in {directory} is corrupt") from last_err
