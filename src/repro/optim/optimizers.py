"""Optimizers, built in-repo (no optax dependency).

Functional style: ``opt = adamw(lr); state = opt.init(params);
params, state = opt.update(grads, state, params)``. All states are pytrees
mirroring the parameter tree, so GSPMD shards optimizer state exactly like
the corresponding parameter (ZeRO-1 on the tensor-parallel axis for free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)
    # Stable value identity for the engine's fused-train-step compile cache
    # (repro.core.distributed.get_compiled_train_step): two optimizers with
    # the same hyperparameters share one compiled program. None (e.g. a
    # schedule callable for lr) falls back to instance identity.
    key: Optional[tuple] = None


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        frac = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip: Optional[float] = None,
          state_dtype=jnp.float32,
          key: Optional[tuple] = None) -> Optimizer:
    """AdamW with optional global-norm clipping.

    ``state_dtype`` lets large configs keep moments in bf16 (halves optimizer
    HBM; used by the nemotron-340b dry-run config). ``key`` declares a value
    identity for a *callable* lr (schedules can't be hashed by value): pass
    e.g. ``key=("cos", base_lr, warmup, total)`` so sweeps constructing many
    schedule-based optimizers share one compiled fused train step — without
    it each instance falls back to identity keying, which pins its compiled
    program in the engine cache for the process lifetime."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=_tree_zeros_like(params, state_dtype),
                         nu=_tree_zeros_like(params, state_dtype))

    def update(grads, state, params):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        lr_t = lr_fn(step)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr_t * delta
            return newp.astype(p.dtype), m.astype(state_dtype), v.astype(state_dtype)

        # flatten/unflatten (NOT tree.map with tuple is_leaf: params trees
        # may legitimately contain structural tuples — hybrid archs do)
        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state.mu)
        leaves_v = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(leaves_p, leaves_g, leaves_m, leaves_v)]
        newp = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return newp, AdamState(step=step, mu=mu, nu=nu)

    if key is None and not callable(lr):
        key = ("adamw", float(lr), b1, b2, eps, weight_decay, grad_clip,
               jnp.dtype(state_dtype).name)
    elif key is not None:
        key = ("adamw", *key, b1, b2, eps, weight_decay, grad_clip,
               jnp.dtype(state_dtype).name)
    return Optimizer(init=init, update=update, key=key)


def adam(lr=1e-3, **kw) -> Optimizer:
    return adamw(lr=lr, weight_decay=0.0, **kw)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: object


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return SGDState(step=jnp.zeros((), jnp.int32),
                        momentum=_tree_zeros_like(params) if momentum else None)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state.momentum, grads)
            newp = jax.tree.map(lambda p, m: p - lr_t * m, params, mom)
            return newp, SGDState(step=step, momentum=mom)
        newp = jax.tree.map(lambda p, g: p - lr_t * g, params, grads)
        return newp, SGDState(step=step, momentum=None)

    key = (("sgd", float(lr), momentum) if not callable(lr) else None)
    return Optimizer(init=init, update=update, key=key)
