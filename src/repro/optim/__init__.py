from repro.optim.optimizers import (
    Optimizer, adamw, adam, sgd, clip_by_global_norm, cosine_schedule,
)

__all__ = ["Optimizer", "adamw", "adam", "sgd", "clip_by_global_norm",
           "cosine_schedule"]
