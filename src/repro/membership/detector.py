"""Peer-death detection: timeout attribution + bounded liveness probing.

Detection is two-phase, because the comm deadline alone cannot tell a
dead peer from a long straggler or a flapping link:

1. **Suspicion** — ``resilient_call`` exhausts its retries against the
   dispatch boundary and raises ``CommTimeout``; when the underlying
   transient named a peer (the engine's ``PeerDeadError.peer``), the
   timeout carries it. :func:`peer_of` extracts that attribution from an
   exception chain.
2. **Confirmation** — :class:`PeerProbe` re-probes the suspect a bounded
   number of times with a short backoff. In this single-process harness
   the probe consults the engine's dead-peer registry (a real deployment
   would open a fresh health-check channel); a peer that answers any probe
   is a false positive and the suspicion is cleared with zero numerical
   trace — the ChaosPlan's flapping ``peer_death`` exercises exactly that
   path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.obs.trace import span as _obs_span


def peer_of(exc: BaseException) -> int:
    """Best-effort peer attribution for a failure: the first ``peer >= 0``
    found walking the exception and its cause/context chain; -1 when no
    peer was named (a generic timeout — not membership's business)."""
    seen: set[int] = set()
    e: Optional[BaseException] = exc
    while e is not None and id(e) not in seen:
        seen.add(id(e))
        p = getattr(e, "peer", None)
        if isinstance(p, int) and p >= 0:
            return p
        e = e.__cause__ if e.__cause__ is not None else e.__context__
    return -1


@dataclasses.dataclass
class ProbeResult:
    alive: bool
    attempts: int
    elapsed_s: float


class PeerProbe:
    """Bounded re-probe of a suspect peer.

    ``probe_fn(shard) -> bool`` answers one liveness check; the default
    consults the engine's dead-peer registry. ``confirm`` returns
    ``alive=True`` as soon as any probe answers (flap → false positive),
    ``alive=False`` after ``attempts`` consecutive silent probes."""

    def __init__(self, probe_fn: Optional[Callable[[int], bool]] = None,
                 *, attempts: int = 3, backoff_s: float = 0.001):
        if probe_fn is None:
            from repro.core import distributed as engine
            probe_fn = lambda s: not engine.peer_is_dead(s)  # noqa: E731
        self.probe_fn = probe_fn
        self.attempts = max(1, int(attempts))
        self.backoff_s = float(backoff_s)

    def confirm(self, shard: int) -> ProbeResult:
        t0 = time.perf_counter()
        with _obs_span("membership.probe", shard=int(shard)):
            for attempt in range(1, self.attempts + 1):
                if self.probe_fn(shard):
                    return ProbeResult(True, attempt,
                                       time.perf_counter() - t0)
                if attempt < self.attempts:
                    time.sleep(self.backoff_s)
        return ProbeResult(False, self.attempts, time.perf_counter() - t0)
