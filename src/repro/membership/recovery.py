"""Deterministic world rebuild after a confirmed peer death.

Every survivor computes the new world locally from the same inputs —
``(part, dead, mode)`` — so the "recovery barrier" needs no coordination
service: :func:`rebuild_world` is a pure function, and equal inputs give
every survivor byte-identical ``part``/``owner``/``local_idx`` maps. The
Trainer then rebuilds the stateful side (FeatureStore tiers, ShapeBudget
buckets, cache, prefetcher) against the returned maps and reloads
params/opt from the shared crash-atomic checkpoint.

Recovery modes (``ResiliencePolicy.membership_mode``):

* ``"rejoin"`` — a replacement worker takes the dead rank: the partition
  is unchanged, features are restored from the authoritative source, and
  the resumed run is **bit-identical** to the fault-free one (the
  partition maps, seeds, and checkpointed params are all exactly what
  they were).
* ``"redistribute"`` / ``"adopt"`` — elastic shrink: survivors re-own the
  lost vertices (``graph.partition.reassign_partition``) and continue at
  world size P-1. Numerics legitimately change (different shard batches,
  different gradient reduction groups), so correctness is gated on
  loss-curve tolerance vs a fresh same-world-size baseline, not parity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.partition import local_index_map, reassign_partition


@dataclasses.dataclass
class WorldRebuild:
    """The new world's partition maps (pure data, no device state)."""

    part: np.ndarray          # (n,) new shard assignment, compacted ids
    owner: np.ndarray         # (n,) = part (int32)
    local_idx: np.ndarray     # (n,) row within the owning shard
    max_part_size: int        # rectangular shard height
    num_shards: int           # P - 1 for elastic modes
    dead: int                 # the shard that died (old id space)
    mode: str
    moved_rows: int           # vertices whose owner changed


def rebuild_world(part: np.ndarray, dead: int, num_shards: int, *,
                  mode: str = "redistribute",
                  adopter: int | None = None) -> WorldRebuild:
    """Compute the post-death world for an elastic mode.

    Deterministic in its arguments (no RNG, no wall clock): survivors
    agree on the result without exchanging it. ``mode="rejoin"`` is not a
    rebuild — the partition is unchanged by construction — and is
    rejected here to keep the call sites honest."""
    if mode not in ("redistribute", "adopt"):
        raise ValueError(
            f"rebuild_world handles elastic modes only, got {mode!r} "
            "(rejoin keeps the old world)")
    part = np.asarray(part)
    new_part = reassign_partition(part, dead, parts=num_shards, mode=mode,
                                  adopter=adopter)
    owner, local_idx, max_sz = local_index_map(new_part, num_shards - 1)
    # a vertex moved if its new owner differs from its compacted old owner
    old_compact = part.astype(np.int32).copy()
    old_compact[old_compact > dead] -= 1
    moved = int(np.count_nonzero((new_part != old_compact)
                                 | (part == dead)))
    return WorldRebuild(part=new_part, owner=owner, local_idx=local_idx,
                        max_part_size=int(max_sz),
                        num_shards=num_shards - 1, dead=int(dead),
                        mode=mode, moved_rows=moved)
