"""MembershipView — who is in the world, and which world is this.

LeapGNN pins each worker to its vertex features, so membership is part of
the *data plane*: losing peer p means losing shard p's feature rows, and
every plan, cache entry, and prefetch built against the old world is
garbage. The view therefore carries two things:

* per-shard liveness (``alive``/``suspect``) fed by the comm deadline —
  a peer-attributed ``CommTimeout`` marks a suspect, a bounded re-probe
  (repro.membership.detector) confirms or clears it;
* a monotonically increasing **generation**, bumped on every confirmed
  membership change (death-and-rejoin, elastic shrink). Plans are stamped
  with the generation they were built under and refused at the dispatch
  boundary when it no longer matches — the same version/stale-refusal
  discipline the CacheStore uses, applied to the world itself.

The view is process-local state about a shared fact: every survivor runs
the same deterministic recovery (``reassign_partition`` is a pure function
of ``(part, dead, mode)``), so equal inputs produce equal worlds without a
coordination service. Observability: the current generation is published
as the ``membership.generation`` gauge; suspicion/confirmation/rejoin
land on ``membership.suspects`` / ``membership.deaths`` /
``membership.rejoins`` counters.
"""
from __future__ import annotations

import threading
from typing import List

from repro.obs import metrics as _obs_metrics
from repro.obs.trace import event as _obs_event


class StaleGeneration(RuntimeError):
    """A plan (or other world-stamped artifact) from an older membership
    generation reached a dispatch boundary. Recoverable: the epoch replay
    rebuilds plans under the current generation."""

    def __init__(self, msg: str, *, have: int, want: int,
                 epoch: int = -1, it: int = -1):
        super().__init__(msg)
        self.site = "membership"
        self.have = int(have)
        self.want = int(want)
        self.epoch = epoch
        self.it = it


class MembershipView:
    """World size + per-shard liveness + epoch-stamped generation."""

    def __init__(self, num_shards: int, generation: int = 0):
        self.num_shards = int(num_shards)
        self.generation = int(generation)
        self.alive: List[bool] = [True] * self.num_shards
        self._suspect: set[int] = set()
        self.epoch = -1               # epoch of the last generation bump
        self.events: list[tuple] = []  # (what, shard, generation, epoch)
        self._lock = threading.Lock()
        _obs_metrics.set_gauge("membership.generation", self.generation)

    # -- queries -------------------------------------------------------

    def is_alive(self, shard: int) -> bool:
        return 0 <= shard < self.num_shards and self.alive[shard]

    def is_suspect(self, shard: int) -> bool:
        return shard in self._suspect

    def alive_shards(self) -> list[int]:
        return [s for s in range(self.num_shards) if self.alive[s]]

    def world_size(self) -> int:
        return sum(self.alive)

    # -- transitions ---------------------------------------------------

    def mark_suspect(self, shard: int, *, epoch: int = -1,
                     it: int = -1) -> None:
        """A comm deadline expired against ``shard``: suspicion, not yet a
        membership change (no generation bump — a cleared false positive
        must leave zero trace)."""
        with self._lock:
            if shard in self._suspect or not self.is_alive(shard):
                return
            self._suspect.add(shard)
            self.events.append(("suspect", shard, self.generation, epoch))
        _obs_metrics.inc("membership.suspects")
        _obs_event("membership.suspect", shard=shard, epoch=epoch, it=it)

    def clear_suspect(self, shard: int) -> None:
        """The probe found the peer alive (a flap): forget the suspicion."""
        with self._lock:
            self._suspect.discard(shard)

    def confirm_dead(self, shard: int, *, epoch: int = -1) -> int:
        """The probe confirmed the death: mark dead and bump the
        generation. Returns the new generation; every artifact stamped
        with an older one is now stale."""
        with self._lock:
            if not self.is_alive(shard):
                return self.generation
            self.alive[shard] = False
            self._suspect.discard(shard)
            self.generation += 1
            self.epoch = epoch
            self.events.append(("dead", shard, self.generation, epoch))
            gen = self.generation
        _obs_metrics.inc("membership.deaths")
        _obs_metrics.set_gauge("membership.generation", gen)
        _obs_event("membership.dead", shard=shard, epoch=epoch,
                   generation=gen)
        return gen

    def rejoin(self, shard: int, *, epoch: int = -1) -> int:
        """A replacement worker took the dead rank (same world size):
        mark alive again under a fresh generation."""
        with self._lock:
            if self.is_alive(shard):
                return self.generation
            self.alive[shard] = True
            self.generation += 1
            self.epoch = epoch
            self.events.append(("rejoin", shard, self.generation, epoch))
            gen = self.generation
        _obs_metrics.inc("membership.rejoins")
        _obs_metrics.set_gauge("membership.generation", gen)
        _obs_event("membership.rejoin", shard=shard, epoch=epoch,
                   generation=gen)
        return gen

    def shrink(self, dead: int, *, epoch: int = -1) -> int:
        """Elastic re-ownership: the world compacts to ``num_shards - 1``
        (shard ids above ``dead`` shift down by one, matching
        ``reassign_partition``'s compaction). Fresh generation."""
        with self._lock:
            if self.num_shards < 2:
                raise ValueError("cannot shrink a 1-shard world")
            self.num_shards -= 1
            self.alive = [True] * self.num_shards
            self._suspect = set()
            self.generation += 1
            self.epoch = epoch
            self.events.append(("shrink", dead, self.generation, epoch))
            gen = self.generation
        _obs_metrics.set_gauge("membership.generation", gen)
        _obs_metrics.set_gauge("membership.world_size", self.num_shards)
        _obs_event("membership.shrink", shard=dead, epoch=epoch,
                   generation=gen)
        return gen

    def check_generation(self, have: int, *, epoch: int = -1,
                         it: int = -1) -> None:
        """Refuse a world-stamped artifact from another generation.
        ``have < 0`` means unstamped (built before membership existed or
        outside a Trainer) and passes — only a *known-old* stamp is a
        defect worth replaying for."""
        if have < 0 or have == self.generation:
            return
        raise StaleGeneration(
            f"plan built under membership generation {have} dispatched "
            f"under generation {self.generation} (epoch {epoch}, it {it})",
            have=have, want=self.generation, epoch=epoch, it=it)
