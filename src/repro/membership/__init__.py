"""repro.membership — elastic multi-host membership for feature-centric
training.

Survive the death of a *peer shard* — the failure mode single-process
resilience (PR 7) cannot absorb, because LeapGNN pins features to workers
and a dead peer takes a slice of the data plane with it. Three layers:

* **Detection** (:mod:`.detector`): the existing ``resilient_call``
  deadline attributes a ``CommTimeout`` to a peer (``peer_of``); a bounded
  :class:`PeerProbe` separates a real death from a flap.
* **View** (:mod:`.view`): :class:`MembershipView` tracks per-shard
  liveness and an epoch-stamped **generation**; plans are stamped with the
  generation they were built under and :class:`StaleGeneration` refuses
  old-world plans at dispatch boundaries.
* **Re-ownership** (:mod:`.recovery`): :func:`rebuild_world` computes the
  survivors' new ``part``/``owner``/``local_idx`` deterministically (no
  coordination service needed); the Trainer rebuilds feature tiers /
  budgets / caches against it and resumes from the shared crash-atomic
  checkpoint. Same-world-size rejoin is bit-identical to the fault-free
  run; elastic shrink is gated on loss-curve tolerance.
"""
from repro.membership.detector import PeerProbe, ProbeResult, peer_of
from repro.membership.recovery import WorldRebuild, rebuild_world
from repro.membership.view import MembershipView, StaleGeneration

__all__ = [
    "MembershipView", "StaleGeneration",
    "PeerProbe", "ProbeResult", "peer_of",
    "WorldRebuild", "rebuild_world",
]
