"""Core graph containers (host-side, numpy).

A ``CSRGraph`` stores out-neighborhoods in compressed-sparse-row form. GNN
sampling treats the graph as undirected unless stated otherwise; generators
in :mod:`repro.graph.synthetic` symmetrize before building CSR.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Compressed sparse row adjacency.

    Attributes:
      indptr:  (n+1,) int64 — row pointer.
      indices: (nnz,) int32 — column (neighbor) ids.
    """

    indptr: np.ndarray
    indices: np.ndarray

    @property
    def num_vertices(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   symmetrize: bool = True) -> "CSRGraph":
        """Build CSR from an edge list, deduplicating and (optionally)
        symmetrizing. Self loops are dropped."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        keep = src != dst
        src, dst = src[keep], dst[keep]
        # Dedup (src,dst) pairs.
        key = src * n + dst
        key = np.unique(key)
        src, dst = key // n, key % n
        order = np.argsort(src, kind="stable")
        src, dst = src[order], dst[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return CSRGraph(indptr=indptr, indices=dst.astype(np.int32))

    def topology_bytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes


@dataclasses.dataclass
class GraphDataset:
    """A graph + vertex features + labels + train split.

    Mirrors the paper's Table 2 inputs: topology volume ``Vol_G`` vs feature
    volume ``Vol_F`` (features dominate, which is what makes feature-centric
    training pay off).
    """

    name: str
    graph: CSRGraph
    features: np.ndarray        # (n, dim) float32
    labels: np.ndarray          # (n,) int32
    train_mask: np.ndarray      # (n,) bool
    num_classes: int
    communities: Optional[np.ndarray] = None  # ground-truth blocks if synthetic

    @property
    def num_vertices(self) -> int:
        return self.graph.num_vertices

    @property
    def feature_dim(self) -> int:
        return int(self.features.shape[1])

    def vol_g_bytes(self) -> int:
        return self.graph.topology_bytes()

    def vol_f_bytes(self) -> int:
        return int(self.features.nbytes)

    def train_vertices(self) -> np.ndarray:
        return np.nonzero(self.train_mask)[0].astype(np.int64)
