"""Graph partitioners.

Three partitioners, matching the paper's evaluation matrix:

* ``hash_partition`` — P³'s random hash partitioning (no locality; the
  baseline LeapGNN is *not* designed for, §8 "Generality").
* ``ldg_partition`` — Linear Deterministic Greedy streaming partitioner
  [Stanton & Kliot, KDD'12]: our METIS stand-in. METIS itself is not
  available offline; LDG is the standard streaming approximation that, on
  community-structured graphs, recovers the same edge-cut locality that
  Table 1 attributes to METIS. Multiple passes refine the cut.
* ``range_partition`` — contiguous ranges, the "heuristic" used by BGL for
  graphs too large for METIS (the paper uses it for UK/IT).

All return an (n,) int32 part id array with parts of near-equal size
(capacity-constrained), which is what keeps the redistribution step of
LeapGNN load-balanced (§5.1 step 1).
"""
from __future__ import annotations

import numpy as np

from repro.graph.structs import CSRGraph


def hash_partition(n: int, parts: int, seed: int = 0) -> np.ndarray:
    """Random hash partition (P³-style)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, parts, size=n).astype(np.int32)


def range_partition(n: int, parts: int) -> np.ndarray:
    """Contiguous range partition (BGL-style heuristic for huge graphs)."""
    return ((np.arange(n, dtype=np.int64) * parts) // n).astype(np.int32)


def ldg_partition(g: CSRGraph, parts: int, passes: int = 2,
                  slack: float = 1.05, seed: int = 0) -> np.ndarray:
    """Linear Deterministic Greedy partitioning with refinement passes.

    Pass 1 streams vertices in a random order, assigning each to
    ``argmax_p |N(v) ∩ p| * (1 - size_p / capacity)``. Later passes re-stream
    and allow moves, which tightens the cut (METIS-like quality on
    community graphs).
    """
    n = g.num_vertices
    cap = slack * n / parts
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    part = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(parts, dtype=np.int64)

    indptr, indices = g.indptr, g.indices
    for pass_i in range(passes):
        for v in order:
            nbr = indices[indptr[v]:indptr[v + 1]]
            np_part = part[nbr]
            np_part = np_part[np_part >= 0]
            if np_part.size:
                counts = np.bincount(np_part, minlength=parts).astype(np.float64)
            else:
                counts = np.zeros(parts, dtype=np.float64)
            # balance penalty; +tiny noise to break ties randomly
            score = counts * np.maximum(0.0, 1.0 - sizes / cap)
            if np.all(score <= 0):
                p = int(np.argmin(sizes))
            else:
                p = int(np.argmax(score))
            old = part[v]
            if old == p:
                continue
            if sizes[p] >= cap and old >= 0:
                continue  # keep current assignment if target full
            if old >= 0:
                sizes[old] -= 1
            part[v] = p
            sizes[p] += 1
    return part


def community_partition(communities: np.ndarray, parts: int) -> np.ndarray:
    """Ground-truth-community partition for synthetic graphs — the METIS
    stand-in. On community-structured graphs METIS recovers the communities
    (that is its objective); our synthetic generators expose them directly,
    so assigning whole communities round-robin to parts reproduces METIS's
    locality (Table 1: 88–95 % on Products) without shipping METIS.
    Balanced because synthetic communities are equal-sized."""
    return (communities % parts).astype(np.int32)


def drop_cross_edges(g: CSRGraph, part: np.ndarray) -> CSRGraph:
    """Remove every edge crossing partitions (the locality-optimized
    baseline's sampling graph, §7.9: LO never touches remote features, at
    the cost of biasing neighborhoods toward the local partition)."""
    src = np.repeat(np.arange(g.num_vertices, dtype=np.int64), g.degrees())
    keep = part[src] == part[g.indices]
    src, dst = src[keep], g.indices[keep].astype(np.int64)
    indptr = np.zeros(g.num_vertices + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRGraph(indptr=indptr, indices=dst.astype(np.int32))


def edge_cut(g: CSRGraph, part: np.ndarray) -> float:
    """Fraction of edges crossing partitions (quality metric)."""
    src = np.repeat(np.arange(g.num_vertices), g.degrees())
    cross = part[src] != part[g.indices]
    return float(cross.mean()) if cross.size else 0.0


def partition_sizes(part: np.ndarray, parts: int) -> np.ndarray:
    return np.bincount(part, minlength=parts)


def reassign_partition(part: np.ndarray, dead: int, *,
                       parts: int | None = None,
                       mode: str = "redistribute",
                       adopter: int | None = None) -> np.ndarray:
    """Re-own a dead shard's vertices among the survivors (repro.membership).

    Returns a new ``(n,)`` int32 part array over ``parts - 1`` shards,
    with shard ids **compacted**: a survivor ``p`` keeps its id if
    ``p < dead`` and becomes ``p - 1`` otherwise, so the result is a dense
    ``[0, parts-1)`` labeling (what ``local_index_map``/``shard_features``
    require). The rebuild is a pure function of ``(part, dead, mode,
    adopter)`` — every survivor computes the same new world without
    coordination, which is what makes the recovery barrier deterministic.

    * ``mode="redistribute"`` — the lost vertices are dealt round-robin
      (in global-id order) across all survivors, preserving balance.
    * ``mode="adopt"`` — one survivor takes the whole shard: ``adopter``
      if given, else the smallest survivor (ties to the lowest id).
      Simpler bookkeeping (other survivors' locals are untouched), at the
      cost of imbalance.
    """
    part = np.asarray(part)
    P = int(parts) if parts is not None else int(part.max()) + 1
    if P < 2:
        raise ValueError("cannot reassign with fewer than 2 shards")
    if not 0 <= dead < P:
        raise ValueError(f"dead shard {dead} out of range [0, {P})")
    survivors = [p for p in range(P) if p != dead]
    lost = np.nonzero(part == dead)[0]
    new_part = part.astype(np.int32).copy()
    if mode == "redistribute":
        targets = np.asarray(survivors, dtype=np.int32)
        new_part[lost] = targets[np.arange(lost.size) % targets.size]
    elif mode == "adopt":
        if adopter is None:
            sizes = partition_sizes(part, P)
            adopter = min(survivors, key=lambda p: (sizes[p], p))
        if adopter == dead or not 0 <= adopter < P:
            raise ValueError(f"adopter {adopter} is not a survivor")
        new_part[lost] = adopter
    else:
        raise ValueError(f"unknown reassign mode {mode!r}")
    # compact: close the dead shard's id gap so the world is dense again
    new_part[new_part > dead] -= 1
    return new_part


def local_index_map(part: np.ndarray, parts: int) -> tuple[np.ndarray, np.ndarray, int]:
    """Global-id -> (owner, local index) maps for a partitioned feature store.

    Returns ``(owner, local_idx, max_part_size)`` where
    ``features_sharded[owner[v], local_idx[v]] == features[v]``. Every shard
    is padded to ``max_part_size`` rows so the sharded table is rectangular
    (a requirement for SPMD layouts on TPU).
    """
    owner = part.astype(np.int32)
    local_idx = np.zeros_like(owner)
    max_sz = 0
    for p in range(parts):
        ids = np.nonzero(owner == p)[0]
        local_idx[ids] = np.arange(ids.size, dtype=np.int32)
        max_sz = max(max_sz, ids.size)
    return owner, local_idx, int(max_sz)


def shard_features(features: np.ndarray, part: np.ndarray, parts: int
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Materialize the rectangular sharded feature table.

    Returns ``(table, owner, local_idx)`` with ``table`` of shape
    (parts, max_part_size, dim); padding rows are zero.
    """
    owner, local_idx, max_sz = local_index_map(part, parts)
    dim = features.shape[1]
    table = np.zeros((parts, max_sz, dim), dtype=features.dtype)
    table[owner, local_idx] = features
    return table, owner, local_idx
