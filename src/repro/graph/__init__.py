"""Graph substrate: CSR storage, synthetic datasets, partitioning, sampling.

Everything in this package is *host-side* (numpy): in DGL — and in LeapGNN,
which builds on it — graph sampling and partition bookkeeping run on CPU,
feeding fixed-shape tensors to the accelerator. We keep that split: this
package never imports jax.
"""
from repro.graph.structs import CSRGraph, GraphDataset
from repro.graph.synthetic import make_dataset, DATASETS
from repro.graph.partition import hash_partition, ldg_partition, range_partition
from repro.graph.sampler import sample_tree_block, layerwise_sample, TreeBlock

__all__ = [
    "CSRGraph", "GraphDataset", "make_dataset", "DATASETS",
    "hash_partition", "ldg_partition", "range_partition",
    "sample_tree_block", "layerwise_sample", "TreeBlock",
]
