"""Synthetic graph datasets with community structure + power-law degrees.

The paper evaluates on Arxiv/Products (OGB) and UK/IN/IT (WebGraph). Those
are not redistributable inside this container, so we generate *structurally
analogous* graphs: power-law degree distribution, strong community locality
(which is what METIS exploits, and what micrograph locality relies on), and
the paper's feature dimensions. The UK/IN/IT datasets had random features in
the paper too (§7.1), so synthetic features are faithful there by
construction.

Scales are reduced (``scale`` multiplier) to fit a 1-core CPU container; the
*ratios* the paper measures (locality percentages, bytes per strategy,
α ratios) are scale-stable, which is what our benchmarks report.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.graph.structs import CSRGraph, GraphDataset

# name -> (n_vertices, avg_degree, feat_dim, n_classes) at scale=1.0,
# mirroring Table 2's relative shapes.
DATASETS = {
    # paper:       #V      #E       dim
    "arxiv":    (169_000, 7.0, 128, 40),
    "products": (245_000, 25.0, 100, 47),   # products scaled 1/10
    "uk":       (100_000, 41.0, 600, 10),   # uk-2005 scaled 1/10
    "in":       (138_000, 12.0, 600, 10),   # in-2004 scaled 1/10
    "it":       (413_000, 28.0, 600, 10),   # it-2004 scaled 1/100
}


def _powerlaw_degrees(n: int, avg_deg: float, rng: np.random.Generator,
                      alpha: float = 2.1, d_min: int = 1) -> np.ndarray:
    """Draw a power-law degree sequence with the requested mean."""
    u = rng.random(n)
    # Pareto with exponent alpha, then rescale to hit the target mean.
    raw = d_min * (1.0 - u) ** (-1.0 / (alpha - 1.0))
    raw = np.minimum(raw, n / 4)  # clip hubs
    deg = np.maximum(1, np.round(raw * (avg_deg / raw.mean()))).astype(np.int64)
    return deg


def community_graph(n: int, avg_deg: float, n_communities: int,
                    p_intra: float, seed: int) -> tuple[CSRGraph, np.ndarray]:
    """Power-law graph with contiguous communities.

    Each vertex draws its degree from a power law; each edge endpoint is
    chosen within the community with probability ``p_intra`` (uniformly),
    otherwise globally. This yields the locality structure that METIS-like
    partitioners recover and that Table 1 measures.
    """
    rng = np.random.default_rng(seed)
    comm = (np.arange(n) * n_communities) // n  # contiguous blocks
    comm_start = np.searchsorted(comm, np.arange(n_communities))
    comm_size = np.bincount(comm, minlength=n_communities)

    deg = _powerlaw_degrees(n, avg_deg / 2.0, rng)  # half: symmetrization doubles
    m = int(deg.sum())
    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    intra = rng.random(m) < p_intra
    # Intra-community endpoint: uniform within the source's community.
    c = comm[src]
    dst_intra = comm_start[c] + (rng.random(m) * comm_size[c]).astype(np.int64)
    # Inter-community endpoint: global, degree-biased via repeated src pool.
    dst_inter = src[rng.integers(0, m, size=m)]
    dst = np.where(intra, dst_intra, dst_inter)
    g = CSRGraph.from_edges(n, src, dst, symmetrize=True)
    return g, comm


def make_dataset(name: str, scale: float = 1.0, seed: int = 0,
                 feat_dim: int | None = None,
                 train_frac: float = 0.1,
                 spill_dir: str | None = None,
                 feature_budget_bytes: int = 0,
                 spill_chunk_rows: int = 1 << 16) -> GraphDataset:
    """Build a named synthetic dataset (see ``DATASETS``).

    Spill-to-disk (repro.features): with ``spill_dir`` set, features whose
    total bytes exceed ``feature_budget_bytes`` (0 = always spill when a
    dir is given) are *generated chunked* straight into an on-disk ``.npy``
    memmap instead of host RAM — peak host memory is one
    ``spill_chunk_rows`` chunk, so graphs larger than the host budget
    generate fine. The Generator draws values sequentially from its
    bit-stream, so chunked draws are bitwise identical to the one-shot
    in-RAM array (asserted in tests) — spilling never changes the dataset.
    """
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    n0, avg_deg, dim0, n_classes = DATASETS[name]
    n = max(1024, int(n0 * scale))
    dim = feat_dim if feat_dim is not None else dim0
    n_comm = max(8, n // 2048)
    g, comm = community_graph(n, avg_deg, n_comm, p_intra=0.85, seed=seed)

    rng = np.random.default_rng(seed + 1)
    labels = (comm % n_classes).astype(np.int32)
    spill = (spill_dir is not None
             and (feature_budget_bytes <= 0
                  or n * dim * 4 > feature_budget_bytes))
    if spill:
        from numpy.lib.format import open_memmap
        path = Path(spill_dir)
        path.mkdir(parents=True, exist_ok=True)
        fpath = path / f"{name}_features.npy"
        mm = open_memmap(fpath, mode="w+", dtype=np.float32, shape=(n, dim))
        for a in range(0, n, spill_chunk_rows):
            b = min(a + spill_chunk_rows, n)
            mm[a:b] = rng.standard_normal((b - a, dim), dtype=np.float32)
        centers = rng.standard_normal((n_classes, dim), dtype=np.float32)
        # Make labels weakly predictable from community + neighborhood so
        # that accuracy-parity experiments (Table 3) have signal to learn.
        for a in range(0, n, spill_chunk_rows):
            b = min(a + spill_chunk_rows, n)
            mm[a:b] += 0.5 * centers[labels[a:b]]
        mm.flush()
        del mm
        feats = np.load(fpath, mmap_mode="r")
    else:
        feats = rng.standard_normal((n, dim), dtype=np.float32)
        centers = rng.standard_normal((n_classes, dim), dtype=np.float32)
        feats += 0.5 * centers[labels]
    train_mask = rng.random(n) < train_frac
    return GraphDataset(name=name, graph=g, features=feats, labels=labels,
                        train_mask=train_mask, num_classes=n_classes,
                        communities=comm)
