"""K-hop neighbor sampling (host-side, numpy).

TPU adaptation (see DESIGN.md §2): sampled neighborhoods are *fixed-fanout
trees*, giving rectangular (B, f, f², …) frontier arrays. On GPUs, DGL
builds ragged message-flow graphs; ragged layouts are hostile to the TPU's
static-shape compiler, so we sample **with replacement** to a fixed fanout
(the standard TPU-native formulation; when deg(v) >= fanout this draws
`fanout` distinct-in-expectation neighbors, and when deg(v) < fanout the
duplicates implement mean-aggregation weighting). Vertices with degree 0
self-loop.

A ``TreeBlock`` is the fundamental sampled unit. A *subgraph* (paper §2) is
a TreeBlock with B = mini-batch-size roots; a *micrograph* (paper §4) is a
TreeBlock with roots drawn from a single (home-server, model) group.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.structs import CSRGraph


@dataclasses.dataclass
class TreeBlock:
    """Fixed-fanout k-hop sample.

    hops[0] = roots (B,), hops[h] = (B * f^h,) global vertex ids; the
    children of ``hops[h][i]`` are ``hops[h+1][i*f:(i+1)*f]``.
    """

    hops: list[np.ndarray]
    fanout: int

    @property
    def batch_size(self) -> int:
        return int(self.hops[0].shape[0])

    @property
    def num_layers(self) -> int:
        return len(self.hops) - 1

    @property
    def roots(self) -> np.ndarray:
        return self.hops[0]

    def all_ids(self) -> np.ndarray:
        """Every sampled vertex id, with duplicates (tree multiset)."""
        return np.concatenate(self.hops)

    def unique_ids(self) -> np.ndarray:
        return np.unique(self.all_ids())

    def num_feature_rows(self) -> int:
        """Feature rows gathered for this block (tree layout, with dups)."""
        return int(sum(h.shape[0] for h in self.hops))

    def locality(self, part: np.ndarray) -> float:
        """R_micro / R_sub of Table 1: fraction of non-root sampled vertices
        co-located (same partition) with this block's (first) root."""
        home = part[self.hops[0][0]]
        non_root = np.concatenate(self.hops[1:]) if len(self.hops) > 1 else np.array([], np.int64)
        if non_root.size == 0:
            return 1.0
        return float((part[non_root] == home).mean())

    def select(self, idx: np.ndarray) -> "TreeBlock":
        """Sub-block for a subset of roots (used by micrograph grouping)."""
        f = self.fanout
        hops = []
        pos = np.asarray(idx, dtype=np.int64)  # positions within hop h
        for ids in self.hops:
            hops.append(ids[pos])
            pos = (pos[:, None] * f + np.arange(f)[None, :]).reshape(-1)
        return TreeBlock(hops=hops, fanout=f)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer — a counter-based hash usable as a stateless RNG."""
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15))
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


def _sample_neighbors(g: CSRGraph, frontier: np.ndarray, fanout: int,
                      rng: np.random.Generator | None,
                      seed: int | None = None, hop: int = 0) -> np.ndarray:
    """(m,) frontier -> (m*fanout,) sampled neighbors, with replacement.

    Two modes:
      * ``rng`` — stateful draw (fresh neighborhoods every call).
      * ``seed`` — *stateless* hash of (vertex, slot, hop, seed): the sampled
        tree below a root is a pure function of (root, seed), independent of
        which strategy/step groups the root. This is what makes LeapGNN's
        accuracy-fidelity claim (§5.1, Table 3) a *bitwise-testable*
        gradient-parity property instead of a statistical one.
    """
    deg = g.indptr[frontier + 1] - g.indptr[frontier]
    start = g.indptr[frontier]
    if seed is not None:
        with np.errstate(over="ignore"):
            key = (frontier.astype(np.uint64)[:, None]
                   * np.uint64(0x100000001B3)
                   + np.arange(fanout, dtype=np.uint64)[None, :]
                   + np.uint64(hop) * np.uint64(0x9E3779B9)
                   + np.uint64(seed) * np.uint64(0xDEADBEEF63))
        h = _splitmix64(key)
        offs = (h % np.maximum(deg, 1).astype(np.uint64)[:, None]).astype(np.int64)
    else:
        offs = (rng.random((frontier.shape[0], fanout)) *
                np.maximum(deg, 1)[:, None]).astype(np.int64)
    flat = (start[:, None] + offs).reshape(-1)
    nbrs = g.indices[np.minimum(flat, g.num_edges - 1)].astype(np.int64)
    # degree-0 vertices self-loop
    self_loop = np.repeat(deg == 0, fanout)
    nbrs = np.where(self_loop, np.repeat(frontier, fanout), nbrs)
    return nbrs


def sample_tree_block(g: CSRGraph, roots: np.ndarray, num_layers: int,
                      fanout: int, rng: np.random.Generator | None = None,
                      seed: int | None = None) -> TreeBlock:
    """Node-wise k-hop sampling (GraphSAGE-style) into a TreeBlock.

    Pass ``seed`` for stateless per-root-deterministic sampling (gradient
    parity across strategies), or ``rng`` for stateful sampling."""
    if (rng is None) == (seed is None):
        raise ValueError("pass exactly one of rng / seed")
    hops = [np.asarray(roots, dtype=np.int64)]
    for h in range(num_layers):
        hops.append(_sample_neighbors(g, hops[-1], fanout, rng,
                                      seed=seed, hop=h))
    return TreeBlock(hops=hops, fanout=fanout)


def layerwise_sample(g: CSRGraph, roots: np.ndarray, num_layers: int,
                     layer_size: int, rng: np.random.Generator
                     ) -> list[np.ndarray]:
    """Layer-wise (FastGCN-style) sampling: each layer draws a fixed-size,
    degree-biased vertex set shared by the whole batch. Used by the Table-1
    locality benchmark (the paper evaluates both sampling families)."""
    layers = [np.asarray(roots, dtype=np.int64)]
    deg = g.degrees().astype(np.float64)
    for _ in range(num_layers):
        # candidates: union of neighbors of the previous layer
        prev = layers[-1]
        cand = np.concatenate([g.neighbors(int(v)) for v in prev]) if prev.size else prev
        if cand.size == 0:
            layers.append(prev.copy())
            continue
        cand = np.unique(cand)
        p = deg[cand] + 1.0
        p /= p.sum()
        take = min(layer_size, cand.size)
        layers.append(rng.choice(cand, size=take, replace=False, p=p).astype(np.int64))
    return layers


def micrograph_split(block: TreeBlock) -> list[TreeBlock]:
    """Split a subgraph TreeBlock into per-root micrographs (paper §4)."""
    return [block.select(np.array([i])) for i in range(block.batch_size)]


def group_roots_by_home(roots: np.ndarray, part: np.ndarray, parts: int
                        ) -> list[np.ndarray]:
    """Step 1 of §5.1: group mini-batch roots by home server."""
    home = part[roots]
    return [roots[home == s] for s in range(parts)]
