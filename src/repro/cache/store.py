"""Device-resident remote-feature cache table (repro.cache tentpole, part b).

A :class:`CacheStore` holds, per shard, a padded table of *remote* feature
rows chosen by an admission policy (repro.cache.policy): shard s's slice
``table[s]`` is ``(c_max, d)`` with the cached rows packed in id-sorted
order and zero padding above. The host-side :class:`CacheIndex` is the
SlotMap-style lookup structure the planner consults: per-shard sorted
global-id arrays with aligned slot arrays, so a hit test is one
``searchsorted`` per shard.

Shapes are quantized: ``c_max`` is a power-of-two bucket (repro.train's
``next_bucket``), grown only when an installed selection outgrows it —
cache-*content* refreshes between epochs never change device shapes, so the
jitted iteration (whose workspace is ``[local | cached | fetched]``) never
retraces across refreshes. ``version`` increments on every install; plans
record the version they were built against and the Trainer refuses to
execute a stale plan (features are static during training, so cached rows
are always *exact* copies — versioning guards index/table agreement, not
value staleness).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import metrics as _obs_metrics
from repro.obs.trace import span as _obs_span


def _next_pow2(n: int, minimum: int = 1) -> int:
    """Delegates to the budget module's canonical pow2 bucketing so the
    store's c_max quantization can never drift from ShapeBudget.grow (the
    'refreshes never retrace' invariant couples them). Lazy import: the
    repro.train package pulls jax, which this host-side module doesn't need
    at import time."""
    from repro.train.budget import next_bucket
    return next_bucket(n, minimum)


@dataclasses.dataclass
class CacheIndex:
    """Host-side cached-set lookup: which remote ids shard s holds, and in
    which cache-table row. ``ids[s]`` is sorted ascending; ``slots[s]`` is
    aligned and points into ``[0, c_max)``."""

    ids: list[np.ndarray]      # per shard, sorted unique global vertex ids
    slots: list[np.ndarray]    # per shard, aligned cache-table row
    c_max: int                 # padded table height (power of two, or 0)
    version: int = 0

    @property
    def num_shards(self) -> int:
        return len(self.ids)

    def num_cached(self, shard: int) -> int:
        return int(self.ids[shard].size)

    def hit_split(self, shard: int, query: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray]:
        """(hit_mask, hit_slots) for sorted-or-not ``query`` ids on ``shard``.
        ``hit_slots`` is aligned with ``query`` and valid where the mask is
        True (0 elsewhere). Fully vectorized — one searchsorted."""
        query = np.asarray(query, np.int64)
        cids = self.ids[shard]
        hit = np.zeros(query.size, bool)
        slot = np.zeros(query.size, np.int64)
        if cids.size and query.size:
            pos = np.searchsorted(cids, query)
            ok = (pos < cids.size) & \
                (cids[np.minimum(pos, cids.size - 1)] == query)
            hit = ok
            slot[ok] = self.slots[shard][pos[ok]]
        return hit, slot

    @staticmethod
    def empty(num_shards: int) -> "CacheIndex":
        z = [np.zeros(0, np.int64) for _ in range(num_shards)]
        return CacheIndex(ids=z, slots=[a.copy() for a in z], c_max=0,
                          version=0)


class CacheStore:
    """Padded per-shard cache table + its index, versioned.

    The device array is uploaded lazily (``device_table``) and re-uploaded
    only after :meth:`install` — between refreshes the table stays resident,
    exactly like the Trainer's feature table. ``c_max`` may be pre-sized
    (``CacheStore(..., c_max=next_bucket(budget_rows))``) so even a cold
    (empty) cache already has its final device shape — the compile-once
    pattern the Trainer uses.
    """

    def __init__(self, num_shards: int, feature_dim: int, c_max: int = 0,
                 dtype=np.float32):
        self.num_shards = int(num_shards)
        self.feature_dim = int(feature_dim)
        self.dtype = np.dtype(dtype)
        self.c_max = _next_pow2(c_max) if c_max else 0
        self.version = 0
        self.index = CacheIndex.empty(self.num_shards)
        self.index.c_max = self.c_max
        self._host = np.zeros((self.num_shards, self.c_max, self.feature_dim),
                              self.dtype)
        self._device = None          # uploaded lazily, invalidated on install
        self.installs = 0
        self.repads = 0              # c_max re-buckets (shape changes)

    # ------------------------------------------------------------------

    @property
    def device_table(self):
        """(N, c_max, d) jnp array, cached across calls until an install."""
        if self._device is None:
            import jax.numpy as jnp
            with _obs_span("cache.upload", bytes=int(self._host.nbytes)):
                self._device = jnp.asarray(self._host)
            _obs_metrics.inc("cache.upload_bytes", int(self._host.nbytes))
        return self._device

    def nbytes(self) -> int:
        return int(self._host.nbytes)

    def rows_installed(self) -> int:
        return int(sum(i.size for i in self.index.ids))

    # ------------------------------------------------------------------

    def install(self, ids_per_shard: list[np.ndarray],
                rows_per_shard: list[np.ndarray]) -> dict:
        """Replace the cached set: ``rows_per_shard[s][k]`` is the feature
        row of ``ids_per_shard[s][k]`` (any order; sorted here). Grows
        ``c_max`` to the next power-of-two bucket only when the selection
        outgrows the current one (counted in ``repads`` — each re-pad is a
        device-shape change and therefore one new jit trace downstream).
        Returns install stats for the Trainer's epoch accounting."""
        assert len(ids_per_shard) == self.num_shards
        k_max = max((np.asarray(i).size for i in ids_per_shard), default=0)
        if k_max > self.c_max:
            self.c_max = _next_pow2(k_max, self.c_max + 1)
            self.repads += 1
        host = np.zeros((self.num_shards, self.c_max, self.feature_dim),
                        self.dtype)
        ids_s, slots_s = [], []
        rows_total = 0
        for s in range(self.num_shards):
            ids = np.asarray(ids_per_shard[s], np.int64)
            rows = np.asarray(rows_per_shard[s], self.dtype)
            assert rows.shape[0] == ids.size, (rows.shape, ids.size)
            order = np.argsort(ids)
            ids = ids[order]
            if ids.size and np.any(np.diff(ids) == 0):
                raise ValueError(f"duplicate cached ids on shard {s}")
            host[s, :ids.size] = rows[order]
            ids_s.append(ids)
            slots_s.append(np.arange(ids.size, dtype=np.int64))
            rows_total += int(ids.size)
        self.version += 1
        self.installs += 1
        self.index = CacheIndex(ids=ids_s, slots=slots_s, c_max=self.c_max,
                                version=self.version)
        self._host = host
        self._device = None
        _obs_metrics.inc("cache.installs")
        _obs_metrics.inc("cache.rows_installed", rows_total)
        if self.repads:
            _obs_metrics.registry().gauge("cache.repads").set(self.repads)
        return {"rows": rows_total, "bytes": rows_total * self.feature_dim
                * self.dtype.itemsize, "c_max": self.c_max,
                "version": self.version}

    def install_from(self, feature_store,
                     ids_per_shard: list[np.ndarray]) -> dict:
        """Refresh the cached set straight from a
        :class:`repro.features.FeatureStore`: the selected rows are
        resolved through the store's tier chain (host hot tier → mmap
        disk) instead of a caller-held dense host copy — the tier-0
        refresh path of the feature hierarchy. The store must have bound
        owner/local_idx maps (``take_global``)."""
        with _obs_span("cache.install",
                       rows=int(sum(np.asarray(i).size
                                    for i in ids_per_shard))):
            rows = [feature_store.take_global(ids)
                    for ids in ids_per_shard]
            return self.install(ids_per_shard, rows)
