"""repro.cache — degree-aware remote-feature cache with deterministic
epoch prefetch.

LeapGNN's pre-gathering (§5.2) dedups remote fetches *within* one
iteration; this subsystem removes the recurring cross-iteration traffic on
top of it (RapidGNN, PAPERS.md): an admission policy (:mod:`policy`)
chooses per-shard cached remote-vertex sets under a byte budget, a
device-resident padded store (:mod:`store`) keeps those rows next to the
local feature shard, and a deterministic epoch prefetcher (:mod:`prefetch`)
computes next-epoch hot sets ahead of time so refreshes happen off the
critical path.

The planner splits every needed remote id into cache *hits* (read from the
resident table — the workspace becomes ``[local | cached | fetched]``) and
*misses* (shipped through the ordinary all_to_all exchange); features are
static during training, so cached rows are exact copies and cache-enabled
gradients are bit-identical to cache-off (tests/test_cache.py).
"""
from repro.cache.policy import (DegreePolicy, LFUPolicy, budget_rows,
                                make_policy)
from repro.cache.prefetch import EpochPrefetcher
from repro.cache.store import CacheIndex, CacheStore

__all__ = [
    "CacheIndex", "CacheStore", "DegreePolicy", "LFUPolicy",
    "EpochPrefetcher", "budget_rows", "make_policy",
]
