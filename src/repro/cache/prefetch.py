"""Deterministic epoch-level cache prefetch (repro.cache tentpole, part c).

The repo's sampler is stateless (``_splitmix64`` over (vertex, slot, hop,
seed) — repro.graph.sampler): the tree below a root is a pure function of
(root, seed), and the Trainer derives both its roots and its sample seeds
from (epoch, iteration). So *next* epoch's remote-feature requests are
computable **now**, before the epoch runs — RapidGNN's central observation.
:class:`EpochPrefetcher` replays the sampling pipeline for a future epoch on
the host (no device work, runs on the Trainer's cache thread while the
current epoch executes) and returns per-shard request-frequency tables the
admission policy turns into the next cached set.

Prediction fidelity: a §5.3 merge moves some merged roots to the hosting
server of their target step, so replaying the *unmerged* rotation would
mispredict the requesting shard for those roots — the cache then simply
misses them (misses are fetched through the ordinary exchange; correctness
is never at stake). The ``fold_steps`` hook closes that gap: the Trainer
wires it to fold each predicted assignment to the merging controller's
current pattern exactly like build_plan does, so the forecast is exact
with merging off *and* under a frozen merge with the paper's deterministic
"min" selector (the RD baseline's random folds consume controller RNG
state and cannot be replayed ahead of time — those predictions stay
unfolded). With an exact forecast a covering budget yields a 100% hit
rate.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.micrograph import (hopgnn_assignment, lo_assignment,
                                   model_centric_assignment)
from repro.graph.sampler import sample_tree_block


class EpochPrefetcher:
    """Replays sampling for a future epoch → per-shard hot-set frequencies.

    ``roots_for(epoch, it)`` must be the Trainer's own deterministic root
    draw; ``sample_seed_for(epoch, it)`` its seed schedule. Both are plain
    callables so the prefetcher stays decoupled from the Trainer object
    (benchmarks drive it standalone).
    """

    def __init__(self, *, graph, part: np.ndarray, owner: np.ndarray,
                 num_shards: int, num_layers: int, fanout: int,
                 roots_for: Callable[[int, int], Sequence[np.ndarray]],
                 sample_seed_for: Callable[[int, int], int],
                 strategy: str = "hopgnn",
                 fold_steps: Optional[Callable] = None):
        self.graph = graph
        self.part = np.asarray(part)
        self.owner = np.asarray(owner)
        self.num_shards = int(num_shards)
        self.num_layers = int(num_layers)
        self.fanout = int(fanout)
        self.roots_for = roots_for
        self.sample_seed_for = sample_seed_for
        self.strategy = strategy
        self.fold_steps = fold_steps   # optional merge-pattern application

    def _assignment(self, roots):
        roots = [np.asarray(r, np.int64) for r in roots]
        if self.strategy == "model_centric":
            amat = model_centric_assignment(roots)
        elif self.strategy == "lo":
            amat = lo_assignment(roots, self.part)
        else:
            amat = hopgnn_assignment(roots, self.part)
        if self.fold_steps is not None:
            amat = self.fold_steps(amat)
        return amat

    def iteration_sets(self, epoch: int, it: int) -> list[np.ndarray]:
        """Per-requesting-shard deduped ids (local AND remote) one future
        iteration touches — the common core both forecasts share."""
        roots = self.roots_for(epoch, it)
        amat = self._assignment(roots)
        seed = self.sample_seed_for(epoch, it)
        n = amat.num_shards
        per_shard: list[list[np.ndarray]] = [[] for _ in range(n)]
        for s in range(n):
            for t in range(amat.num_steps):
                r = amat.roots_at(s, t)
                if r.size == 0:
                    continue
                blk = sample_tree_block(self.graph, r, self.num_layers,
                                        self.fanout, seed=seed)
                per_shard[s].append(blk.all_ids())
        return [np.unique(np.concatenate(ps)) if ps
                else np.zeros(0, np.int64) for ps in per_shard]

    def iteration_requests(self, epoch: int, it: int
                           ) -> list[np.ndarray]:
        """Per-shard deduped remote ids one future iteration will request —
        exactly the sets ``build_gather_plan`` would dedup to (§5.2)."""
        sets = self.iteration_sets(epoch, it)
        return [ids[self.owner[ids] != s] for s, ids in enumerate(sets)]

    def epoch_touched(self, epoch: int, iters: int
                      ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Per-OWNING-shard (ids, read_counts) over a whole future epoch:
        every id whose feature row shard p will have to *serve* next epoch
        — to its own plans' local region or to a peer's fetch — with the
        number of iteration-level reads as the count. This is the exact
        tier-2 → tier-1 readahead forecast for the tiered FeatureStore
        (repro.features): installing these rows hot means next epoch's plan
        gathers never touch the mmap tier (given a covering budget).

        Grouping is by OWNER (who serves the read), unlike
        :meth:`epoch_requests`' by-REQUESTER grouping (who caches the
        fetch) — the two consumers of the same sampled future."""
        n = self.num_shards
        per_owner: list[list[np.ndarray]] = [[] for _ in range(n)]
        for it in range(iters):
            sets = self.iteration_sets(epoch, it)
            for ids in sets:
                if ids.size == 0:
                    continue
                own = self.owner[ids]
                for p in np.unique(own):
                    per_owner[int(p)].append(ids[own == p])
        out = []
        for p in range(n):
            if per_owner[p]:
                ids, cnt = np.unique(np.concatenate(per_owner[p]),
                                     return_counts=True)
                out.append((ids, cnt.astype(np.int64)))
            else:
                out.append((np.zeros(0, np.int64), np.zeros(0, np.int64)))
        return out

    def epoch_requests(self, epoch: int, iters: int
                       ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Per-shard (ids, request_counts) over a whole future epoch: the
        count of iterations in which each remote id will be requested — the
        exact frequency table an LFU ranks by. Per-iteration sets are
        already deduped, so one unique-with-counts over their concatenation
        at the end is the whole merge (no per-iteration re-sorting)."""
        n = self.num_shards
        per_shard: list[list[np.ndarray]] = [[] for _ in range(n)]
        for it in range(iters):
            reqs = self.iteration_requests(epoch, it)
            for s in range(min(n, len(reqs))):
                if reqs[s].size:
                    per_shard[s].append(reqs[s])
        out = []
        for s in range(n):
            if per_shard[s]:
                ids, cnt = np.unique(np.concatenate(per_shard[s]),
                                     return_counts=True)
                out.append((ids, cnt.astype(np.int64)))
            else:
                out.append((np.zeros(0, np.int64), np.zeros(0, np.int64)))
        return out

    def covering_rows(self, epoch: int, iters: int) -> int:
        """The per-shard row budget that covers *every* remote request of
        the epoch (the 100%-hit-rate point benchmarks sweep toward)."""
        hot = self.epoch_requests(epoch, iters)
        return max((ids.size for ids, _ in hot), default=0)
