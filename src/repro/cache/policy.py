"""Cache admission policies (repro.cache tentpole, part a).

A policy chooses, per shard, which *remote* vertices to keep resident under
a byte budget. Two families, mirroring RapidGNN's findings (PAPERS.md,
arXiv 2505.10806 / 2509.05207):

* :class:`DegreePolicy` — static, structural: the highest-degree vertices
  of a power-law graph dominate sampled neighborhoods, so the top-degree
  remote vertices of each shard are cached once and never refreshed.
  Zero prediction cost; hit rate bounded by how head-heavy the degree
  distribution is.
* :class:`LFUPolicy` — epoch-frequency LFU: ranks candidates by how often
  each (shard, vertex) pair actually appeared in observed
  :class:`~repro.core.pregather.GatherPlan` requests (one count per plan —
  pre-gathering dedups within an iteration, so the natural request unit is
  "shard s needed v this iteration"). The deterministic sampler lets the
  epoch prefetcher (repro.cache.prefetch) feed *next*-epoch frequencies
  instead, making the LFU exact rather than trailing.

Budgets are expressed in bytes; :func:`budget_rows` converts to cacheable
rows for a feature width.
"""
from __future__ import annotations

import numpy as np


def budget_rows(budget_bytes: int, feature_dim: int, itemsize: int = 4) -> int:
    """How many feature rows a per-shard byte budget admits."""
    if budget_bytes <= 0 or feature_dim <= 0:
        return 0
    return int(budget_bytes) // (int(feature_dim) * int(itemsize))


def _top_k_by_score(ids: np.ndarray, score: np.ndarray, k: int) -> np.ndarray:
    """The k ids with the highest score; ties broken by smaller id so the
    selection (and therefore the cache layout and every downstream device
    shape) is deterministic."""
    ids = np.asarray(ids, np.int64)
    if k <= 0 or ids.size == 0:
        return np.zeros(0, np.int64)
    if ids.size <= k:
        return np.sort(ids)
    # lexsort: last key is primary → (-score, id) ascending
    order = np.lexsort((ids, -np.asarray(score)))
    return np.sort(ids[order[:k]])


class DegreePolicy:
    """Static degree-based admission from the :class:`CSRGraph` structure."""

    name = "degree"
    static = True          # selection never changes → one install, ever

    def __init__(self, graph, owner: np.ndarray):
        self._deg = np.asarray(graph.degrees(), np.int64)
        self._owner = np.asarray(owner)

    def select(self, shard: int, k: int,
               hot_ids: np.ndarray | None = None,
               hot_counts: np.ndarray | None = None) -> np.ndarray:
        """Top-k remote vertices by degree. ``hot_ids`` (when given — e.g.
        from the prefetcher) restricts candidates to vertices that will
        actually be requested; the static default considers every remote
        vertex."""
        if hot_ids is not None:
            cand = np.asarray(hot_ids, np.int64)
            cand = cand[self._owner[cand] != shard]
        else:
            cand = np.nonzero(self._owner != shard)[0].astype(np.int64)
        return _top_k_by_score(cand, self._deg[cand], k)


class LFUPolicy:
    """Epoch-frequency LFU over observed (or predicted) plan requests.

    Counts are kept per shard as merged sorted (ids, counts) arrays —
    O(observed ids) memory, independent of the global vertex count.
    ``decay`` down-weights history each ``select`` round so the policy
    tracks drift in the request distribution (1.0 = pure cumulative LFU).
    """

    name = "lfu"
    static = False

    def __init__(self, num_shards: int, decay: float = 0.5):
        self.num_shards = int(num_shards)
        self.decay = float(decay)
        self._ids = [np.zeros(0, np.int64) for _ in range(self.num_shards)]
        self._counts = [np.zeros(0, np.float64)
                        for _ in range(self.num_shards)]
        self.observed_plans = 0

    def observe(self, shard: int, ids: np.ndarray,
                counts: np.ndarray | None = None) -> None:
        """Merge one request batch (deduped remote ids of a plan, or a
        predicted-frequency table) into the shard's running counts."""
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return
        w = (np.ones(ids.size, np.float64) if counts is None
             else np.asarray(counts, np.float64))
        cat = np.concatenate([self._ids[shard], ids])
        wat = np.concatenate([self._counts[shard], w])
        uniq, inv = np.unique(cat, return_inverse=True)
        acc = np.zeros(uniq.size, np.float64)
        np.add.at(acc, inv, wat)
        self._ids[shard], self._counts[shard] = uniq, acc

    def observe_plan(self, plan) -> None:
        """Record every (shard, remote id) request of a GatherPlan — the
        plan's SlotMap already holds exactly the deduped per-shard remote
        sets, so observation is zero extra planning work."""
        sm = plan.slot_map
        for s in range(sm.num_shards):
            self.observe(s, sm.shard_ids(s))
        self.observed_plans += 1

    def select(self, shard: int, k: int,
               hot_ids: np.ndarray | None = None,
               hot_counts: np.ndarray | None = None) -> np.ndarray:
        """Top-k by frequency.

        With predicted next-epoch frequencies (``hot_ids``/``hot_counts``
        from the deterministic prefetcher) the forecast is *exact*, so it
        alone ranks the candidates — mixing in stale history could evict a
        vertex that will be requested in favor of one that won't. The
        forecast is still folded into the decayed history so a later
        ``select`` without a forecast (trailing mode, or a prefetch miss)
        has it to fall back on."""
        if self.decay != 1.0 and self._counts[shard].size:
            self._counts[shard] = self._counts[shard] * self.decay
        if hot_ids is not None:
            hot_ids = np.asarray(hot_ids, np.int64)
            hot_counts = (np.ones(hot_ids.size, np.float64)
                          if hot_counts is None
                          else np.asarray(hot_counts, np.float64))
            self.observe(shard, hot_ids, hot_counts)
            return _top_k_by_score(hot_ids, hot_counts, k)
        return _top_k_by_score(self._ids[shard], self._counts[shard], k)


def make_policy(name: str, *, graph=None, owner=None, num_shards: int = 0,
                decay: float = 0.5):
    """Factory used by the Trainer: ``"degree"`` | ``"lfu"``."""
    if name == "degree":
        if graph is None or owner is None:
            raise ValueError("degree policy needs graph and owner")
        return DegreePolicy(graph, owner)
    if name == "lfu":
        if num_shards <= 0:
            raise ValueError("lfu policy needs num_shards")
        return LFUPolicy(num_shards, decay=decay)
    raise ValueError(f"unknown cache policy {name!r}")
