"""Shared benchmark scaffolding.

Every benchmark prints CSV rows ``benchmark,case,metric,value`` and also
returns them; ``benchmarks.run`` aggregates all into bench_output.txt and
benchmarks/results/*.csv. Dataset scale and iteration counts are sized for
a 1-core CPU container (ratios, not wall-clock, are the reproduced
quantities — see DESIGN.md §2).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.comm_model import FABRICS, ModelSpec
from repro.graph import ldg_partition, make_dataset
from repro.graph.partition import hash_partition, shard_features
from repro.models.gnn import GNNConfig, init_gnn, model_param_bytes
from repro.obs.export import run_manifest

RESULTS = Path(__file__).resolve().parent / "results"

# paper §7.1 model suite; hidden dims 16/128 evaluated in Fig. 11
PAPER_MODELS = {
    "gcn": dict(model="gcn", num_layers=3),
    "sage": dict(model="sage", num_layers=3),
    "gat": dict(model="gat", num_layers=3),
    "deepgcn": dict(model="deepgcn", num_layers=7),
    "film": dict(model="film", num_layers=10),
}

DEFAULT_FABRIC = FABRICS["ethernet_10g"]


class Bench:
    def __init__(self, name: str):
        self.name = name
        self.rows: list[tuple] = []

    def emit(self, case: str, metric: str, value):
        self.rows.append((self.name, case, metric, value))
        print(f"{self.name},{case},{metric},{value}")

    def save_csv(self):
        RESULTS.mkdir(parents=True, exist_ok=True)
        with open(RESULTS / f"{self.name}.csv", "w") as f:
            f.write("benchmark,case,metric,value\n")
            for r in self.rows:
                f.write(",".join(str(x) for x in r) + "\n")

    def save_json(self, path: Path | None = None,
                  seed: int | None = None) -> Path:
        """Write BENCH_<name>.json at the repo root: the machine-readable
        bench trajectory ({case: {metric: value}}) CI and the driver read.
        Every artifact carries a run manifest (git sha, jax/python
        versions, platform — repro.obs.export) so a bench JSON can always
        be matched to the commit that produced it."""
        out: dict = {}
        for _, case, metric, value in self.rows:
            out.setdefault(case, {})[metric] = value
        path = path or (Path(__file__).resolve().parents[1]
                        / f"BENCH_{self.name}.json")
        with open(path, "w") as f:
            json.dump({"benchmark": self.name,
                       "manifest": run_manifest(seed=seed),
                       "results": out}, f,
                      indent=2, sort_keys=True)
        return path


def setup(dataset="products", scale=0.02, parts=4, partitioner="community",
          seed=0):
    """``community`` = METIS stand-in (ground-truth communities; see
    repro.graph.partition.community_partition); ``ldg`` = streaming greedy;
    ``hash`` = P³-style random."""
    from repro.graph.partition import community_partition
    ds = make_dataset(dataset, scale=scale, seed=seed)
    if partitioner == "community":
        part = community_partition(ds.communities, parts)
    elif partitioner == "ldg":
        part = ldg_partition(ds.graph, parts, passes=1, seed=seed)
    else:
        part = hash_partition(ds.num_vertices, parts, seed)
    table, owner, local_idx = shard_features(ds.features, part, parts)
    return dict(ds=ds, parts=parts, part=part, table=table, owner=owner,
                local_idx=local_idx)


def gnn_cfg(model: str, env, hidden=128, fanout=10) -> GNNConfig:
    kw = PAPER_MODELS[model]
    # fixed-fanout trees grow f^L: deep models (DeepGCN 7L, FiLM 10L) use
    # fanout 2, mirroring the paper's own deep-GNN settings (§3.1)
    if kw["num_layers"] > 3:
        fanout = 2
    return GNNConfig(model=kw["model"], num_layers=kw["num_layers"],
                     hidden_dim=hidden, feature_dim=env["ds"].feature_dim,
                     num_classes=env["ds"].num_classes, fanout=fanout)


def model_spec(cfg: GNNConfig, env) -> ModelSpec:
    import jax
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    return ModelSpec(feature_dim=cfg.feature_dim, hidden_dim=cfg.hidden_dim,
                     num_layers=cfg.num_layers,
                     param_bytes=model_param_bytes(params))


def timer(fn, warmup=1, iters=3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def sample_roots(env, per_model, rng=None, seed=0):
    rng = rng or np.random.default_rng(seed)
    tv = env["ds"].train_vertices()
    return [rng.choice(tv, per_model, replace=False)
            for _ in range(env["parts"])]
