"""Fig. 17/18 reproduction: micrograph-merging dynamics.

* Fig. 17: the controller walks time steps down across epochs and freezes
  at the knee (modeled epoch time = comm seconds + per-step overhead).
* Fig. 18: paper's min-selection vs random (RD) merging — workload balance
  across servers and resulting epoch time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, DEFAULT_FABRIC, sample_roots, setup
from repro.core import MergingController, plan_iteration
from repro.core.micrograph import hopgnn_assignment
from repro.train import merging_walk

STEP_OVERHEAD_S = 3e-3      # per-time-step sync + kernel-launch cost model
F32 = 4


def _epoch_time(env, roots, assignment, fanout, dim):
    plan = plan_iteration(
        env["ds"].graph, env["ds"].labels, env["part"], env["owner"],
        env["local_idx"], env["table"].shape[1], roots,
        num_layers=3, fanout=fanout, strategy="hopgnn", pregather=True,
        assignment=assignment, sample_seed=9)
    comm = DEFAULT_FABRIC.seconds(
        plan.remote_rows_exact * dim * F32 / env["parts"])
    return comm + STEP_OVERHEAD_S * plan.num_steps, plan


def _biased_roots(env, per_model, seed=0):
    """Roots drawn with community skew (paper Fig. 18's imbalanced regime:
    real mini-batches are not uniform over partitions)."""
    rng = np.random.default_rng(seed)
    tv = env["ds"].train_vertices()
    w = 1.0 + 3.0 * (env["part"][tv] == 0)       # shard 0 over-represented
    p = w / w.sum()
    return [rng.choice(tv, per_model, replace=False, p=p)
            for _ in range(env["parts"])]


def run(quick=True):
    b = Bench("merging")
    # two datasets bracket the knee: products (100-dim features) is
    # overhead-dominated -- the controller merges all the way down; uk
    # (600-dim) is comm-dominated -- merging regresses immediately and the
    # controller freezes high. The *adaptivity* is the Fig. 17 claim.
    frozen_at = {}
    for dataset in ("products", "uk"):
        env = setup(dataset=dataset, scale=0.15 if quick else 0.3)
        fanout = 10
        dim = env["ds"].feature_dim
        roots = _biased_roots(env, 64)
        base = hopgnn_assignment([np.asarray(r, np.int64) for r in roots],
                                 env["part"])
        ctl = MergingController(base=base)
        walk = merging_walk(
            ctl, lambda amat: _epoch_time(env, roots, amat, fanout, dim),
            max_epochs=6)
        for epoch, (steps, t, _plan) in enumerate(walk):
            b.emit(f"fig17-{dataset}", f"epoch{epoch}_steps", steps)
            b.emit(f"fig17-{dataset}", f"epoch{epoch}_time_ms",
                   round(1000 * t, 2))
        frozen_at[dataset] = ctl.assignment_for_epoch().num_steps
        b.emit(f"fig17-{dataset}", "frozen_at_steps", frozen_at[dataset])

        # Fig. 18: min-selection vs random merging, one merge round
        ctl_min = MergingController(base=base, selector="min")
        ctl_rd = MergingController(base=base, selector="random", seed=1)
        for name, ctl2 in (("min", ctl_min), ("rd", ctl_rd)):
            ctl2.record_epoch_time(1.0)       # trigger one merge
            amat = ctl2.assignment_for_epoch()
            t, plan = _epoch_time(env, roots, amat, fanout, dim)
            counts = amat.root_counts()       # (T, N)
            imbalance = float(counts.max() / np.maximum(counts.mean(), 1))
            b.emit(f"fig18-{dataset}", f"{name}_time_ms",
                   round(1000 * t, 2))
            b.emit(f"fig18-{dataset}", f"{name}_imbalance",
                   round(imbalance, 2))
    b.emit("summary", "controller_adapts_per_dataset",
           int(frozen_at["products"] != frozen_at["uk"]))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
