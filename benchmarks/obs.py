"""repro.obs benchmark: tracing must observe, never perturb.

Three CI-gated contracts on the full pipelined + cached + streamed
stack (the heaviest instrumented configuration: tiered FeatureStore,
LFU device cache with prefetch, non-blocking fused dispatch):

1. **Overhead** — steady per-iteration wall with span tracing enabled
   stays within ``OVERHEAD_GATE_X`` (1.05×) of the tracing-off run. The
   recorder's hot path is one bool check when off and two clock reads +
   one ring store when on; anything above the gate is a regression on
   the dispatch path.
2. **Bit-parity** — losses AND parameters of the traced run are
   bit-identical to the untraced run (tracing only reads clocks; it
   must never touch params, plans, or rng state). Hard gate, exact 0.
3. **Coverage** — the exported Chrome-trace JSON is schema-valid and
   decomposes a steady iteration into the named spans (plan build,
   upload commit, dispatch, loss sync, cache refresh, readahead) across
   all four thread tracks (main / prefetch / uploader /
   cache+readahead).

Artifacts: BENCH_obs.json (repo root), the Perfetto-loadable timeline
at benchmarks/results/obs_trace.json, and a registry snapshot at
benchmarks/results/obs_metrics.jsonl (manifest header + one row per
counter group).
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import RESULTS, Bench
from repro.core import distributed as engine
from repro.features import FeatureStore
from repro.graph import ldg_partition, make_dataset
from repro.graph.partition import shard_features
from repro.models.gnn import GNNConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.export import (chrome_trace, run_manifest,
                              trace_span_names, trace_track_names,
                              validate_chrome_trace, write_metrics_jsonl)
from repro.optim import adam
from repro.train import Trainer

EPOCHS = 4
ITERS = 8
BATCH = 8
PARTS = 4
SEED = 0
OVERHEAD_GATE_X = 1.05

REQUIRED_SPANS = {"plan.build", "plan.wait", "upload.commit", "dispatch",
                  "loss.sync", "cache.refresh", "cache.forecast",
                  "features.readahead", "features.readahead.forecast"}
REQUIRED_TRACKS = {"main", "prefetch", "uploader", "cache+readahead"}


def _cfg(ds):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                     feature_dim=ds.feature_dim,
                     num_classes=ds.num_classes, fanout=4)


def _fit(ds, part, owner, local_idx, store, cfg):
    tr = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
                 local_idx=local_idx, table=store, cfg=cfg,
                 optimizer=adam(5e-3), merging=False,
                 train_vertices=ds.train_vertices(),
                 cache_policy="lfu", cache_budget_bytes=1 << 20,
                 loss_sync_iters=4)
    stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                   batch_per_model=BATCH)
    return tr, stats


def _steady_iter_ms(stats):
    # best steady epoch after warmup (compile excluded by the synced
    # steady window; see repro.train.pipeline timing semantics)
    return 1000 * float(np.min([s.steady_time_s / ITERS
                                for s in stats[1:]]))


def run(quick=True):
    import jax

    b = Bench("obs")
    scale = 0.04 if quick else 0.2
    ds = make_dataset("arxiv", scale=scale, seed=SEED)
    part = ldg_partition(ds.graph, PARTS, passes=1)
    table, owner, local_idx = shard_features(
        np.asarray(ds.features), part, PARTS)
    cfg = _cfg(ds)

    with tempfile.TemporaryDirectory() as td:
        def streamed(case):
            budget = max(1, int(table.nbytes) // 4)
            return FeatureStore.build(
                ds.features, part, PARTS,
                directory=str(Path(td) / case),
                host_budget_bytes=budget)

        # ---- A: tracing off (baseline + parity reference) ----
        obs_trace.disable()
        engine.clear_compile_cache()
        tr_off, st_off = _fit(ds, part, owner, local_idx,
                              streamed("off"), cfg)
        off_ms = _steady_iter_ms(st_off)

        # ---- B: identical run, tracing on ----
        obs_trace.enable()
        try:
            engine.clear_compile_cache()
            tr_on, st_on = _fit(ds, part, owner, local_idx,
                                streamed("on"), cfg)
        finally:
            obs_trace.disable()
        on_ms = _steady_iter_ms(st_on)
        overhead = on_ms / off_ms

        # ---- parity: losses and parameters, exact ----
        loss_dmax = float(np.max(np.abs(
            np.array([s.loss for s in st_on])
            - np.array([s.loss for s in st_off]))))
        params_equal = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(tr_off.params),
                            jax.tree.leaves(tr_on.params)))
        parity_ok = loss_dmax == 0.0 and params_equal

        # ---- trace export + coverage ----
        manifest = run_manifest(seed=SEED, config={
            "epochs": EPOCHS, "iters": ITERS, "batch": BATCH,
            "parts": PARTS, "scale": scale, "model": cfg.model})
        doc = chrome_trace(manifest=manifest)
        problems = validate_chrome_trace(doc)
        spans = trace_span_names(doc)
        tracks = trace_track_names(doc)
        missing_spans = sorted(REQUIRED_SPANS - spans)
        missing_tracks = sorted(REQUIRED_TRACKS - tracks)
        coverage_ok = not missing_spans and not missing_tracks
        schema_ok = not problems
        RESULTS.mkdir(parents=True, exist_ok=True)
        trace_path = RESULTS / "obs_trace.json"
        import json
        trace_path.write_text(json.dumps(doc))

        snap = obs_metrics.registry().snapshot()
        write_metrics_jsonl(RESULTS / "obs_metrics.jsonl",
                            [{"kind": "counters", **snap["counters"]},
                             {"kind": "gauges", **snap["gauges"]}],
                            manifest=manifest)

    b.emit("tracing_off", "steady_iter_ms", round(off_ms, 2))
    b.emit("tracing_on", "steady_iter_ms", round(on_ms, 2))
    b.emit("tracing_on", "overhead_x", round(overhead, 3))
    b.emit("tracing_on", "span_records", len(obs_trace.records()))
    b.emit("tracing_on", "dropped_records", obs_trace.dropped())
    b.emit("parity", "loss_dmax_traced_vs_untraced", loss_dmax)
    b.emit("parity", "params_bit_equal", int(params_equal))
    b.emit("trace", "spans_named", len(spans))
    b.emit("trace", "tracks", len(tracks))
    b.emit("trace", "missing_spans", ";".join(missing_spans) or "none")
    b.emit("trace", "missing_tracks", ";".join(missing_tracks) or "none")
    b.emit("trace", "schema_problems", len(problems))
    b.emit("trace", "file", str(trace_path))
    b.emit("summary", "overhead_gate_x", OVERHEAD_GATE_X)
    b.emit("summary", "meets_overhead_gate",
           int(overhead <= OVERHEAD_GATE_X))
    b.emit("summary", "parity_ok", int(parity_ok))
    b.emit("summary", "coverage_ok", int(coverage_ok))
    b.emit("summary", "schema_ok", int(schema_ok))
    b.save_csv()
    b.save_json(seed=SEED)
    obs_trace.clear()
    return b


if __name__ == "__main__":
    run()
