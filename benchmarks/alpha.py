"""Fig. 5 reproduction: α = remote-fetched feature bytes / model bytes,
across the GNN model suite (incl. deep variants) and hidden dims 16/128.

Paper finding: α ∈ [13.4, 2368.1], growing with depth — the motivation for
moving the model instead of the features.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, gnn_cfg, model_spec, sample_roots, setup
from repro.core import plan_iteration
from repro.core.comm_model import alpha_ratio


def run(quick=True):
    b = Bench("alpha")
    env = setup(dataset="products", scale=0.02 if quick else 0.1)
    fanout = 5 if quick else 10
    alphas = []
    for model in ("gcn", "sage", "gat", "deepgcn", "film"):
        for hidden in (16, 128):
            cfg = gnn_cfg(model, env, hidden=hidden, fanout=fanout)
            spec = model_spec(cfg, env)
            roots = sample_roots(env, 32)
            plan = plan_iteration(
                env["ds"].graph, env["ds"].labels, env["part"],
                env["owner"], env["local_idx"], env["table"].shape[1],
                roots, num_layers=cfg.num_layers, fanout=cfg.fanout,
                strategy="model_centric", sample_seed=1)
            a = alpha_ratio(plan.remote_rows_exact, cfg.feature_dim,
                            spec.param_bytes)
            alphas.append(a)
            b.emit(f"{model}-h{hidden}", "alpha", round(a, 1))
            b.emit(f"{model}-h{hidden}", "log2_alpha",
                   round(float(np.log2(max(a, 1e-9))), 2))
    b.emit("summary", "alpha_min", round(min(alphas), 1))
    b.emit("summary", "alpha_max", round(max(alphas), 1))
    # the paper's regime check: α ≫ 1 everywhere
    b.emit("summary", "alpha_gt_1_everywhere",
           int(all(a > 1 for a in alphas)))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
