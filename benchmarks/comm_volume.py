"""Fig. 7 reproduction: total bytes moved per iteration — model-centric vs
naive feature-centric — plus the HopGNN/P³/LO points (Fig. 11's mechanism).

Paper finding: naive FC can be up to 2.59× *worse* than model-centric
(intermediate data + repeated migrations); HopGNN beats both.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, gnn_cfg, model_spec, sample_roots, setup
from repro.core import plan_iteration
from repro.core.comm_model import (hopgnn_bytes, lo_bytes,
                                   model_centric_bytes, naive_fc_bytes,
                                   p3_bytes)
from repro.graph.sampler import micrograph_split, sample_tree_block


def run(quick=True):
    b = Bench("comm_volume")
    worst_naive_ratio = 0.0
    for dataset in ("arxiv", "products", "uk"):
        # large enough that feature volume dominates model-migration bytes
        # (the paper's regime; small graphs saturate unique-vertex counts)
        env = setup(dataset=dataset, scale=0.15 if quick else 0.3)
        fanout = 10
        for model in ("gcn", "gat", "film"):
            cfg = gnn_cfg(model, env, fanout=fanout)
            spec = model_spec(cfg, env)
            rng = np.random.default_rng(0)
            roots_pm = sample_roots(env, 64, rng=rng)
            # per-root micrographs for the byte models
            micros, shard_of = [], []
            for s, roots in enumerate(roots_pm):
                blk = sample_tree_block(env["ds"].graph, roots,
                                        cfg.num_layers, cfg.fanout, seed=11)
                micros.extend(micrograph_split(blk))
                shard_of.extend([s] * len(roots))
            mc = model_centric_bytes(micros, env["owner"], shard_of, spec,
                                     env["parts"])
            nv = naive_fc_bytes(micros, env["owner"], spec, env["parts"])
            p3 = p3_bytes(micros, env["owner"], shard_of, spec, env["parts"])
            lo = lo_bytes(spec, env["parts"])
            plan = plan_iteration(
                env["ds"].graph, env["ds"].labels, env["part"],
                env["owner"], env["local_idx"], env["table"].shape[1],
                roots_pm, num_layers=cfg.num_layers, fanout=cfg.fanout,
                strategy="hopgnn", pregather=True, sample_seed=11)
            hop_spmd = hopgnn_bytes(plan.remote_rows_exact, plan.num_steps,
                                    spec, env["parts"],
                                    replicated_params=True)
            hop_paper = hopgnn_bytes(plan.remote_rows_exact, plan.num_steps,
                                     spec, env["parts"],
                                     replicated_params=False)
            case = f"{dataset}-{model}"
            for name, d in (("model_centric", mc), ("naive_fc", nv),
                            ("p3", p3), ("lo", lo),
                            ("hopgnn_spmd", hop_spmd),
                            ("hopgnn_paper", hop_paper)):
                b.emit(case, f"{name}_MB", round(d["total"] / 1e6, 3))
            ratio = nv["total"] / max(mc["total"], 1)
            worst_naive_ratio = max(worst_naive_ratio, ratio)
            b.emit(case, "naive_over_mc", round(ratio, 2))
            b.emit(case, "hopgnn_speedup_bytes",
                   round(mc["total"] / max(hop_paper["total"], 1), 2))
            b.emit(case, "hopgnn_spmd_speedup_bytes",
                   round(mc["total"] / max(hop_spmd["total"], 1), 2))
    b.emit("summary", "naive_worst_ratio", round(worst_naive_ratio, 2))
    # paper observes naive can exceed MC (up to 2.59×)
    b.emit("summary", "naive_can_exceed_mc", int(worst_naive_ratio > 1.0))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
