"""repro.serve benchmark: online-inference latency/throughput + gates.

Four CI-facing contracts (BENCH_serve.json, repo root):

1. **Parity** (hard gate) — served logits bitwise-equal to the offline
   eval forward for a probe set, with the hot-feature cache enabled and
   refreshing mid-run. Exact 0/1.
2. **Retraces** (hard gate) — steady-state serving compiles nothing after
   :meth:`GNNServer.warmup`; the engine trace log's ``infer`` count must
   not move across the whole bench. Exact 0.
3. **Dynamic batching** (timing gate, retried once in CI) — at
   saturation (closed-loop burst) the dynamic micro-batcher must beat
   batch-size-1 serving by ≥ 2× on throughput.
4. **Latency curve** — open-loop offered-QPS sweep at 3 levels below
   saturation, reporting served p50/p99 ms per level (the user-visible
   latency semantics: submit → result, queue wait included).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, setup
from repro.core.distributed import infer_trace_count
from repro.features import FeatureStore
from repro.serve import GNNServer
from repro.train.budget import ShapeBudget

SPEEDUP_GATE_X = 2.0
QPS_FRACTIONS = (0.25, 0.5, 0.8)


def _make_server(env, cfg, params, store, *, max_batch, budget=None):
    return GNNServer(graph=env["ds"].graph, params=params, cfg=cfg,
                     store=store, budget=budget, max_batch=max_batch,
                     cache_budget_bytes=1 << 20, cache_refresh_every=8)


def _drain_burst(srv, nodes) -> float:
    """Closed-loop: enqueue everything, pump to empty. Returns seconds."""
    t0 = time.perf_counter()
    tickets = [srv.submit(int(v)) for v in nodes]
    while not all(t.done() for t in tickets):
        srv.loop.pump(wait_s=0.0)
    return time.perf_counter() - t0


def _offered_sweep(srv, nodes, offered_qps) -> dict:
    """Open-loop: background serving thread, client paces submissions at
    ``offered_qps``; latency is submit → result per ticket."""
    srv.start()
    try:
        gap = 1.0 / offered_qps
        tickets = []
        t_next = time.perf_counter()
        for v in nodes:
            now = time.perf_counter()
            if now < t_next:
                time.sleep(t_next - now)
            tickets.append(srv.submit(int(v)))
            t_next += gap
        t0 = time.perf_counter()
        for t in tickets:
            t.wait(120.0)
        lat_ms = np.array([1e3 * t.latency_s() for t in tickets])
        span = max(tickets[-1].t_done - tickets[0].t_submit, 1e-9)
        return {"achieved_qps": round(len(tickets) / span, 1),
                "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
                "p99_ms": round(float(np.percentile(lat_ms, 99)), 3)}
    finally:
        srv.stop()


def run(quick: bool = True) -> Bench:
    import jax
    from benchmarks.common import gnn_cfg
    from repro.models.gnn import init_gnn

    b = Bench("serve")
    env = setup(dataset="products", scale=0.02, parts=4,
                partitioner="community", seed=0)
    store = FeatureStore.from_array(env["table"], owner=env["owner"],
                                    local_idx=env["local_idx"])
    cfg = gnn_cfg("sage", env, hidden=32)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n = env["ds"].graph.num_vertices
    burst_n = 128 if quick else 256
    sweep_n = 60 if quick else 150

    # both servers warm up (compile their rungs) before the retrace
    # baseline is taken — everything after this line must compile nothing
    dyn = _make_server(env, cfg, params, store, max_batch=64)
    dyn.warmup()
    b1 = _make_server(env, cfg, params, store, max_batch=1,
                      budget=ShapeBudget(min_batch_pad=1))
    b1.warmup()
    traces_baseline = infer_trace_count()

    # ---- dynamic batcher at saturation ---------------------------------
    burst = rng.integers(0, n, burst_n)
    dyn_s = _drain_burst(dyn, burst)
    dyn_qps = burst_n / dyn_s
    b.emit("saturation", "dyn_qps", round(dyn_qps, 1))
    b.emit("saturation", "dyn_mean_batch",
           round(dyn.loop.served / max(dyn.loop.batches, 1), 1))

    # ---- batch-size-1 baseline (honest: batch_pad rung of 1) -----------
    b1_s = _drain_burst(b1, burst)
    b1_qps = burst_n / b1_s
    speedup = dyn_qps / b1_qps
    b.emit("saturation", "b1_qps", round(b1_qps, 1))
    b.emit("saturation", "speedup_x", round(speedup, 2))
    b.emit("saturation", "meets_2x_gate", int(speedup >= SPEEDUP_GATE_X))

    # ---- offered-QPS sweep (open loop, 3 levels below saturation) ------
    for frac in QPS_FRACTIONS:
        offered = max(dyn_qps * frac, 1.0)
        nodes = rng.integers(0, n, sweep_n)
        res = _offered_sweep(dyn, nodes, offered)
        case = f"qps_{frac}"
        b.emit(case, "offered_qps", round(offered, 1))
        for k, v in res.items():
            b.emit(case, k, v)

    # ---- hard gates: parity + compile-once -----------------------------
    import jax.numpy as jnp
    from repro.graph.sampler import sample_tree_block
    from repro.models.gnn import gnn_forward
    probe = np.unique(rng.integers(0, n, 32))
    got = dyn.predict(probe.tolist())
    blk = sample_tree_block(env["ds"].graph, probe, cfg.num_layers,
                            cfg.fanout, seed=999)
    feats = [jnp.asarray(store.take_global(ids)) for ids in blk.hops]
    ref = np.asarray(gnn_forward(params, cfg, feats))
    b.emit("parity", "bitwise_equal", int(np.array_equal(got, ref)))

    b.emit("retraces", "after_warmup",
           infer_trace_count() - traces_baseline)
    b.emit("retraces", "cache_installs", dyn.stats()["cache_installs"])

    b.save_csv()
    b.save_json(seed=0)
    return b


if __name__ == "__main__":
    run(quick=True)
