"""Fig. 22/23 reproduction: sensitivity to batch size, feature dimension,
fanout, and shard count. Metric: modeled per-iteration time (comm over the
paper's fabric + measured compute is strategy-invariant, so the *ratio*
HopGNN/DGL is the reproduced quantity).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, DEFAULT_FABRIC, sample_roots, setup
from repro.core import plan_iteration
from repro.graph import make_dataset
from repro.graph.partition import shard_features
from repro.graph import ldg_partition

F32 = 4


def _ratio(env, per_model, fanout, dim):
    roots = sample_roots(env, per_model)
    kw = dict(num_layers=3, fanout=fanout, sample_seed=4)
    mc = plan_iteration(env["ds"].graph, env["ds"].labels, env["part"],
                        env["owner"], env["local_idx"],
                        env["table"].shape[1], roots,
                        strategy="model_centric", **kw)
    hop = plan_iteration(env["ds"].graph, env["ds"].labels, env["part"],
                         env["owner"], env["local_idx"],
                         env["table"].shape[1], roots, strategy="hopgnn",
                         pregather=True, **kw)
    t_mc = DEFAULT_FABRIC.seconds(mc.remote_rows_exact * dim * F32)
    t_hop = DEFAULT_FABRIC.seconds(hop.remote_rows_exact * dim * F32)
    return t_mc / max(t_hop, 1e-12), t_mc, t_hop


def run(quick=True):
    b = Bench("sensitivity")
    scale = 0.02 if quick else 0.1

    env = setup(dataset="products", scale=scale)
    dim = env["ds"].feature_dim
    # batch size sweep (Fig. 22a)
    for per_model in (8, 16, 32, 64):
        sp, *_ = _ratio(env, per_model, 5, dim)
        b.emit("batch", f"b{per_model * env['parts']}_speedup", round(sp, 2))
    # feature dim sweep (Fig. 22b) — dim affects bytes linearly for both;
    # ratio is dim-invariant in the byte model, but the paper's point is
    # the comm *share* grows: report hop comm at each dim
    for d in (100, 300, 600):
        sp, t_mc, t_hop = _ratio(env, 24, 5, d)
        b.emit("feature_dim", f"d{d}_dgl_comm_ms", round(1000 * t_mc, 2))
        b.emit("feature_dim", f"d{d}_hop_comm_ms", round(1000 * t_hop, 2))
        b.emit("feature_dim", f"d{d}_speedup", round(sp, 2))
    # fanout sweep (Fig. 23a)
    for f in (2, 5, 10):
        sp, *_ = _ratio(env, 16, f, dim)
        b.emit("fanout", f"f{f}_speedup", round(sp, 2))
    # P³ hidden-dim sensitivity (§7.2 observation 4: P³ wins at small
    # hidden dims, loses at large; HopGNN is hidden-dim independent)
    from benchmarks.common import gnn_cfg, model_spec, sample_roots
    from repro.core.comm_model import hopgnn_bytes, p3_bytes
    from repro.graph.sampler import micrograph_split, sample_tree_block
    for hidden in (16, 64, 128, 256):
        cfg = gnn_cfg("gat", env, hidden=hidden, fanout=10)
        spec = model_spec(cfg, env)
        roots_pm = sample_roots(env, 32)
        micros, shard_of = [], []
        for s, r in enumerate(roots_pm):
            blk = sample_tree_block(env["ds"].graph, r, cfg.num_layers,
                                    cfg.fanout, seed=6)
            micros.extend(micrograph_split(blk))
            shard_of.extend([s] * len(r))
        plan = plan_iteration(env["ds"].graph, env["ds"].labels,
                              env["part"], env["owner"], env["local_idx"],
                              env["table"].shape[1], roots_pm,
                              num_layers=cfg.num_layers, fanout=cfg.fanout,
                              strategy="hopgnn", pregather=True,
                              sample_seed=6)
        p3 = p3_bytes(micros, env["owner"], shard_of, spec, env["parts"])
        hop = hopgnn_bytes(plan.remote_rows_exact, plan.num_steps, spec,
                           env["parts"], replicated_params=True)
        b.emit("p3_hidden", f"h{hidden}_p3_MB", round(p3["total"] / 1e6, 2))
        b.emit("p3_hidden", f"h{hidden}_hop_MB",
               round(hop["total"] / 1e6, 2))
        b.emit("p3_hidden", f"h{hidden}_hop_over_p3",
               round(p3["total"] / max(hop["total"], 1), 2))

    # shard count sweep (Fig. 23b)
    ds = make_dataset("products", scale=scale, seed=0)
    for parts in (2, 4, 6, 8):
        part = ldg_partition(ds.graph, parts, passes=1)
        table, owner, local_idx = shard_features(ds.features, part, parts)
        env2 = dict(ds=ds, parts=parts, part=part, table=table,
                    owner=owner, local_idx=local_idx)
        sp, *_ = _ratio(env2, 16, 5, dim)
        b.emit("shards", f"n{parts}_speedup", round(sp, 2))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
