"""Fig. 13/14/15/16 reproduction: the contribution of each technique.

+MG  = micrograph-based training (vs model-centric baseline)
+PG  = +MG with pre-gathering
All  = +PG with merging (merging's effect is on time steps; its byte
       effect is neutral — Fig. 17's win is sync/launch overhead)

Metrics: remote feature rows (the paper's "remote requests"), miss rate
(Fig. 14), and modeled comm seconds.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, DEFAULT_FABRIC, sample_roots, setup
from repro.core import plan_iteration
from repro.core.merging import merge_min_step


def run(quick=True):
    b = Bench("ablation")
    for dataset in ("arxiv", "products", "uk", "in"):
        env = setup(dataset=dataset, scale=0.02 if quick else 0.1)
        fanout = 5 if quick else 10
        roots = sample_roots(env, 24)
        common = dict(num_layers=3, fanout=fanout, sample_seed=3)

        def mk(strategy, pregather, assignment=None):
            return plan_iteration(
                env["ds"].graph, env["ds"].labels, env["part"],
                env["owner"], env["local_idx"], env["table"].shape[1],
                roots, strategy=strategy, pregather=pregather,
                assignment=assignment, **common)

        dgl = mk("model_centric", True)
        mg = mk("hopgnn", False)        # micrographs, per-step fetches
        pg = mk("hopgnn", True)         # + pre-gathering
        merged = merge_min_step(pg.assignment)
        al = mk("hopgnn", True, assignment=merged)   # + merging

        for name, plan in (("dgl", dgl), ("+MG", mg), ("+PG", pg),
                           ("All", al)):
            b.emit(dataset, f"{name}_remote_rows", plan.remote_rows_exact)
            b.emit(dataset, f"{name}_miss_rate_pct",
                   round(100 * plan.miss_rate_per_request(), 1))
            b.emit(dataset, f"{name}_steps", plan.num_steps)
        b.emit(dataset, "mg_miss_improvement_pct",
               round(100 * (dgl.miss_rate_per_request()
                            - mg.miss_rate_per_request()), 1))
        b.emit(dataset, "pg_request_reduction",
               round(mg.remote_rows_exact / max(pg.remote_rows_exact, 1), 2))
        # Fig. 13 ordering: each technique monotonically helps (bytes)
        b.emit(dataset, "monotone",
               int(dgl.remote_rows_exact >= mg.remote_rows_exact
                   >= pg.remote_rows_exact))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
