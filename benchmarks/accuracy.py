"""Table 3 reproduction: model accuracy — DGL (model-centric) vs LO
(locality-optimized, biased) vs HopGNN — after identical training budgets
on the synthetic Arxiv analogue.

Paper finding: HopGNN matches DGL to <0.1 %; LO drops accuracy.
"""
from __future__ import annotations

from benchmarks.common import Bench, setup
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.train import Trainer


def _train(env, cfg, strategy, epochs, iters, seed=0):
    # identical root streams per strategy (root_seed) + stateless sampling
    # (sample_seed) keep the comparison exact; the Trainer's shape budget
    # makes the loop compile-once instead of retracing every iteration.
    trainer = Trainer.from_env(env, cfg, strategy=strategy, merging=False,
                               optimizer=adam(5e-3), init_seed=seed,
                               root_seed=seed)
    trainer.fit(epochs=epochs, iters_per_epoch=iters, batch_per_model=16)
    return trainer


def run(quick=True):
    b = Bench("accuracy")
    env = setup(dataset="arxiv", scale=0.02 if quick else 0.1)
    epochs, iters = (2, 5) if quick else (5, 20)
    for model in ("gcn", "sage", "gat"):
        cfg = GNNConfig(model=model, num_layers=2, hidden_dim=32,
                        feature_dim=env["ds"].feature_dim,
                        num_classes=env["ds"].num_classes, fanout=4)
        accs = {}
        for strategy, name in (("model_centric", "dgl"), ("lo", "lo"),
                               ("hopgnn", "hopgnn")):
            trainer = _train(env, cfg, strategy, epochs, iters)
            accs[name] = trainer.evaluate(n_eval=512, seed=77)
            b.emit(model, f"{name}_acc_pct", round(100 * accs[name], 2))
        b.emit(model, "hopgnn_drop_pct",
               round(100 * (accs["dgl"] - accs["hopgnn"]), 2))
        b.emit(model, "lo_drop_pct",
               round(100 * (accs["dgl"] - accs["lo"]), 2))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
