"""Table 3 reproduction: model accuracy — DGL (model-centric) vs LO
(locality-optimized, biased) vs HopGNN — after identical training budgets
on the synthetic Arxiv analogue.

Paper finding: HopGNN matches DGL to <0.1 %; LO drops accuracy.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Bench, sample_roots, setup
from repro.core import plan_iteration, run_iteration
from repro.graph.sampler import sample_tree_block
from repro.models.gnn import GNNConfig, gnn_forward, init_gnn
from repro.optim import adam


def _train(env, cfg, strategy, epochs, iters, seed=0):
    import jax.numpy as jnp
    params = init_gnn(jax.random.PRNGKey(seed), cfg)
    opt = adam(5e-3)
    state = opt.init(params)
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        for it in range(iters):
            roots = sample_roots(env, 16, rng=rng)
            plan = plan_iteration(
                env["ds"].graph, env["ds"].labels, env["part"],
                env["owner"], env["local_idx"], env["table"].shape[1],
                roots, num_layers=cfg.num_layers, fanout=cfg.fanout,
                strategy=strategy, sample_seed=ep * 1000 + it)
            grads, _ = run_iteration(params, env["table"], plan, cfg)
            params, state = opt.update(grads, state, params)
    return params


def _acc(env, cfg, params, n_eval=512, seed=77):
    import jax.numpy as jnp
    ds = env["ds"]
    rng = np.random.default_rng(seed)
    nodes = rng.choice(ds.num_vertices, min(n_eval, ds.num_vertices),
                       replace=False)
    blk = sample_tree_block(ds.graph, nodes, cfg.num_layers, cfg.fanout,
                            seed=4242)
    feats = [jnp.asarray(ds.features[ids]) for ids in blk.hops]
    logits = gnn_forward(params, cfg, feats)
    return float((jnp.argmax(logits, -1) ==
                  jnp.asarray(ds.labels[nodes])).mean())


def run(quick=True):
    b = Bench("accuracy")
    env = setup(dataset="arxiv", scale=0.02 if quick else 0.1)
    epochs, iters = (2, 5) if quick else (5, 20)
    for model in ("gcn", "sage", "gat"):
        cfg = GNNConfig(model=model, num_layers=2, hidden_dim=32,
                        feature_dim=env["ds"].feature_dim,
                        num_classes=env["ds"].num_classes, fanout=4)
        accs = {}
        for strategy, name in (("model_centric", "dgl"), ("lo", "lo"),
                               ("hopgnn", "hopgnn")):
            params = _train(env, cfg, strategy, epochs, iters)
            accs[name] = _acc(env, cfg, params)
            b.emit(model, f"{name}_acc_pct", round(100 * accs[name], 2))
        b.emit(model, "hopgnn_drop_pct",
               round(100 * (accs["dgl"] - accs["hopgnn"]), 2))
        b.emit(model, "lo_drop_pct",
               round(100 * (accs["dgl"] - accs["lo"]), 2))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
