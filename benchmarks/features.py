"""repro.features benchmark: out-of-core training vs host-memory budget.

The tiered FeatureStore's claim is that a graph ~4× larger than host memory
trains at (nearly) resident speed, because the exact next-epoch readahead
keeps the gather path on the host hot tier instead of the mmap disk tier.
This bench spills a synthetic dataset's features to per-shard ``.npy``
memmaps, fixes the host budget at ¼ of the backing bytes (graph = 4× host
budget), and sweeps the hot-tier fraction 1.0 → 0.25 of that budget,
reporting per (fraction):

  * steady per-iteration wall time through the pipelined Trainer,
  * per-tier gather traffic (hot-tier rows/bytes vs disk rows/bytes) and
    the plan-carried upload bytes, plus the comm-model decomposition
    (:func:`repro.core.comm_model.tiered_feature_bytes`),
  * epoch-boundary readahead seconds (the promotion cost), and
  * loss bit-parity streamed-vs-resident at the full budget (must be 0).

Gate (CI): at the covering hot tier (fraction 1.0) steady iteration time
stays within 1.10× of the all-resident baseline — out-of-core must be
(close to) free when the forecast covers the epoch.

Writes BENCH_features.json at the repo root (benchmarks.common.Bench).
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import Bench
from repro.core import distributed as engine
from repro.core.comm_model import F32, tiered_feature_bytes
from repro.features import FeatureStore
from repro.graph import ldg_partition, make_dataset
from repro.graph.partition import shard_features
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.train import Trainer

EPOCHS = 4
ITERS = 4
BATCH = 8
PARTS = 4
FRACTIONS = (1.0, 0.5, 0.25)
FLAT_GATE_X = 1.10


def _cfg(ds):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                     feature_dim=ds.feature_dim,
                     num_classes=ds.num_classes, fanout=4)


def _fit(ds, part, owner, local_idx, table, cfg):
    tr = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
                 local_idx=local_idx, table=table, cfg=cfg,
                 optimizer=adam(5e-3), merging=False,
                 train_vertices=ds.train_vertices())
    stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                   batch_per_model=BATCH)
    return tr, stats


def _steady(stats):
    """Epochs after the first (hot tier warm, shapes settled)."""
    return stats[1:]


def _steady_iter_ms(stats):
    # best steady epoch: robust to single-core scheduler jitter
    return 1000 * float(np.min([s.steady_time_s / ITERS
                                for s in _steady(stats)]))


def run(quick=True):
    b = Bench("features")
    scale = 0.04 if quick else 0.2
    with tempfile.TemporaryDirectory() as td:
        ds = make_dataset("arxiv", scale=scale, seed=0,
                          spill_dir=str(td), feature_budget_bytes=1)
        part = ldg_partition(ds.graph, PARTS, passes=1)
        table, owner, local_idx = shard_features(
            np.asarray(ds.features), part, PARTS)
        cfg = _cfg(ds)
        row_bytes = ds.feature_dim * F32
        backing = int(table.nbytes)
        host_budget = backing // 4           # graph = 4× host budget
        b.emit("workload", "backing_bytes", backing)
        b.emit("workload", "host_budget_bytes", host_budget)
        b.emit("workload", "backing_to_budget_x",
               round(backing / host_budget, 2))

        # ---- all-resident baseline (the pre-store world) ----
        engine.clear_compile_cache()
        tr0, st0 = _fit(ds, part, owner, local_idx, table, cfg)
        base_ms = _steady_iter_ms(st0)
        losses0 = [s.loss for s in st0]
        b.emit("resident", "steady_iter_ms", round(base_ms, 2))
        b.emit("resident", "traces_after_epoch0",
               sum(s.traces for s in _steady(st0)))

        results = {}
        for frac in FRACTIONS:
            case = f"budget-{int(100 * frac)}pct"
            store = FeatureStore.build(
                ds.features, part, PARTS,
                directory=str(Path(td) / case),
                host_budget_bytes=max(1, int(host_budget * frac)))
            engine.clear_compile_cache()
            tr, st = _fit(ds, part, owner, local_idx, store, cfg)
            steady = _steady(st)
            ms = _steady_iter_ms(st)
            t1 = sum(s.tier1_rows for s in steady)
            t2 = sum(s.tier2_rows for s in steady)
            up = sum(s.upload_bytes for s in steady)
            ra = sum(s.readahead_s for s in steady)
            iters = len(steady) * ITERS
            model = tiered_feature_bytes(
                t1, t2, store.stats.readahead_rows, up,
                ds.feature_dim, iters)
            results[frac] = dict(ms=ms, losses=[s.loss for s in st])
            b.emit(case, "hot_rows_per_shard", store.hot_rows)
            b.emit(case, "steady_iter_ms", round(ms, 2))
            b.emit(case, "iter_ratio_vs_resident", round(ms / base_ms, 3))
            b.emit(case, "tier1_rows_per_iter", round(t1 / iters, 1))
            b.emit(case, "tier2_rows_per_iter", round(t2 / iters, 1))
            b.emit(case, "tier1_bytes_per_iter", round(t1 * row_bytes
                                                       / iters))
            b.emit(case, "tier2_bytes_per_iter", round(t2 * row_bytes
                                                       / iters))
            b.emit(case, "upload_bytes_per_iter", round(up / iters))
            b.emit(case, "disk_fraction", round(model["disk_fraction"], 4))
            b.emit(case, "readahead_s_per_epoch",
                   round(ra / len(steady), 4))
            b.emit(case, "traces_after_epoch0",
                   sum(s.traces for s in steady))

        # ---- gates ----
        full = results[1.0]
        flat = full["ms"] / base_ms
        b.emit("parity", "loss_dmax_resident_vs_full_budget",
               float(np.max(np.abs(np.array(full["losses"])
                                   - np.array(losses0)))))
        b.emit("summary", "flat_ratio_at_covering", round(flat, 3))
        b.emit("summary", "meets_flat_gate", int(flat <= FLAT_GATE_X))
        # monotone pressure check: shrinking the hot tier moves traffic
        # to the disk tier (informational; timing on 1 core is noisy)
        b.emit("summary", "out_of_core_trains", 1)
    b.save_csv()
    b.save_json()
    return b


if __name__ == "__main__":
    run()
