"""Host-planner microbenchmark: legacy (per-vertex Python) vs vectorized
plan construction across shard counts.

Plan construction is the host-side half of LeapGNN's pre-gathering (§5.2):
dedup every shard's remote-vertex set, lay the fetches out per peer
(``build_gather_plan``), and translate every tree-block hop's global ids to
workspace slots (``workspace_indices``). The seed implementation did this
with per-vertex dict inserts and list-comprehension lookups; the vectorized
planner is one ``np.unique`` over a combined (shard, peer, id) key plus
SlotMap gathers. This benchmark times both on the same sampled tree blocks
— sampling itself is excluded; it is identical work on both sides — and
writes the machine-readable trajectory to ``BENCH_planning.json``.

Acceptance gate: ≥ 10× at 8+ shards (``speedup`` metric, case ``n8``).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Bench, setup
from repro.core.pregather import (_reference_build_gather_plan,
                                  _reference_workspace_indices,
                                  build_gather_plan, workspace_indices)
from repro.graph.sampler import sample_tree_block

# the paper's standard suite is 3-layer fanout-10 (benchmarks/common.py)
FANOUT = 10
NUM_LAYERS = 3


def _sample_workload(env, n: int, roots_per_step: int, seed: int = 0):
    """One iteration's tree blocks: n shards × T=n rotation steps."""
    rng = np.random.default_rng(seed)
    tv = env["ds"].train_vertices()
    blocks = [[sample_tree_block(env["ds"].graph,
                                 rng.choice(tv, roots_per_step,
                                            replace=False),
                                 NUM_LAYERS, FANOUT, seed=7)
               for _ in range(n)] for _ in range(n)]          # [s][t]
    needed = [np.concatenate([blk.all_ids() for blk in row])
              for row in blocks]
    return blocks, needed


def _time(fn, iters: int) -> float:
    fn()                                   # warmup (page-in, allocator)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def run(quick=True):
    b = Bench("planning")
    shard_counts = (4, 8) if quick else (4, 8, 16)
    roots = 96 if quick else 256
    iters = 2 if quick else 3
    speedups = {}
    for n in shard_counts:
        env = setup(dataset="products", scale=0.15, parts=n)
        blocks, needed = _sample_workload(env, n, roots)
        owner, local_idx = env["owner"], env["local_idx"]
        local_rows = env["table"].shape[1]

        def plan_with(build, translate):
            plan = build(needed, owner, local_idx, n, local_rows)
            for s in range(n):
                for t in range(n):
                    translate(blocks[s][t].hops, s, owner, local_idx, plan)
            return plan

        t_vec = _time(lambda: plan_with(build_gather_plan,
                                        workspace_indices), iters)
        t_ref = _time(lambda: plan_with(_reference_build_gather_plan,
                                        _reference_workspace_indices), iters)
        # parity spot-check rides along: same req/counts both ways
        pv = plan_with(build_gather_plan, workspace_indices)
        pr = plan_with(_reference_build_gather_plan,
                       _reference_workspace_indices)
        np.testing.assert_array_equal(pv.req, pr.req)
        np.testing.assert_array_equal(pv.req_count, pr.req_count)

        case = f"n{n}"
        sp = t_ref / t_vec
        speedups[n] = sp
        b.emit(case, "legacy_ms", round(1000 * t_ref, 1))
        b.emit(case, "vectorized_ms", round(1000 * t_vec, 1))
        b.emit(case, "speedup", round(sp, 1))
        b.emit(case, "remote_rows", pv.remote_rows_exact())
        b.emit(case, "translated_ids", sum(
            sum(h.size for h in blocks[s][t].hops)
            for s in range(n) for t in range(n)))
    gate_n = max(k for k in speedups if k >= 8)
    b.emit("summary", "speedup_at_8plus_shards", round(speedups[gate_n], 1))
    b.emit("summary", "meets_10x_gate", int(speedups[gate_n] >= 10))
    b.save_csv()
    b.save_json()
    return b.rows


if __name__ == "__main__":
    run()
