"""repro.resilience benchmark: the cost of surviving faults.

Two claims get numbers here:

1. **Always-on plumbing is (nearly) free.** The default ResiliencePolicy
   adds one params/opt tree copy per epoch (the rollback snapshot), a
   deque peek per dispatch (the supervisor check), an ``isfinite`` per
   loss-sync window, and the retry guard around argument staging. Gate:
   steady per-iteration wall with the policy on stays within
   ``OVERHEAD_GATE_X`` (1.15×) of the policy-off run.

2. **Recovery costs throughput, never numerics.** Under the headline
   recoverable FaultPlan (background-thread kill + straggler + dropped
   exchange + corrupted disk rows + a NaN step) on the full streamed
   stack, training completes with losses bit-identical to the fault-free
   run (``parity`` must be exactly 0), every fault class fires, and the
   *steady* per-iteration time — recovery replays excluded by taking the
   best steady epoch — stays within the same 1.15× gate. Total wall grows
   by roughly the replayed epochs; that is reported as
   ``recovery_wall_ratio`` (informational: it measures the plan, not the
   plumbing).

Writes BENCH_resilience.json at the repo root (benchmarks.common.Bench).
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import Bench
from repro.core import distributed as engine
from repro.features import FeatureStore
from repro.graph import ldg_partition, make_dataset
from repro.graph.partition import shard_features
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.resilience import FaultPlan
from repro.train import Trainer

EPOCHS = 4
ITERS = 6
BATCH = 8
PARTS = 4
OVERHEAD_GATE_X = 1.15


def _cfg(ds):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                     feature_dim=ds.feature_dim,
                     num_classes=ds.num_classes, fanout=4)


def _fit(ds, part, owner, local_idx, table, cfg, plan=None, **kw):
    tr = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
                 local_idx=local_idx, table=table, cfg=cfg,
                 optimizer=adam(5e-3), merging=False,
                 train_vertices=ds.train_vertices(), **kw)
    if plan is not None:
        with plan.active():
            stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                           batch_per_model=BATCH)
    else:
        stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                       batch_per_model=BATCH)
    return tr, stats


def _steady_iter_ms(stats):
    # best steady epoch after warmup: excludes compile and — in the
    # faulted run — the replayed epochs' recovery wall
    return 1000 * float(np.min([s.steady_time_s / ITERS
                                for s in stats[1:]]))


def _wall_s(stats):
    return float(sum(s.time_s for s in stats))


def run(quick=True):
    b = Bench("resilience")
    scale = 0.04 if quick else 0.2
    ds = make_dataset("arxiv", scale=scale, seed=0)
    part = ldg_partition(ds.graph, PARTS, passes=1)
    table, owner, local_idx = shard_features(
        np.asarray(ds.features), part, PARTS)
    cfg = _cfg(ds)

    # ---- 1. plumbing overhead: policy off vs always-on default ----
    engine.clear_compile_cache()
    _, st_off = _fit(ds, part, owner, local_idx, table, cfg,
                     resilience=False)
    off_ms = _steady_iter_ms(st_off)
    engine.clear_compile_cache()
    _, st_on = _fit(ds, part, owner, local_idx, table, cfg)
    on_ms = _steady_iter_ms(st_on)
    overhead = on_ms / off_ms
    b.emit("policy_off", "steady_iter_ms", round(off_ms, 2))
    b.emit("policy_on", "steady_iter_ms", round(on_ms, 2))
    b.emit("policy_on", "overhead_x", round(overhead, 3))
    b.emit("parity", "loss_dmax_policy_on_vs_off",
           float(np.max(np.abs(np.array([s.loss for s in st_on])
                               - np.array([s.loss for s in st_off])))))

    # ---- 2. recovery under the headline recoverable FaultPlan ----
    with tempfile.TemporaryDirectory() as td:
        def streamed(case):
            budget = max(1, int(table.nbytes) // 4)
            return FeatureStore.build(
                ds.features, part, PARTS,
                directory=str(Path(td) / case),
                host_budget_bytes=budget, crc_chunk_rows=256)

        engine.clear_compile_cache()
        _, st_clean = _fit(ds, part, owner, local_idx,
                           streamed("clean"), cfg)
        clean_ms = _steady_iter_ms(st_clean)
        clean_wall = _wall_s(st_clean)
        fp = FaultPlan.recoverable(seed=7)
        engine.clear_compile_cache()
        tr_f, st_f = _fit(ds, part, owner, local_idx,
                          streamed("faulty"), cfg, plan=fp)
        faulty_ms = _steady_iter_ms(st_f)
        parity = float(np.max(np.abs(
            np.array([s.loss for s in st_f])
            - np.array([s.loss for s in st_clean]))))
        kinds = sorted({k for (k, *_r) in fp.fired})
        steady_ratio = faulty_ms / clean_ms
        b.emit("streamed_clean", "steady_iter_ms", round(clean_ms, 2))
        b.emit("streamed_clean", "wall_s", round(clean_wall, 2))
        b.emit("recoverable", "steady_iter_ms", round(faulty_ms, 2))
        b.emit("recoverable", "wall_s", round(_wall_s(st_f), 2))
        b.emit("recoverable", "steady_ratio_vs_clean",
               round(steady_ratio, 3))
        b.emit("recoverable", "recovery_wall_ratio",
               round(_wall_s(st_f) / clean_wall, 3))
        b.emit("recoverable", "fault_classes_fired", len(kinds))
        b.emit("recoverable", "faults_fired", fp.fired_count())
        b.emit("recoverable", "epoch_attempts",
               sum(s.epoch_attempts for s in st_f))
        b.emit("recoverable", "comm_retries",
               sum(s.comm_retries for s in st_f))
        b.emit("recoverable", "rollbacks", sum(s.rollbacks for s in st_f))
        b.emit("recoverable", "bg_errors", sum(s.bg_errors for s in st_f))
        b.emit("recoverable", "crc_failures_repaired",
               tr_f.store.stats.crc_failures)
        b.emit("parity", "loss_dmax_faulted_vs_clean", parity)

    # ---- gates ----
    b.emit("summary", "overhead_gate_x", OVERHEAD_GATE_X)
    b.emit("summary", "meets_overhead_gate",
           int(overhead <= OVERHEAD_GATE_X
               and steady_ratio <= OVERHEAD_GATE_X))
    b.emit("summary", "parity_ok", int(parity == 0.0))
    b.emit("summary", "all_fault_classes_fired", int(len(kinds) == 5))
    b.save_csv()
    b.save_json()
    return b


if __name__ == "__main__":
    run()
