"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only name]

Emits ``benchmark,case,metric,value`` CSV rows (also saved under
benchmarks/results/) — see EXPERIMENTS.md for the paper-claim mapping.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


SUITES = ["alpha", "locality", "comm_volume", "end_to_end", "ablation",
          "merging", "sensitivity", "accuracy", "roofline", "planning",
          "cache", "features", "resilience", "obs", "serve", "membership"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (slow on 1 CPU core)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    suites = [args.only] if args.only else SUITES
    failures = []
    for name in suites:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        try:
            mod.run(quick=not args.full)
            print(f"----- {name} done in {time.perf_counter() - t0:.1f}s")
        except Exception:                               # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED suites: {failures}")
        sys.exit(1)
    print("\nall benchmark suites completed")


if __name__ == "__main__":
    main()
