"""Table 1 reproduction: micrograph locality R_micro vs subgraph locality
R_sub across partitioners (METIS-like LDG vs range heuristic), sampling
families (node-wise vs layer-wise), shard counts (2–16), and model depths
(2L vs 10L).

Paper finding: R_micro > R_sub always; the gap widens with shard count
(1.59× at 2 shards → 10.6× at 16).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench
from repro.graph import ldg_partition, make_dataset, range_partition
from repro.graph.sampler import (layerwise_sample, micrograph_split,
                                 sample_tree_block)


def _r_micro_r_sub(ds, part, layers, fanout, n_roots=48, seed=0):
    rng = np.random.default_rng(seed)
    roots = rng.choice(ds.num_vertices, n_roots, replace=False)
    blk = sample_tree_block(ds.graph, roots, layers, fanout, seed=seed)
    micros = micrograph_split(blk)
    r_micro = float(np.mean([m.locality(part) for m in micros]))
    # R_sub: non-root vertices co-located with a designated root (paper §4)
    non_root = np.concatenate(blk.hops[1:])
    r_sub = float(np.mean(part[non_root] == part[roots[0]]))
    return r_micro, r_sub


def _r_layerwise(ds, part, layers, layer_size, n_roots=48, seed=0):
    rng = np.random.default_rng(seed)
    roots = rng.choice(ds.num_vertices, n_roots, replace=False)
    r_micros = []
    for r in roots[:16]:
        lyrs = layerwise_sample(ds.graph, np.array([r]), layers, layer_size,
                                np.random.default_rng(seed))
        non_root = np.concatenate(lyrs[1:]) if len(lyrs) > 1 else np.array([])
        if non_root.size:
            r_micros.append(float(np.mean(part[non_root] == part[r])))
    return float(np.mean(r_micros)) if r_micros else 1.0


def run(quick=True):
    b = Bench("locality")
    for dataset, part_name in (("arxiv", "ldg"), ("products", "ldg"),
                               ("uk", "range"), ("it", "range")):
        scale = 0.02 if quick else 0.1
        if dataset == "it":
            scale = 0.01 if quick else 0.05
        ds = make_dataset(dataset, scale=scale, seed=0)
        for shards in (2, 4, 8, 16):
            part = (ldg_partition(ds.graph, shards, passes=1)
                    if part_name == "ldg"
                    else range_partition(ds.num_vertices, shards))
            for layers, tag in ((2, "2L"), (10, "10L")):
                fanout = 2 if layers == 10 else 5
                r_micro, r_sub = _r_micro_r_sub(ds, part, layers, fanout)
                case = f"{dataset}-{part_name}-S{shards}-{tag}"
                b.emit(case, "r_micro_pct", round(100 * r_micro, 1))
                b.emit(case, "r_sub_pct", round(100 * r_sub, 1))
                b.emit(case, "micro_gt_sub", int(r_micro > r_sub))
        # layer-wise sampling family (Table 1 lower half), 4 shards
        part = (ldg_partition(ds.graph, 4, passes=1) if part_name == "ldg"
                else range_partition(ds.num_vertices, 4))
        rl = _r_layerwise(ds, part, 2, layer_size=32)
        b.emit(f"{dataset}-{part_name}-S4-layerwise2L", "r_micro_pct",
               round(100 * rl, 1))
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    run()
