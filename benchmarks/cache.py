"""repro.cache benchmark: hit rate and per-iteration remote traffic vs
cache budget, 0% → covering, for both admission policies.

LeapGNN's pre-gathering dedups remote fetches within one iteration; the
cache layer removes the *recurring* cross-iteration traffic (RapidGNN,
PAPERS.md). This bench sweeps the per-shard byte budget from nothing to
"covers every remote request of an epoch" and reports, per (policy,
budget):

  * steady-state cache hit rate (epochs after the first refresh),
  * measured remote feature bytes per iteration (misses × row bytes) and
    the drop vs cache-off — the ≥ 2× acceptance gate at covering budget,
  * steady per-iteration wall time through the Trainer,
  * jit traces in steady epochs (must be 0: refreshes never retrace),
  * gradient bit-parity cache-on vs cache-off (must be exact), and
  * the cache-adjusted α ratio next to the plain one.

Writes BENCH_cache.json at the repo root (benchmarks.common.Bench).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Bench, setup
from repro.cache import CacheStore, DegreePolicy, EpochPrefetcher
from repro.core import distributed as engine
from repro.core import plan_iteration, run_iteration
from repro.core.comm_model import F32, alpha_ratio, alpha_ratio_cached
from repro.models.gnn import GNNConfig, init_gnn, model_param_bytes
from repro.optim import adam
from repro.train import Trainer

EPOCHS = 3
ITERS = 4
BATCH = 8


def _cfg(env, hidden=32):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=hidden,
                     feature_dim=env["ds"].feature_dim,
                     num_classes=env["ds"].num_classes, fanout=4)


def _trainer(env, cfg, **kw):
    return Trainer.from_env(env, cfg, optimizer=adam(5e-3), merging=False,
                            **kw)


def _fit(env, cfg, **kw):
    tr = _trainer(env, cfg, **kw)
    stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                   batch_per_model=BATCH)
    return tr, stats


def _steady(stats):
    """Epochs after the first refresh landed (epoch 0 is cold for lfu)."""
    return stats[1:]


def run(quick=True):
    b = Bench("cache")
    env = setup(dataset="products", scale=0.03 if quick else 0.15)
    cfg = _cfg(env)
    d = env["ds"].feature_dim
    row_bytes = d * F32

    # covering budget: every remote request of a steady epoch fits
    probe = _trainer(env, cfg)
    pf = EpochPrefetcher(
        graph=env["ds"].graph, part=env["part"], owner=env["owner"],
        num_shards=env["parts"], num_layers=cfg.num_layers,
        fanout=cfg.fanout,
        roots_for=lambda e, i: probe._roots_for(e, i, BATCH),
        sample_seed_for=lambda e, i: e * 10_000 + i)
    covering = max(pf.covering_rows(e, ITERS) for e in range(1, EPOCHS))
    b.emit("workload", "covering_rows_per_shard", covering)
    b.emit("workload", "feature_dim", d)

    # ---- baseline: cache off ----
    engine.clear_compile_cache()
    _, stats0 = _fit(env, cfg)
    miss0 = sum(s.remote_rows for s in _steady(stats0)) \
        / (len(_steady(stats0)) * ITERS)
    bytes0 = miss0 * row_bytes
    b.emit("off", "remote_bytes_per_iter", round(bytes0))
    b.emit("off", "steady_iter_ms",
           round(1000 * np.mean([s.steady_time_s / ITERS
                                 for s in _steady(stats0)]), 2))
    losses0 = [s.loss for s in stats0]

    spec_pb = model_param_bytes(init_gnn(
        __import__("jax").random.PRNGKey(0), cfg))
    b.emit("off", "alpha", round(alpha_ratio(int(miss0), d, spec_pb), 2))

    drop_at_covering = {}
    for policy in ("degree", "lfu"):
        for frac in (0.1, 0.5, 1.0):
            rows = max(1, int(round(covering * frac)))
            engine.clear_compile_cache()
            tr, stats = _fit(env, cfg, cache_policy=policy,
                             cache_budget_bytes=rows * row_bytes)
            case = f"{policy}-{int(100 * frac)}pct"
            steady = _steady(stats)
            hit = float(np.mean([s.cache_hit_rate for s in steady]))
            miss = sum(s.remote_rows for s in steady) \
                / (len(steady) * ITERS)
            refresh_rows = tr.cache_store.rows_installed()
            bytes_i = miss * row_bytes
            drop = bytes0 / max(bytes_i, 1.0)
            b.emit(case, "budget_rows", rows)
            b.emit(case, "hit_rate_pct", round(100 * hit, 1))
            b.emit(case, "remote_bytes_per_iter", round(bytes_i))
            b.emit(case, "bytes_drop_x", round(drop, 2))
            b.emit(case, "steady_iter_ms",
                   round(1000 * np.mean([s.steady_time_s / ITERS
                                         for s in steady]), 2))
            b.emit(case, "refresh_s_per_epoch",
                   round(float(np.mean([s.cache_refresh_s
                                        for s in steady])), 4))
            b.emit(case, "traces_after_epoch0",
                   sum(s.traces for s in steady))
            b.emit(case, "alpha_cached",
                   round(alpha_ratio_cached(int(miss), d, spec_pb,
                                            refresh_rows=refresh_rows,
                                            iters_per_refresh=ITERS), 2))
            # bitwise training parity: same seeds → same per-epoch losses
            b.emit(case, "loss_bit_identical",
                   int([s.loss for s in stats] == losses0))
            if frac == 1.0:
                drop_at_covering[policy] = drop

    # ---- single-iteration gradient bit-parity, cache on vs off ----
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    tv = env["ds"].train_vertices()
    roots = [rng.choice(tv, BATCH, replace=False)
             for _ in range(env["parts"])]
    kw = dict(num_layers=cfg.num_layers, fanout=cfg.fanout,
              strategy="hopgnn", pregather=True, sample_seed=11)
    args = (env["ds"].graph, env["ds"].labels, env["part"], env["owner"],
            env["local_idx"], env["table"].shape[1], roots)
    params = init_gnn(jax.random.PRNGKey(0), cfg)
    plan_off = plan_iteration(*args, **kw)
    pol = DegreePolicy(env["ds"].graph, env["owner"])
    store = CacheStore(env["parts"], d, c_max=256)
    ids = [pol.select(s, 256) for s in range(env["parts"])]
    store.install(ids, [env["table"][env["owner"][i], env["local_idx"][i]]
                        for i in ids])
    plan_on = plan_iteration(*args, **kw, cache_index=store.index)
    g0, l0 = run_iteration(params, env["table"], plan_off, cfg)
    g1, l1 = run_iteration(params, env["table"], plan_on, cfg,
                           cache=store.device_table)
    dmax = max(float(jnp.abs(a - c).max()) for a, c in
               zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
    b.emit("parity", "grad_dmax", dmax)
    b.emit("parity", "loss_equal", int(float(l0) == float(l1)))
    b.emit("parity", "hit_rows", plan_on.cache_hit_rows)

    b.emit("summary", "bytes_drop_x_covering_lfu",
           round(drop_at_covering.get("lfu", 0.0), 2))
    b.emit("summary", "meets_2x_gate",
           int(max(drop_at_covering.values(), default=0.0) >= 2.0))
    b.save_csv()
    b.save_json()
    return b.rows


if __name__ == "__main__":
    run()
