"""Fig. 11/12 reproduction: end-to-end per-iteration time for DGL-style
model-centric, P³, naive feature-centric, and HopGNN, across the GNN model
suite.

The container is 1 CPU core, so A100 wall-clock is not measurable; we
reproduce the paper's *decomposition* instead: exact per-strategy
communication bytes over the paper's 10 Gb/s fabric, plus a compute term
modeled from the iteration's FLOPs at the paper's observed GPU efficiency
(Fig. 20 shows < 20 % of one A100 kept busy by sparse GNN kernels; we use
10 % of 312 TFLOP/s). Compute is identical across strategies (same kernels,
same trees — the parity invariant), exactly as in the paper; the ratios are
communication-driven, which is the paper's own bottleneck analysis (Fig. 4:
gathering is 44–83 % of step time).

A second, *measured* section runs a small real training through the
repro.train Trainer and reports first-epoch (compile-inclusive) vs
steady-state iteration times plus the jit trace count — the compile-once
claim as wall-clock, not a model.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Bench, DEFAULT_FABRIC, gnn_cfg, model_spec,
                               sample_roots, setup)
from repro.core import plan_iteration
from repro.core import distributed as engine
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.train import Trainer
from repro.core.comm_model import (hopgnn_bytes, model_centric_bytes,
                                   naive_fc_bytes, p3_bytes)
from repro.graph.sampler import micrograph_split, sample_tree_block

A100_EFFECTIVE = 312e12 * 0.10      # paper Fig. 20: sparse kernels <20% util


def _iter_flops(plan, cfg) -> float:
    """fwd+bwd ≈ 6 × Σ_hop rows_h × d_in_h × d_out_h (dense tree layout)."""
    total = 0.0
    d_in = cfg.feature_dim
    rows = plan.total_rows
    for layer in range(cfg.num_layers):
        d_out = cfg.hidden_dim
        total += 6.0 * rows * d_in * d_out
        d_in = d_out
        rows /= max(cfg.fanout, 2)
    return total


def run(quick=True):
    b = Bench("end_to_end")
    # scale matters here: on a few-thousand-vertex graph the batch saturates
    # the vertex set and dedup hides the feature traffic the paper measures;
    # 0.15 (~37k vertices) is the smallest products analogue in the paper's
    # regime (features ≫ model).
    env = setup(dataset="products", scale=0.15 if quick else 0.5)
    per_model = 128 if quick else 512       # paper batches: 512–16k roots
    models = ("gcn", "sage", "gat", "deepgcn", "film")
    fabric = DEFAULT_FABRIC
    speedups = {}
    for model in models:
        # deep models are the paper's Model(16) headline (Fig. 12): their α
        # is largest there; h128 deep is also reported (scale caveat in
        # EXPERIMENTS.md — a 37k-vertex graph caps feature volume, so the
        # migration share is pessimistic vs the paper's 2.45M vertices).
        hiddens = (16, 128) if (quick and model in ("deepgcn", "film")) \
            else ((128,) if quick else (16, 128))
        for hidden in hiddens:
            cfg = gnn_cfg(model, env, hidden=hidden, fanout=10)
            spec = model_spec(cfg, env)
            rng = np.random.default_rng(0)
            roots_pm = sample_roots(env, per_model, rng=rng)

            micros, shard_of = [], []
            for s, roots in enumerate(roots_pm):
                blk = sample_tree_block(env["ds"].graph, roots,
                                        cfg.num_layers, cfg.fanout, seed=5)
                micros.extend(micrograph_split(blk))
                shard_of.extend([s] * len(roots))

            plan_hop = plan_iteration(
                env["ds"].graph, env["ds"].labels, env["part"],
                env["owner"], env["local_idx"], env["table"].shape[1],
                roots_pm, num_layers=cfg.num_layers, fanout=cfg.fanout,
                strategy="hopgnn", pregather=True, sample_seed=5)

            compute_s = _iter_flops(plan_hop, cfg) / A100_EFFECTIVE \
                / env["parts"]

            mc = model_centric_bytes(micros, env["owner"], shard_of, spec,
                                     env["parts"])
            nv = naive_fc_bytes(micros, env["owner"], spec, env["parts"])
            p3 = p3_bytes(micros, env["owner"], shard_of, spec,
                          env["parts"])
            hop = hopgnn_bytes(plan_hop.remote_rows_exact,
                               plan_hop.num_steps, spec, env["parts"],
                               replicated_params=False)

            case = f"products-{model}-h{hidden}"
            times = {}
            for name, d, msgs in (("dgl", mc, 4), ("p3", p3, 8),
                                  ("naive", nv, nv.get("migrations", 4)),
                                  ("hopgnn", hop,
                                   2 * plan_hop.num_steps)):
                comm_s = fabric.seconds(d["total"] / env["parts"],
                                        messages=msgs)
                times[name] = comm_s + compute_s
                b.emit(case, f"{name}_iter_ms",
                       round(1000 * times[name], 3))
                b.emit(case, f"{name}_comm_ms", round(1000 * comm_s, 3))
            b.emit(case, "compute_ms", round(1000 * compute_s, 3))
            b.emit(case, "comm_share_dgl_pct",
                   round(100 * (times["dgl"] - compute_s) / times["dgl"], 1))
            sp = {k: times[k] / times["hopgnn"] for k in times}
            speedups[(model, hidden)] = sp
            for k in ("dgl", "p3", "naive"):
                b.emit(case, f"speedup_vs_{k}", round(sp[k], 2))
    # ---- measured: compile-once Trainer, first vs steady epoch ----
    env_m = setup(dataset="products", scale=0.03)
    cfg_m = GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                      feature_dim=env_m["ds"].feature_dim,
                      num_classes=env_m["ds"].num_classes, fanout=4)
    tc0 = engine.trace_count()
    trainer = Trainer.from_env(env_m, cfg_m, optimizer=adam(5e-3),
                               merging=False)
    iters = 4
    stats = trainer.fit(epochs=3, iters_per_epoch=iters, batch_per_model=8)
    first, steady = stats[0], stats[1:]
    steady_iter = sum(s.time_s for s in steady) / (len(steady) * iters)
    b.emit("measured", "first_epoch_iter_ms",
           round(1000 * first.time_s / iters, 2))
    b.emit("measured", "steady_iter_ms", round(1000 * steady_iter, 2))
    b.emit("measured", "steady_device_iter_ms",
           round(1000 * steady[-1].steady_time_s / iters, 2))
    b.emit("measured", "jit_traces", engine.trace_count() - tc0)
    b.emit("measured", "traces_after_epoch0",
           sum(s.traces for s in steady))
    b.emit("measured", "compile_amortization_x",
           round(first.time_s / max(sum(s.time_s for s in steady)
                                    / len(steady), 1e-9), 1))

    best_p3 = max(v["p3"] for v in speedups.values())
    b.emit("summary", "best_speedup_vs_p3", round(best_p3, 2))
    b.emit("summary", "hopgnn_beats_dgl_everywhere",
           int(all(v["dgl"] > 1 for v in speedups.values())))
    b.emit("summary", "hopgnn_beats_naive_everywhere",
           int(all(v["naive"] > 1 for v in speedups.values())))
    b.save_csv()
    b.save_json()
    return b.rows


if __name__ == "__main__":
    run()
