"""Fig. 11/12 reproduction: end-to-end per-iteration time for DGL-style
model-centric, P³, naive feature-centric, and HopGNN, across the GNN model
suite.

The container is 1 CPU core, so A100 wall-clock is not measurable; we
reproduce the paper's *decomposition* instead: exact per-strategy
communication bytes over the paper's 10 Gb/s fabric, plus a compute term
modeled from the iteration's FLOPs at the paper's observed GPU efficiency
(Fig. 20 shows < 20 % of one A100 kept busy by sparse GNN kernels; we use
10 % of 312 TFLOP/s). Compute is identical across strategies (same kernels,
same trees — the parity invariant), exactly as in the paper; the ratios are
communication-driven, which is the paper's own bottleneck analysis (Fig. 4:
gathering is 44–83 % of step time).

A second, *measured* section runs a small real training through the
repro.train Trainer and reports first-epoch (compile-inclusive) vs
steady-state iteration times plus the jit trace count — the compile-once
claim as wall-clock, not a model. It is an A/B over the async device
pipeline: the same training runs once through the pre-pipeline loop
(grads round-trip, eager optimizer, per-iteration loss sync) and once
through the pipelined loop (fused donated step, non-blocking dispatch,
committed plan uploads, K-stacked dispatch), and the host-overhead gap
``steady_iter_ms − steady_device_iter_ms`` is reported for both. The CI
gate requires pipelined steady wall ≤ ½ of unpipelined on the emulated
8-shard config.

    python -m benchmarks.end_to_end [--measured-only]
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Bench, DEFAULT_FABRIC, gnn_cfg, model_spec,
                               sample_roots, setup)
from repro.core import plan_iteration
from repro.core import distributed as engine
from repro.models.gnn import GNNConfig
from repro.optim import adam
from repro.train import Trainer
from repro.core.comm_model import (hopgnn_bytes, model_centric_bytes,
                                   naive_fc_bytes, p3_bytes)
from repro.graph.sampler import micrograph_split, sample_tree_block

A100_EFFECTIVE = 312e12 * 0.10      # paper Fig. 20: sparse kernels <20% util


def _iter_flops(plan, cfg) -> float:
    """fwd+bwd ≈ 6 × Σ_hop rows_h × d_in_h × d_out_h (dense tree layout)."""
    total = 0.0
    d_in = cfg.feature_dim
    rows = plan.total_rows
    for layer in range(cfg.num_layers):
        d_out = cfg.hidden_dim
        total += 6.0 * rows * d_in * d_out
        d_in = d_out
        rows /= max(cfg.fanout, 2)
    return total


def _ab(env, cfg, epochs, iters, batch, stack):
    """One pipeline A/B: identical training through the pre-PR5 loop
    (eager optimizer update, per-iteration float(loss) sync, per-call
    device uploads) and the async pipeline (fused donated step,
    non-blocking dispatch, committed ping-pong uploads, K-stacked
    dispatch). Returns (legacy stats, pipelined stats, pipelined traces).
    """
    def fit(**kw):
        tc0 = engine.trace_count()
        trainer = Trainer.from_env(env, cfg, optimizer=adam(5e-3),
                                   merging=False, **kw)
        stats = trainer.fit(epochs=epochs, iters_per_epoch=iters,
                            batch_per_model=batch)
        return stats, engine.trace_count() - tc0

    stats_u, _ = fit(pipeline=False, fused=False)
    stats_p, traces_p = fit(pipeline=True, pipeline_stack=stack)
    return stats_u, stats_p, traces_p


def _measured(b: Bench) -> None:
    """Wall-clock section, two configurations.

    ``measured`` — the historical compile-once config (4 shards, scale
    0.03; the pre-PR5 baseline recorded steady_iter_ms 278.4 here against
    a 10.5 ms device estimate — the 27× host-overhead gap). steady_iter_ms
    is the mean steady-epoch wall per iteration through the *pipelined*
    Trainer; the unpipelined figure and the host-overhead decomposition
    ride along. steady_device_iter_ms is the device floor of the
    production step: the fused program run through the *blocking* loop,
    compile-free iterations only — what an iteration costs when the host
    adds nothing but one dispatch and one sync.

    ``pipeline8`` — the emulated 8-shard A/B the CI gate reads: a small
    model (host overhead dominates device time, the regime the pipeline
    targets), pipelined steady per-iteration wall must be ≤ ½ of
    unpipelined. Medians over steady epochs (1-core container noise).
    """
    env_m = setup(dataset="products", scale=0.03)
    cfg_m = GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                      feature_dim=env_m["ds"].feature_dim,
                      num_classes=env_m["ds"].num_classes, fanout=4)
    epochs, iters = 4, 8
    stats_u, stats_p, traces_p = _ab(env_m, cfg_m, epochs, iters,
                                     batch=8, stack=4)
    # device floor: the fused step through the blocking loop (host adds
    # one dispatch + one sync per iteration, nothing else)
    tr_f = Trainer.from_env(env_m, cfg_m, optimizer=adam(5e-3),
                            merging=False, pipeline=False, fused=True)
    stats_f = tr_f.fit(epochs=epochs, iters_per_epoch=iters,
                       batch_per_model=8)

    def steady_wall_ms(stats):
        steady = stats[1:]
        return 1000 * sum(s.time_s for s in steady) / (len(steady) * iters)

    first = stats_p[0]
    wall_p = steady_wall_ms(stats_p)
    wall_u = steady_wall_ms(stats_u)
    dev_ms = 1000 * float(np.median([s.steady_time_s / iters
                                     for s in stats_f[1:]
                                     if s.compile_free]))
    b.emit("measured", "steady_iter_ms", round(wall_p, 2))
    b.emit("measured", "steady_iter_ms_unpipelined", round(wall_u, 2))
    b.emit("measured", "steady_device_iter_ms", round(dev_ms, 2))
    b.emit("measured", "host_overhead_ms",
           round(max(wall_p - dev_ms, 0.0), 2))
    b.emit("measured", "host_overhead_ms_unpipelined",
           round(max(wall_u - dev_ms, 0.0), 2))
    b.emit("measured", "pipeline_speedup_x", round(wall_u / wall_p, 2))
    b.emit("measured", "steady_dispatch_iter_ms",
           round(1000 * float(np.mean([s.dispatch_s / iters
                                       for s in stats_p[1:]])), 2))
    b.emit("measured", "first_epoch_iter_ms",
           round(1000 * first.time_s / iters, 2))
    b.emit("measured", "jit_traces", traces_p)
    b.emit("measured", "traces_after_epoch0",
           sum(s.traces for s in stats_p[1:]))
    b.emit("measured", "compile_amortization_x",
           round(first.time_s / max(sum(s.time_s for s in stats_p[1:])
                                    / len(stats_p[1:]), 1e-9), 1))

    # ---- emulated 8-shard gate config: host-overhead-dominated model ----
    env_8 = setup(dataset="products", scale=0.03, parts=8)
    cfg_8 = GNNConfig(model="sage", num_layers=2, hidden_dim=16,
                      feature_dim=env_8["ds"].feature_dim,
                      num_classes=env_8["ds"].num_classes, fanout=2)
    epochs8, iters8 = 5, 16
    stats_u8, stats_p8, _ = _ab(env_8, cfg_8, epochs8, iters8,
                                batch=2, stack=8)

    def steady_med_ms(stats):
        # compile-free steady per-iteration wall (synced window for the
        # pipelined loop, trace-free iteration walls for the legacy one);
        # median over steady epochs — 1-core container timings are noisy
        return 1000 * float(np.median([s.steady_time_s / iters8
                                       for s in stats[1:]
                                       if s.compile_free]))

    p8, u8 = steady_med_ms(stats_p8), steady_med_ms(stats_u8)
    b.emit("pipeline8", "steady_iter_ms", round(p8, 2))
    b.emit("pipeline8", "steady_iter_ms_unpipelined", round(u8, 2))
    b.emit("pipeline8", "pipeline_speedup_x", round(u8 / p8, 2))
    b.emit("pipeline8", "traces_after_epoch0",
           sum(s.traces for s in stats_p8[1:]))
    b.emit("pipeline8", "meets_half_gate", int(p8 <= 0.5 * u8))


def run(quick=True, measured_only=False):
    if measured_only:
        # own bench name: the full suite's BENCH_end_to_end.json (comm-model
        # decomposition + measured sections) must not be clobbered by the
        # quick `make bench-pipeline` smoke
        b = Bench("pipeline")
        _measured(b)
        b.save_csv()
        b.save_json()
        return b.rows
    b = Bench("end_to_end")
    # scale matters here: on a few-thousand-vertex graph the batch saturates
    # the vertex set and dedup hides the feature traffic the paper measures;
    # 0.15 (~37k vertices) is the smallest products analogue in the paper's
    # regime (features ≫ model).
    env = setup(dataset="products", scale=0.15 if quick else 0.5)
    per_model = 128 if quick else 512       # paper batches: 512–16k roots
    models = ("gcn", "sage", "gat", "deepgcn", "film")
    fabric = DEFAULT_FABRIC
    speedups = {}
    for model in models:
        # deep models are the paper's Model(16) headline (Fig. 12): their α
        # is largest there; h128 deep is also reported (scale caveat in
        # EXPERIMENTS.md — a 37k-vertex graph caps feature volume, so the
        # migration share is pessimistic vs the paper's 2.45M vertices).
        hiddens = (16, 128) if (quick and model in ("deepgcn", "film")) \
            else ((128,) if quick else (16, 128))
        for hidden in hiddens:
            cfg = gnn_cfg(model, env, hidden=hidden, fanout=10)
            spec = model_spec(cfg, env)
            rng = np.random.default_rng(0)
            roots_pm = sample_roots(env, per_model, rng=rng)

            micros, shard_of = [], []
            for s, roots in enumerate(roots_pm):
                blk = sample_tree_block(env["ds"].graph, roots,
                                        cfg.num_layers, cfg.fanout, seed=5)
                micros.extend(micrograph_split(blk))
                shard_of.extend([s] * len(roots))

            plan_hop = plan_iteration(
                env["ds"].graph, env["ds"].labels, env["part"],
                env["owner"], env["local_idx"], env["table"].shape[1],
                roots_pm, num_layers=cfg.num_layers, fanout=cfg.fanout,
                strategy="hopgnn", pregather=True, sample_seed=5)

            compute_s = _iter_flops(plan_hop, cfg) / A100_EFFECTIVE \
                / env["parts"]

            mc = model_centric_bytes(micros, env["owner"], shard_of, spec,
                                     env["parts"])
            nv = naive_fc_bytes(micros, env["owner"], spec, env["parts"])
            p3 = p3_bytes(micros, env["owner"], shard_of, spec,
                          env["parts"])
            hop = hopgnn_bytes(plan_hop.remote_rows_exact,
                               plan_hop.num_steps, spec, env["parts"],
                               replicated_params=False)

            case = f"products-{model}-h{hidden}"
            times = {}
            for name, d, msgs in (("dgl", mc, 4), ("p3", p3, 8),
                                  ("naive", nv, nv.get("migrations", 4)),
                                  ("hopgnn", hop,
                                   2 * plan_hop.num_steps)):
                comm_s = fabric.seconds(d["total"] / env["parts"],
                                        messages=msgs)
                times[name] = comm_s + compute_s
                b.emit(case, f"{name}_iter_ms",
                       round(1000 * times[name], 3))
                b.emit(case, f"{name}_comm_ms", round(1000 * comm_s, 3))
            b.emit(case, "compute_ms", round(1000 * compute_s, 3))
            b.emit(case, "comm_share_dgl_pct",
                   round(100 * (times["dgl"] - compute_s) / times["dgl"], 1))
            sp = {k: times[k] / times["hopgnn"] for k in times}
            speedups[(model, hidden)] = sp
            for k in ("dgl", "p3", "naive"):
                b.emit(case, f"speedup_vs_{k}", round(sp[k], 2))
    # ---- measured: compile-once Trainer + async-pipeline A/B ----
    _measured(b)

    best_p3 = max(v["p3"] for v in speedups.values())
    b.emit("summary", "best_speedup_vs_p3", round(best_p3, 2))
    b.emit("summary", "hopgnn_beats_dgl_everywhere",
           int(all(v["dgl"] > 1 for v in speedups.values())))
    b.emit("summary", "hopgnn_beats_naive_everywhere",
           int(all(v["naive"] > 1 for v in speedups.values())))
    b.save_csv()
    b.save_json()
    return b.rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--measured-only", action="store_true",
                    help="skip the comm-model sweep; run only the measured "
                         "pipeline A/B (the `make bench-pipeline` target)")
    args = ap.parse_args()
    run(quick=not args.full, measured_only=args.measured_only)
