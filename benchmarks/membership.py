"""repro.membership benchmark: the cost of losing a peer.

Two claims get numbers here:

1. **Same-world rejoin is bit-exact and cheap.** A persistent peer death
   injected mid-epoch is detected through the comm deadline, confirmed by
   the bounded probe, and recovered by rejoin + resume from the shared
   epoch-boundary checkpoint. Gates: loss parity vs the fault-free run is
   exactly 0 (``parity_ok``), and the steady state after recovery has
   zero retraces (``zero_steady_retraces``). Detection / rebuild / resume
   phase walls come from the ``membership.*`` spans.

2. **Elastic shrink stays on the loss trajectory.** When the policy
   shrinks the world to P-1 instead (redistribute), training continues
   and the final loss lands within ``SHRINK_TOL`` relative of a fresh
   P-1 baseline — the partition move costs redistribution wall and one
   recovery retrace, not convergence. The post-recovery steady state is
   retrace-free here too: the new world's shapes are traced once.

Writes BENCH_membership.json at the repo root (benchmarks.common.Bench).
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import numpy as np

from benchmarks.common import Bench
from repro.core import distributed as engine
from repro.graph import ldg_partition, make_dataset
from repro.graph.partition import shard_features
from repro.models.gnn import GNNConfig
from repro.obs import trace
from repro.optim import adam
from repro.resilience import (FaultPlan, FaultSpec, ResiliencePolicy,
                              RetryPolicy)
from repro.train import Trainer

EPOCHS = 4
ITERS = 6
BATCH = 8
PARTS = 4
SHRINK_TOL = 0.35           # relative final-loss gap vs fresh P-1 baseline
KILL = dict(epoch=2, it=2, shard=1)


def _cfg(ds):
    return GNNConfig(model="sage", num_layers=2, hidden_dim=32,
                     feature_dim=ds.feature_dim,
                     num_classes=ds.num_classes, fanout=4)


def _policy(mode="rejoin"):
    return ResiliencePolicy(
        retry=RetryPolicy(max_retries=2, backoff_s=0.002, deadline_s=5.0),
        membership_mode=mode, probe_backoff_s=0.001)


def _fit(ds, part, owner, local_idx, table, cfg, plan=None, **kw):
    tr = Trainer(graph=ds.graph, labels=ds.labels, part=part, owner=owner,
                 local_idx=local_idx, table=table, cfg=cfg,
                 optimizer=adam(5e-3), merging=False,
                 train_vertices=ds.train_vertices(), **kw)
    if plan is not None:
        with plan.active():
            stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                           batch_per_model=BATCH)
    else:
        stats = tr.fit(epochs=EPOCHS, iters_per_epoch=ITERS,
                       batch_per_model=BATCH)
    return tr, stats


def _wall_s(stats):
    return float(sum(s.time_s for s in stats))


def _phase_ms(records):
    out = {}
    for r in records:
        if r.name.startswith("membership."):
            out[r.name.split(".", 1)[1]] = \
                out.get(r.name.split(".", 1)[1], 0.0) + r.dur_ns / 1e6
    return out


def run(quick=True):
    b = Bench("membership")
    scale = 0.04 if quick else 0.2
    ds = make_dataset("arxiv", scale=scale, seed=0)
    part = ldg_partition(ds.graph, PARTS, passes=1)
    table, owner, local_idx = shard_features(
        np.asarray(ds.features), part, PARTS)
    cfg = _cfg(ds)

    # ---- baseline: fault-free, membership plumbing on ----
    engine.clear_compile_cache()
    _, st_clean = _fit(ds, part, owner, local_idx, table, cfg,
                       resilience=_policy())
    clean_wall = _wall_s(st_clean)
    b.emit("clean", "wall_s", round(clean_wall, 2))
    b.emit("clean", "final_loss", round(float(st_clean[-1].loss), 4))

    # ---- 1. same-world rejoin: bit parity + phase walls ----
    with tempfile.TemporaryDirectory() as td:
        fp = FaultPlan([FaultSpec("peer_death", **KILL)])
        engine.clear_compile_cache()
        trace.clear()
        trace.enable()
        try:
            tr_r, st_r = _fit(ds, part, owner, local_idx, table, cfg,
                              plan=fp, resilience=_policy(),
                              ckpt_dir=str(Path(td) / "ck"))
            phases = _phase_ms(trace.records())
        finally:
            trace.disable()
    parity = float(np.max(np.abs(
        np.array([s.loss for s in st_r])
        - np.array([s.loss for s in st_clean]))))
    steady_retraces = sum(s.traces for s in st_r[KILL["epoch"] + 1:])
    b.emit("rejoin", "faults_fired", fp.fired_count())
    b.emit("rejoin", "recoveries", tr_r.membership_recoveries)
    b.emit("rejoin", "generation", tr_r.membership.generation)
    b.emit("rejoin", "wall_s", round(_wall_s(st_r), 2))
    b.emit("rejoin", "recovery_wall_ratio",
           round(_wall_s(st_r) / clean_wall, 3))
    for name in ("detect", "rebuild", "resume"):
        b.emit("rejoin", f"{name}_ms", round(phases.get(name, 0.0), 3))
    b.emit("rejoin", "steady_retraces_after_recovery", steady_retraces)
    b.emit("parity", "loss_dmax_rejoin_vs_clean", parity)

    # ---- 2. elastic shrink (redistribute) vs fresh P-1 baseline ----
    fp_s = FaultPlan([FaultSpec("peer_death", **KILL)])
    engine.clear_compile_cache()
    tr_s, st_s = _fit(ds, part, owner, local_idx, table, cfg, plan=fp_s,
                      resilience=_policy(mode="redistribute"))
    part3 = ldg_partition(ds.graph, PARTS - 1, passes=1)
    t3, o3, l3 = shard_features(np.asarray(ds.features), part3, PARTS - 1)
    engine.clear_compile_cache()
    _, st_b = _fit(ds, part3, o3, l3, t3, cfg, resilience=_policy())
    shrink_gap = abs(float(st_s[-1].loss) - float(st_b[-1].loss)) \
        / max(abs(float(st_b[-1].loss)), 1e-6)
    shrink_retraces = sum(s.traces for s in st_s[KILL["epoch"] + 1:])
    b.emit("shrink", "world_size_after", tr_s.num_shards)
    b.emit("shrink", "recoveries", tr_s.membership_recoveries)
    b.emit("shrink", "wall_s", round(_wall_s(st_s), 2))
    b.emit("shrink", "final_loss", round(float(st_s[-1].loss), 4))
    b.emit("shrink", "baseline_p3_final_loss",
           round(float(st_b[-1].loss), 4))
    b.emit("shrink", "final_loss_rel_gap", round(shrink_gap, 4))
    b.emit("shrink", "steady_retraces_after_recovery", shrink_retraces)

    # ---- gates ----
    b.emit("summary", "parity_ok", int(parity == 0.0))
    b.emit("summary", "recovered_without_intervention",
           int(tr_r.membership_recoveries >= 1
               and tr_s.membership_recoveries >= 1))
    b.emit("summary", "zero_steady_retraces",
           int(steady_retraces == 0 and shrink_retraces == 0))
    b.emit("summary", "shrink_tol", SHRINK_TOL)
    b.emit("summary", "shrink_within_tolerance",
           int(shrink_gap <= SHRINK_TOL))
    b.save_csv()
    b.save_json()
    return b


if __name__ == "__main__":
    run()
