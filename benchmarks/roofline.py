"""§Roofline (deliverable g): three-term roofline per (arch × shape × mesh)
from the compiled dry-run artifacts.

  compute    = HLO_FLOPs / (chips × peak)        peak = 197 TFLOP/s bf16
  memory     = HLO_bytes / (chips × HBM_bw)      HBM  = 819 GB/s
  collective = coll_bytes / (chips × link_bw)    ICI  = 50 GB/s/link

HLO numbers come from ``cost_analysis`` with the scan-trip-count
extrapolation done by the dry-run (see launch/dryrun.py); collective bytes
are the HLO census. cost_analysis on the partitioned module is already
per-device, so `chips` appears only in MODEL_FLOPS normalization.

MODEL_FLOPS = 6·N·D (dense; N_active for MoE) for train (×1/3 for pure
forward shapes: 2·N·D), giving the useful-compute ratio that flags
remat/redundancy waste.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

DRYRUN = Path(__file__).resolve().parent / "results" / "dryrun"


def shape_tokens(shape: str, arch_rec: dict) -> int:
    from repro.configs import SHAPES
    sh = SHAPES[shape]
    if sh.kind == "decode":
        return sh.global_batch          # one token per sequence
    return sh.global_batch * sh.seq_len


def analyze(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    compute_s = rec["flops"] / PEAK_FLOPS            # per-chip flops already
    memory_s = rec["bytes_accessed"] / HBM_BW
    coll_s = rec["collective_bytes_total"] / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)

    n = rec["active_params"]
    tokens = shape_tokens(rec["shape"], rec)
    factor = 6.0 if rec["shape"].startswith("train") else 2.0
    model_flops_per_chip = factor * n * tokens / chips
    useful = model_flops_per_chip / max(rec["flops"], 1.0)

    bound_s = max(terms.values())
    # roofline fraction: useful work / what the dominant term costs
    mfu_bound = (model_flops_per_chip / PEAK_FLOPS) / max(bound_s, 1e-12)
    return {**terms, "dominant": dominant.replace("_s", ""),
            "model_flops_per_chip": model_flops_per_chip,
            "useful_ratio": useful, "roofline_fraction": mfu_bound,
            "step_time_bound_s": bound_s}


def load_records(mesh: str = "16x16", tag: str = ""):
    recs = []
    for p in sorted(DRYRUN.glob("*.json")):
        r = json.loads(p.read_text())
        want_tag = tag == r.get("tag", "")
        parts = p.stem.split(".")
        has_tag = len(parts) > 3 or (len(parts) == 4)
        if r.get("mesh") != mesh or not want_tag:
            continue
        recs.append(r)
    return recs


def table(mesh: str = "16x16") -> str:
    rows = []
    hdr = (f"| arch | shape | status | compute(s) | memory(s) | "
           f"collective(s) | dominant | useful | roofline-frac |")
    sep = "|" + "---|" * 9
    rows.append(hdr)
    rows.append(sep)
    for r in load_records(mesh):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | skipped | - | - | "
                        f"- | - | - | - |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |")
            continue
        a = analyze(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {a['compute_s']:.4g} | "
            f"{a['memory_s']:.4g} | {a['collective_s']:.4g} | "
            f"{a['dominant']} | {a['useful_ratio']:.2f} | "
            f"{a['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def run(quick=True):
    from benchmarks.common import Bench
    b = Bench("roofline")
    n_ok = n_skip = 0
    worst = []
    for r in load_records("16x16"):
        if r["status"] == "skipped":
            n_skip += 1
            continue
        if r["status"] != "ok":
            b.emit(f"{r['arch']}-{r['shape']}", "status", "FAILED")
            continue
        n_ok += 1
        a = analyze(r)
        case = f"{r['arch']}-{r['shape']}"
        b.emit(case, "dominant", a["dominant"])
        b.emit(case, "compute_s", f"{a['compute_s']:.5g}")
        b.emit(case, "memory_s", f"{a['memory_s']:.5g}")
        b.emit(case, "collective_s", f"{a['collective_s']:.5g}")
        b.emit(case, "useful_ratio", f"{a['useful_ratio']:.3f}")
        b.emit(case, "roofline_fraction", f"{a['roofline_fraction']:.4f}")
        worst.append((a["roofline_fraction"], case))
    b.emit("summary", "combos_ok", n_ok)
    b.emit("summary", "combos_skipped", n_skip)
    if worst:
        worst.sort()
        b.emit("summary", "worst_roofline", f"{worst[0][1]}"
               f"={worst[0][0]:.4f}")
    b.save_csv()
    return b.rows


if __name__ == "__main__":
    import sys
    if len(sys.argv) > 1 and sys.argv[1] == "--table":
        print(table(sys.argv[2] if len(sys.argv) > 2 else "16x16"))
    else:
        run()
